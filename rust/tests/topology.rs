//! Unified `Topology` builder contract (ISSUE 4 acceptance):
//!
//! * **Legacy parity** — every deprecated `ProjectorFarm` constructor is
//!   a shim over `Topology::build_*`, and an equal-weight homogeneous
//!   topology is *bitwise identical* to the pre-refactor construction at
//!   shards 1/2/4 under both partitions (digital exact; optics bitwise —
//!   same mode windows, same noise streams — noisy included).
//! * **Weighted scheduling** — under the batch partition the farm and
//!   the frame-slot scheduler split rows proportionally to shard
//!   weights; equal weights reproduce the historical even split.
//! * **Heterogeneous fleets** — a mixed optical+digital weighted
//!   topology serves and *trains* through the sharded service, with
//!   per-shard slot/energy attribution summing correctly in `Registry`.
//! * **Value-type guarantees** — shorthand round-trips, the stable hash
//!   distinguishes topologies, `build()` is a pure function of the
//!   descriptor (two builds, same bits).

use litl::config::{MediumBacking, Partition, TrainConfig};
use litl::coordinator::farm::ProjectorFarm;
use litl::coordinator::host::{HostAlgo, HostTrainer};
use litl::coordinator::projector::Projector;
use litl::coordinator::service::{ClientProjector, ShardServiceConfig};
use litl::coordinator::topology::{DeviceKind, PoolPolicy, ShardSpec, Topology};
use litl::metrics::Registry;
use litl::net::NetOptions;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::Medium;
use litl::optics::OpuParams;
use litl::sim::power::{Holography, OpuModel};
use litl::tensor::matmul;
use litl::util::rng::Pcg64;

mod common;
use common::{task_batch, ternary_batch};

const D_IN: usize = 10;

fn dense(modes: usize) -> Medium {
    Medium::Dense(TransmissionMatrix::sample(77, D_IN, modes))
}

/// Equal-weight homogeneous topologies reproduce the legacy constructor
/// matrix bit for bit — shards 1/2/4 × both partitions, noisy optics
/// included (same windows, same `NOISE_STREAM_BASE + i` streams).
#[test]
// The deprecated shims ARE the thing under test here (legacy-parity
// pin) — the one sanctioned `allow(deprecated)` outside farm.rs's own
// shim test; everything else in tests/benches goes through Topology.
#[allow(deprecated)]
fn equal_weight_topology_is_bitwise_the_legacy_construction() {
    let tm = TransmissionMatrix::sample(77, D_IN, 28);
    for partition in [Partition::Modes, Partition::Batch] {
        for shards in [1usize, 2, 4] {
            let e = ternary_batch(6, D_IN, 900 + shards as u64);
            // Optical, noise ON: bit equality pins windows AND streams.
            let mut legacy = ProjectorFarm::optical_partitioned_backed(
                OpuParams::default(),
                &dense(28),
                13,
                shards,
                partition,
                Registry::new(),
            )
            .unwrap();
            let mut topo = Topology::homogeneous(DeviceKind::Optical, shards)
                .with_partition(partition)
                .build_farm(OpuParams::default(), &dense(28), 13, Registry::new())
                .unwrap();
            assert_eq!(
                legacy.project(&e).unwrap(),
                topo.project(&e).unwrap(),
                "optical {partition:?} shards={shards}"
            );
            // Digital: bitwise the exact stacked projection.
            let mut legacy = ProjectorFarm::digital_partitioned_backed(
                &dense(28),
                shards,
                partition,
                Registry::new(),
            )
            .unwrap();
            let mut topo = Topology::homogeneous(DeviceKind::Digital, shards)
                .with_partition(partition)
                .build_farm(OpuParams::default(), &dense(28), 0, Registry::new())
                .unwrap();
            let (l1, l2) = legacy.project(&e).unwrap();
            let (t1, t2) = topo.project(&e).unwrap();
            assert_eq!(l1, t1, "digital {partition:?} shards={shards}");
            assert_eq!(l2, t2);
            assert_eq!(l1, matmul(&e, &tm.b_re), "digital vs oracle");
            assert_eq!(l2, matmul(&e, &tm.b_im));
        }
    }
}

/// `build()` is a pure function of the topology: two farms from the
/// same descriptor produce identical bits, and the descriptor itself
/// round-trips through its serialization with a stable hash.
#[test]
fn build_is_a_pure_function_of_the_descriptor() {
    let topo = Topology::parse("opt:2@2+dig:1").unwrap();
    let reparsed = Topology::parse(&topo.shorthand()).unwrap();
    assert_eq!(topo, reparsed);
    assert_eq!(topo.stable_hash(), reparsed.stable_hash());
    let e = ternary_batch(5, D_IN, 42);
    let run = |t: &Topology| {
        let mut farm = t
            .build_farm(OpuParams::default(), &dense(24), 9, Registry::new())
            .unwrap();
        farm.project(&e).unwrap()
    };
    assert_eq!(run(&topo), run(&reparsed));
}

/// Weighted batch scheduling through the sharded service: rows go to
/// shards proportionally to weights, per scheduled frame sequence.
#[test]
fn weighted_service_splits_scheduled_rows_by_weight() {
    let mut topo =
        Topology::homogeneous(DeviceKind::Digital, 2).with_partition(Partition::Batch);
    topo.shards[0].weight = 3;
    let reg = Registry::new();
    let svc = topo
        .build_service(
            OpuParams::default(),
            &dense(16),
            0,
            D_IN,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 32,
                lane_depth: 4,
                partition: Partition::Batch,
                frame_rate_hz: 1500.0,
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
    let client = svc.client();
    let tm = TransmissionMatrix::sample(77, D_IN, 16);
    for i in 0..3 {
        let e = ternary_batch(16, D_IN, 50 + i);
        let (p1, p2) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &tm.b_re), "request {i}");
        assert_eq!(p2, matmul(&e, &tm.b_im), "request {i}");
    }
    svc.shutdown();
    let snap = reg.snapshot();
    // Each 16-row frame sequence splits 12/4 at weights 3:1.
    assert_eq!(snap["service_shard0_slots"], 36.0);
    assert_eq!(snap["service_shard1_slots"], 12.0);
    assert_eq!(reg.sum_counters("service_shard", "_slots"), 48.0);
}

/// The acceptance scenario: a mixed optical+digital *weighted* topology
/// trains end-to-end through the sharded projection service, and the
/// per-shard slot/energy attribution in `Registry` explains the totals.
#[test]
fn hetero_weighted_topology_trains_through_the_sharded_service() {
    run_hetero_training(60, 16);
}

/// The CI `hetero-smoke` job's release-mode run: same scenario, longer
/// horizon and the full synthetic-MNIST input width.
#[test]
#[ignore = "hetero smoke: run with --ignored (dedicated CI step)"]
fn hetero_smoke_full_mnist_through_weighted_service() {
    run_hetero_mnist_smoke();
}

/// Shared body: 2 optical (weight 2) + 1 digital (weight 1) shards on
/// the modes partition serve a host DFA trainer's error projections.
fn run_hetero_training(steps: u64, modes: usize) {
    let layers = [20usize, modes, modes, 10];
    let topo = Topology::parse("hetero:opt:2@2+dig:1").unwrap();
    assert!(!topo.is_homogeneous());
    assert_eq!(topo.kind_tag(), "farm-hetero");
    let medium = Medium::Dense(TransmissionMatrix::sample(91, D_IN, modes));
    let reg = Registry::new();
    let svc = topo
        .build_service(
            OpuParams::default(),
            &medium,
            7,
            D_IN,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 64,
                lane_depth: 4,
                partition: Partition::Modes,
                frame_rate_hz: 1500.0,
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
    let projector = Box::new(ClientProjector::new(svc.client(), modes));
    let mut tr = HostTrainer::new(
        11,
        &layers,
        0.01,
        HostAlgo::DfaTernary { theta: 0.1 },
        projector,
    );
    let batch = 16usize;
    let (mut first, mut last) = (0.0f32, 0.0f32);
    for t in 0..steps {
        let (x, y) = task_batch(3_000 + t, batch, &layers);
        let loss = tr.step(&x, &y).unwrap();
        if t == 0 {
            first = loss;
        }
        last = loss;
    }
    let slot_s = svc.shard_slot_seconds();
    svc.shutdown();
    assert!(last < 0.95 * first, "no learning: first={first} last={last}");

    // Attribution: modes partition charges every shard every frame.
    let total_rows = (steps * batch as u64) as f64;
    let snap = reg.snapshot();
    assert_eq!(snap["service_frames"], total_rows);
    for shard in 0..3 {
        assert_eq!(
            snap[&format!("service_shard{shard}_slots")],
            total_rows,
            "shard {shard} slots"
        );
    }
    assert_eq!(
        reg.sum_counters("service_shard", "_slots"),
        3.0 * total_rows,
        "fleet slot roll-up"
    );
    // Scheduler slot clocks agree with the counters, and the energy
    // model prices exactly the summed slots.
    let clock_total: f64 = slot_s.iter().sum();
    assert!((clock_total - 3.0 * total_rows / 1500.0).abs() < 1e-9);
    let opu = OpuModel::paper(Holography::OffAxis);
    let slots: Vec<u64> = (0..3)
        .map(|i| snap[&format!("service_shard{i}_slots")] as u64)
        .collect();
    let fleet_energy = opu.service_energy(&slots);
    let per_shard_energy: f64 =
        slots.iter().map(|&s| s as f64 * opu.slot_energy()).sum();
    assert!(
        (fleet_energy - per_shard_energy).abs() < 1e-9,
        "fleet energy {fleet_energy} != per-shard sum {per_shard_energy}"
    );
}

/// Release-mode smoke at synthetic-MNIST scale (784-dim inputs).
fn run_hetero_mnist_smoke() {
    use litl::data::{self, Split};
    let modes = 32usize;
    let layers = [784usize, modes, modes, 10];
    let ds = data::load_or_synth(7, 2_000, 500).unwrap();
    let topo = Topology::parse("opt:2@2+dig:1").unwrap();
    let medium = Medium::Dense(TransmissionMatrix::sample(91, D_IN, modes));
    let reg = Registry::new();
    let svc = topo
        .build_service(
            OpuParams::default(),
            &medium,
            7,
            D_IN,
            ShardServiceConfig {
                max_batch: 128,
                queue_depth: 64,
                lane_depth: 4,
                partition: Partition::Modes,
                frame_rate_hz: 1500.0,
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
    let projector = Box::new(ClientProjector::new(svc.client(), modes));
    let mut tr = HostTrainer::new(
        11,
        &layers,
        0.01,
        HostAlgo::DfaTernary { theta: 0.1 },
        projector,
    );
    let batch = 32usize;
    let mut rng = Pcg64::seeded(5);
    let mut steps = 0u64;
    let (mut first, mut last) = (0.0f32, 0.0f32);
    'outer: for _epoch in 0..4 {
        let mut shuffle = rng.split();
        for (x, y) in ds.batches(Split::Train, batch, &mut shuffle) {
            let loss = tr.step(&x, &y).unwrap();
            if steps == 0 {
                first = loss;
            }
            last = loss;
            steps += 1;
            if steps >= 200 {
                break 'outer;
            }
        }
    }
    svc.shutdown();
    assert!(last < 0.8 * first, "no learning: first={first} last={last}");
    // Accuracy well above chance on held-out digits.
    let idxs: Vec<usize> = (0..500).collect();
    let (tx, ty) = ds.gather(Split::Test, &idxs);
    let acc = tr.mlp.accuracy(&tx, &ty);
    assert!(acc > 0.3, "test accuracy {acc} barely above chance");
    // Every scheduled frame is attributed on every shard (modes axis).
    let total_rows = (steps * batch as u64) as f64;
    assert_eq!(
        reg.sum_counters("service_shard", "_slots"),
        3.0 * total_rows
    );
    assert_eq!(reg.snapshot()[litl::coordinator::service::SHARD_ERRORS], 0.0);
}

/// Explicit mode ranges and per-shard noise streams build too — the
/// fully-specified descriptor, not just the weight-derived one.
#[test]
fn explicit_ranges_and_streams_build_and_match_windows() {
    let tm = TransmissionMatrix::sample(77, D_IN, 24);
    let topo = Topology {
        shards: vec![
            ShardSpec {
                device: DeviceKind::Digital,
                weight: 1,
                mode_range: Some((0, 10)),
                noise_stream: None,
                endpoint: None,
            },
            ShardSpec {
                device: DeviceKind::Digital,
                weight: 1,
                mode_range: Some((10, 24)),
                noise_stream: None,
                endpoint: None,
            },
        ],
        partition: Partition::Modes,
        backing: MediumBacking::Materialized,
        pool: PoolPolicy::Owned,
        net: NetOptions::default(),
    };
    let mut farm = topo
        .build_farm(OpuParams::default(), &dense(24), 0, Registry::new())
        .unwrap();
    assert_eq!(farm.mode_counts(), &[10, 14]);
    let e = ternary_batch(4, D_IN, 8);
    let (p1, _) = farm.project(&e).unwrap();
    assert_eq!(p1, matmul(&e, &tm.b_re));
}

/// TrainConfig wiring: the resolved projection topology follows the
/// `[topology]` section / `--topology` shorthand, and validation
/// rejects the impossible combinations before any artifact loads.
#[test]
fn train_config_resolves_and_validates_topologies() {
    let mut cfg = TrainConfig::default();
    cfg.set_kv("topology=\"opt:2@2+dig:1\"").unwrap();
    cfg.validate_projection().unwrap();
    let topo = cfg.projection_topology();
    assert_eq!(topo.shorthand(), "opt:2@2+dig:1");
    assert_eq!(topo.weights(), vec![2, 2, 1]);

    // streamed + hlo is rejected (the artifact needs dense tensors).
    let mut cfg = TrainConfig::default();
    cfg.set_kv("projector=hlo").unwrap();
    cfg.set_kv("medium=streamed").unwrap();
    assert!(cfg.validate_projection().is_err());

    // hlo cannot drive a topology at all.
    let mut cfg = TrainConfig::default();
    cfg.set_kv("projector=hlo").unwrap();
    cfg.set_kv("topology=opt:2").unwrap();
    assert!(cfg.validate_projection().is_err());
}
