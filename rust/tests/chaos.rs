//! Chaos soak: the networked fleet under seeded fault injection.
//!
//! The standing contract this suite pins (new in the v2 wire protocol):
//! with **session resume enabled**, a fault-ridden loopback run —
//! connection cuts, partial writes, single-bit corruption, stalls, and
//! server-side device error bursts, all from one seeded
//! [`FaultPlanCfg`] — finishes **bitwise identical** to the fault-free
//! run, noisy optics included, at shards 1/2/4 × both partitions.  The
//! server's replay journal executes every frame exactly once, so a
//! resumed re-request can never double-advance a device's noise stream.
//!
//! With resume **disabled**, behavior degrades exactly as PR-9 pinned:
//! an in-flight frame on a dying connection completes with an error
//! (zero hangs, bounded wall time) and the serving layer's failover
//! drains the tripped shard onto survivors.
//!
//! Also covered here: the wire-version bump (v1 clients rejected with a
//! typed error before any payload is trusted), stale-UDS-socket
//! reclamation at bind, and (under the CI `chaos-smoke` job) graceful
//! SIGTERM shutdown of a real `litl serve` process with a tile-cache
//! flush.  The headline test prints a `{"bench":"chaos",...}` summary
//! line that `tools/bench_records.sh` collects as `BENCH_chaos.json`.

use std::io::{BufRead, BufReader, Read as _, Write as _};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use litl::config::Partition;
use litl::coordinator::host::{HostAlgo, HostTrainer};
use litl::coordinator::projector::{DigitalProjector, Projector};
use litl::coordinator::service::{
    ClientProjector, FailoverConfig, ShardServiceConfig, SHARD_ERRORS,
};
use litl::coordinator::topology::{DeviceKind, Topology};
use litl::metrics::Registry;
use litl::net::{
    frame, Addr, FaultPlanCfg, Msg, NetOptions, ProjectorServer, RemoteProjector,
    ServerOptions, NET_FAULTS_INJECTED, NET_JOURNAL_REPLAYS, NET_RESUMES,
};
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::Medium;
use litl::optics::OpuParams;

mod common;
use common::{task_batch, ternary_batch};

const D_IN: usize = 10;
const MODES: usize = 32;
const LAYERS: [usize; 4] = [20, 32, 32, 10];
const STEPS: u64 = 8;

/// Client knobs tuned for tests: fast bounded redials so chaos resolves
/// in milliseconds, not the operator-scale defaults.
fn fast_net() -> NetOptions {
    NetOptions {
        connect_timeout_ms: 2_000,
        request_timeout_ms: 10_000,
        reconnect_tries: 3,
        reconnect_base_ms: 5,
        reconnect_max_ms: 20,
        ..NetOptions::default()
    }
}

/// The headline seeded plan: every fault class fires somewhere in an
/// 8-step run (the deterministic `cut_every` guarantees at least the
/// cuts), rates low enough that the bounded resume budget always
/// converges through the bursts.
fn chaos_plan() -> FaultPlanCfg {
    FaultPlanCfg::parse(
        "seed=1337,cut_every=5,cut_ppm=20000,partial_ppm=30000,corrupt_ppm=30000,\
         stall_ppm=20000,stall_ms=2,dev_err_ppm=30000,dev_err_burst=2,\
         dev_stall_ppm=10000,dev_stall_ms=2",
    )
    .unwrap()
}

/// Train `STEPS` steps through the sharded service on `topo`, returning
/// the trainer (for param inspection) and the per-step losses.
fn train_losses(topo: Topology, medium: &Medium, reg: Registry) -> (HostTrainer, Vec<f32>) {
    let partition = topo.partition;
    let svc = topo
        .build_service(
            OpuParams::default(),
            medium,
            7,
            D_IN,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 64,
                lane_depth: 4,
                partition,
                frame_rate_hz: 1500.0,
                ..Default::default()
            },
            reg,
        )
        .unwrap();
    let projector = Box::new(ClientProjector::new(svc.client(), MODES));
    let mut tr = HostTrainer::new(
        11,
        &LAYERS,
        0.01,
        HostAlgo::DfaTernary { theta: 0.1 },
        projector,
    );
    let mut losses = Vec::new();
    for t in 0..STEPS {
        let (x, y) = task_batch(3_000 + t, 16, &LAYERS);
        losses.push(tr.step(&x, &y).unwrap());
    }
    svc.shutdown();
    (tr, losses)
}

/// The tentpole pin: seeded chaos + session resume == fault-free run,
/// bitwise, across shard counts and both partitions.
#[test]
fn faulted_resume_runs_are_bitwise_identical_to_fault_free() {
    let medium = Medium::Dense(TransmissionMatrix::sample(91, D_IN, MODES));
    let plan = chaos_plan();
    let t0 = Instant::now();
    let (mut faults_total, mut resumes_total, mut replays_total) = (0u64, 0u64, 0u64);
    let mut cases = 0u32;
    for n in [1usize, 2, 4] {
        for partition in [Partition::Modes, Partition::Batch] {
            // Fault-free reference: the all-local fleet (never dials).
            let local_topo = Topology::homogeneous(DeviceKind::Optical, n)
                .with_partition(partition)
                .with_backing_of(&medium);
            let (tr_local, losses_local) =
                train_losses(local_topo, &medium, Registry::new());
            // Chaos fleet: the same shards served over TCP with the
            // plan armed on BOTH ends and a resume budget on the client.
            let srv_reg = Registry::new();
            let served: Vec<_> = Topology::homogeneous(DeviceKind::Optical, n)
                .with_partition(partition)
                .with_backing_of(&medium)
                .build_devices(OpuParams::default(), &medium, 7, &Registry::new())
                .unwrap()
                .into_iter()
                .enumerate()
                .map(|(i, d)| (i as u32, d))
                .collect();
            let server = ProjectorServer::bind_with(
                &Addr::parse("tcp:127.0.0.1:0").unwrap(),
                served,
                srv_reg.clone(),
                ServerOptions {
                    journal_cap: 256,
                    faults: Some(plan),
                },
            )
            .unwrap();
            let ep = server.local_addr().to_string();
            let cli_reg = Registry::new();
            let remote_topo = Topology::parse(&format!("opt:{n}!{ep}"))
                .unwrap()
                .with_partition(partition)
                .with_backing_of(&medium)
                .with_net(NetOptions {
                    resume_tries: 8,
                    faults: Some(plan),
                    ..fast_net()
                });
            let (tr_remote, losses_remote) =
                train_losses(remote_topo, &medium, cli_reg.clone());
            let tag = format!("n={n} partition={}", partition.name());
            assert_eq!(losses_local, losses_remote, "{tag}: per-step losses diverged");
            for (i, (a, b)) in
                tr_local.mlp.params.iter().zip(&tr_remote.mlp.params).enumerate()
            {
                assert_eq!(a, b, "{tag}: param {i} diverged under chaos");
            }
            faults_total += cli_reg.counter(NET_FAULTS_INJECTED).get()
                + srv_reg.counter(NET_FAULTS_INJECTED).get();
            resumes_total += cli_reg.counter(NET_RESUMES).get();
            replays_total += srv_reg.counter(NET_JOURNAL_REPLAYS).get();
            cases += 1;
        }
    }
    // A soak that injected nothing proves nothing.
    assert!(faults_total > 0, "the chaos plan never fired — the soak is vacuous");
    assert!(resumes_total > 0, "no redial ever resumed — cuts were never exercised");
    // Summary line for tools/bench_records.sh (BENCH_chaos.json).
    println!(
        "{{\"bench\":\"chaos\",\"cases\":{cases},\"steps\":{STEPS},\
         \"plan\":\"{}\",\"faults_injected\":{faults_total},\
         \"net_resumes\":{resumes_total},\"journal_replays\":{replays_total},\
         \"bitwise_identical\":true,\"wall_s\":{:.2}}}",
        chaos_plan(),
        t0.elapsed().as_secs_f64()
    );
}

/// Resume disabled: the same fault class degrades exactly as PR-9
/// pinned — the in-flight frame errors (never hangs), failover trips
/// the faulted shard, and the survivors carry the run.
#[test]
fn resume_off_degrades_to_failover_with_zero_hangs() {
    let medium = Medium::Dense(TransmissionMatrix::sample(91, D_IN, MODES));
    // Deterministic cut on every 3rd send attempt: the first two frames
    // land, the third dies mid-flight.
    let plan = FaultPlanCfg::parse("seed=7,cut_every=3").unwrap();
    let served: Vec<_> = Topology::parse("opt:1+dig:1")
        .unwrap()
        .with_partition(Partition::Batch)
        .with_backing_of(&medium)
        .build_devices(OpuParams::default(), &medium, 7, &Registry::new())
        .unwrap()
        .into_iter()
        .enumerate()
        .filter(|(i, _)| *i == 0)
        .map(|(i, d)| (i as u32, d))
        .collect();
    let server = ProjectorServer::bind(
        &Addr::parse("tcp:127.0.0.1:0").unwrap(),
        served,
        Registry::new(),
    )
    .unwrap();
    let ep = server.local_addr().to_string();
    let topo = Topology::parse(&format!("opt:1!{ep}+dig:1"))
        .unwrap()
        .with_partition(Partition::Batch)
        .with_backing_of(&medium)
        .with_net(NetOptions {
            resume_tries: 0, // resume OFF: pre-v2 semantics
            faults: Some(plan),
            ..fast_net()
        });
    let reg = Registry::new();
    let svc = topo
        .build_service(
            OpuParams::default(),
            &medium,
            7,
            D_IN,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 64,
                lane_depth: 4,
                partition: Partition::Batch,
                frame_rate_hz: 1500.0,
                failover: FailoverConfig {
                    enabled: true,
                    trip_errors: 1,
                    stall_ms: 5_000,
                    // Long probation: once tripped, the shard stays out
                    // for the whole test — the tail must be all-green
                    // on the digital survivor.
                    probation_ms: 120_000,
                },
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
    let projector = Box::new(ClientProjector::new(svc.client(), MODES));
    let mut tr = HostTrainer::new(
        11,
        &LAYERS,
        0.01,
        HostAlgo::DfaTernary { theta: 0.1 },
        projector,
    );
    let t0 = Instant::now();
    let mut errors = 0u32;
    let mut tail_ok = 0u32;
    for t in 0..20u64 {
        let (x, y) = task_batch(9_000 + t, 16, &LAYERS);
        // Every step RETURNS (Ok or Err) — a hang here is the failure.
        match tr.step(&x, &y) {
            Ok(_) => {
                if t >= 15 {
                    tail_ok += 1;
                }
            }
            Err(_) => errors += 1,
        }
    }
    svc.shutdown();
    assert!(errors >= 1, "the cut plan never errored a step — nothing degraded");
    assert!(errors <= 5, "failover leaked {errors} errors to the client");
    assert_eq!(tail_ok, 5, "post-failover tail still failing on the survivor");
    assert!(
        reg.snapshot()[SHARD_ERRORS] >= 1.0,
        "the injected cut never tripped the shard"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "resume-off degradation must be bounded, not hung"
    );
}

/// A client that insists on resuming against a server with journaling
/// disabled errors deterministically (typed cursor mismatch surfaced
/// through the resume handshake) — never a hang, never a double draw.
#[test]
fn resume_against_a_journal_less_server_errors_deterministically() {
    let served: Vec<(u32, Box<dyn Projector + Send>)> = vec![(
        0,
        Box::new(DigitalProjector::new(TransmissionMatrix::sample(5, D_IN, 16))),
    )];
    let server = ProjectorServer::bind_with(
        &Addr::parse("tcp:127.0.0.1:0").unwrap(),
        served,
        Registry::new(),
        ServerOptions {
            journal_cap: 0, // journaling off server-side
            faults: None,
        },
    )
    .unwrap();
    let mut rp = RemoteProjector::connect(
        server.local_addr(),
        0,
        NetOptions {
            resume_tries: 4,
            // Cut every 2nd send attempt: frame 1 lands, frame 2's
            // attempt is cut and forces a redial + resume.
            faults: Some(FaultPlanCfg::parse("seed=1,cut_every=2").unwrap()),
            ..fast_net()
        },
        &Registry::new(),
    )
    .unwrap();
    let e = ternary_batch(4, D_IN, 3);
    rp.project(&e).unwrap();
    let t0 = Instant::now();
    let err = rp.project(&e).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("rejected resume"),
        "expected a typed resume rejection, got: {msg}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "the rejection path must be bounded"
    );
}

/// Wire-version bump: a v1 peer is answered with a typed protocol
/// error naming the version mismatch, then disconnected — before any
/// payload is trusted.  (The typed client-side `WireError::BadVersion`
/// path is pinned in `net::frame`'s unit tests.)
#[test]
fn v1_clients_are_rejected_by_a_live_server() {
    let served: Vec<(u32, Box<dyn Projector + Send>)> = vec![(
        0,
        Box::new(DigitalProjector::new(TransmissionMatrix::sample(5, D_IN, 16))),
    )];
    let server = ProjectorServer::bind(
        &Addr::parse("tcp:127.0.0.1:0").unwrap(),
        served,
        Registry::new(),
    )
    .unwrap();
    let host = server.local_addr().to_string();
    let host = host.trim_start_matches("tcp:").to_string();
    let mut s = TcpStream::connect(&host).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // A hand-built v1 hello: same magic, version 1, the v1 payload
    // layout (bare shard id), CRC correct for its own bytes.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&frame::MAGIC);
    bytes.extend_from_slice(&1u16.to_le_bytes());
    bytes.extend_from_slice(&frame::OP_HELLO.to_le_bytes());
    let payload = 0u32.to_le_bytes();
    bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    let mut hasher = flate2::Crc::new();
    hasher.update(&bytes[4..]);
    hasher.update(&payload);
    let crc = hasher.sum().to_le_bytes();
    bytes.extend_from_slice(&payload);
    bytes.extend_from_slice(&crc);
    s.write_all(&bytes).unwrap();
    let (reply, _) = frame::recv(&mut s).unwrap();
    match reply {
        Msg::Error { code, message } => {
            assert_eq!(code, frame::ERR_PROTO);
            assert!(
                message.contains("unsupported wire version 1"),
                "rejection must name the version: {message}"
            );
        }
        other => panic!("expected a typed version rejection, got {other:?}"),
    }
    // The server closes the connection after rejecting the framing.
    let mut buf = [0u8; 1];
    assert_eq!(s.read(&mut buf).unwrap_or(0), 0, "connection must be closed");
}

/// Stale-UDS handling at bind: a dead socket file is reclaimed, a live
/// server's socket is refused, and a non-socket file is never unlinked.
#[test]
fn stale_uds_sockets_are_reclaimed_live_and_foreign_paths_refused() {
    let path = std::env::temp_dir().join(format!("litl_chaos_uds_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let addr = Addr::parse(&format!("uds:{}", path.display())).unwrap();
    let mk = || -> Vec<(u32, Box<dyn Projector + Send>)> {
        vec![(
            0,
            Box::new(DigitalProjector::new(TransmissionMatrix::sample(5, D_IN, 16))),
        )]
    };
    // 1) Live server on the path: a second bind refuses loudly and the
    //    incumbent keeps serving.
    let srv = ProjectorServer::bind(&addr, mk(), Registry::new()).unwrap();
    let err = ProjectorServer::bind(&addr, mk(), Registry::new()).unwrap_err();
    assert!(
        format!("{err:#}").contains("live server"),
        "live-socket refusal must say so: {err:#}"
    );
    let mut rp = RemoteProjector::connect(&addr, 0, fast_net(), &Registry::new()).unwrap();
    rp.project(&ternary_batch(2, D_IN, 5)).unwrap();
    drop(rp);
    drop(srv); // graceful shutdown unlinks the path
    // 2) A dead socket (bind leftover of a killed process): reclaimed.
    {
        let _leftover = std::os::unix::net::UnixListener::bind(&path).unwrap();
        // dropping the listener leaves the socket inode behind
    }
    assert!(path.exists(), "dead socket file should linger for this test");
    let srv = ProjectorServer::bind(&addr, mk(), Registry::new()).unwrap();
    drop(srv);
    // 3) A regular file on the path: typed refusal, file untouched.
    std::fs::write(&path, b"precious").unwrap();
    let err = ProjectorServer::bind(&addr, mk(), Registry::new()).unwrap_err();
    assert!(
        format!("{err:#}").contains("not a socket"),
        "non-socket refusal must say so: {err:#}"
    );
    assert_eq!(
        std::fs::read(&path).unwrap(),
        b"precious",
        "bind must never unlink a non-socket file"
    );
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// Multi-process smoke (CI `chaos-smoke` job: `cargo test -- --ignored chaos_smoke`)

/// A spawned `litl serve` child.  Killed (not just dropped) on scope
/// exit so a failing assert never leaks listeners.
struct ServeProc {
    child: Child,
}

impl ServeProc {
    fn spawn(args: &[&str]) -> (ServeProc, String) {
        let mut child = Command::new(env!("CARGO_BIN_EXE_litl"))
            .arg("serve")
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn litl serve");
        let out = child.stdout.take().unwrap();
        let mut lines = BufReader::new(out).lines();
        let ep = loop {
            match lines.next() {
                Some(Ok(l)) => {
                    if let Some(rest) = l.strip_prefix("litl-serve listening on ") {
                        break rest.trim().to_string();
                    }
                }
                other => panic!("serve child exited before its sentinel: {other:?}"),
            }
        };
        (ServeProc { child }, ep)
    }
}

impl Drop for ServeProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

#[test]
#[ignore = "multi-process: run via the CI chaos-smoke job (--ignored chaos_smoke)"]
fn chaos_smoke_graceful_sigterm_drains_and_flushes_tile_cache() {
    let snap = std::env::temp_dir().join(format!(
        "litl_chaos_sigterm_{}.tiles",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&snap);
    let snap_s = snap.to_str().unwrap().to_string();
    let (mut proc_, ep) = ServeProc::spawn(&[
        "--listen", "tcp:127.0.0.1:0", "--topology", "opt:1", "--medium",
        "streamed", "--d-in", "10", "--modes", "64", "--train-seed", "42",
        "--tile-cache-mb", "4", "--tile-cache-save", &snap_s,
    ]);
    // Warm the server's tile cache with a real projection.
    let addr = Addr::parse(&ep).unwrap();
    let mut rp = RemoteProjector::connect(&addr, 0, fast_net(), &Registry::new()).unwrap();
    rp.project(&ternary_batch(4, D_IN, 3)).unwrap();
    drop(rp);
    // SIGTERM → the server stops accepting, drains, flushes the
    // snapshot, and exits 0 (abrupt kill would exit nonzero and skip
    // the flush).
    let status = Command::new("kill")
        .arg(proc_.child.id().to_string())
        .status()
        .expect("send SIGTERM");
    assert!(status.success(), "kill(1) failed");
    let t0 = Instant::now();
    let exit = loop {
        match proc_.child.try_wait().expect("wait on serve child") {
            Some(st) => break st,
            None => {
                assert!(
                    t0.elapsed() < Duration::from_secs(30),
                    "serve child did not exit after SIGTERM"
                );
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    assert!(exit.success(), "graceful shutdown must exit 0, got {exit:?}");
    let meta = std::fs::metadata(&snap).expect("tile-cache snapshot must exist");
    assert!(meta.len() > 0, "tile-cache snapshot must be non-empty");
    let _ = std::fs::remove_file(&snap);
}
