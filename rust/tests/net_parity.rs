//! Networked projector servers: the standing contract is that a
//! loopback remote shard is **bitwise identical** to the same shard
//! in-process — noisy optics included — because both ends build their
//! devices through the one `Topology::build_devices` path.
//!
//! In-process tests here cover TCP + UDS parity across shard counts and
//! both partitions, streamed+cached backing, a mixed local+remote fleet
//! training through the sharded service, wire robustness against
//! garbage, dead-server error completion (no hangs), and bitwise
//! kill-and-resume through the host trainer checkpoint.  The
//! `#[ignore]`d `net_smoke_*` tests spawn real `litl serve` child
//! processes and run under CI's `net-smoke` job.

use std::io::{BufRead, BufReader, Read as _, Write as _};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use litl::config::Partition;
use litl::coordinator::host::{HostAlgo, HostTrainer};
use litl::coordinator::projector::{DigitalProjector, Projector};
use litl::coordinator::service::{
    ClientProjector, FailoverConfig, ShardServiceConfig, SHARD_ERRORS,
};
use litl::coordinator::topology::{DeviceKind, Topology};
use litl::metrics::Registry;
use litl::net::{
    frame, Addr, NetOptions, ProjectorServer, RemoteProjector, NET_FRAMES_RX,
    NET_FRAMES_TX, NET_RECONNECTS, NET_RTT,
};
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::{Medium, StreamedMedium};
use litl::optics::OpuParams;
use litl::tensor::matmul;

mod common;
use common::{task_batch, ternary_batch};

const D_IN: usize = 10;

/// Client knobs tuned for tests: fast bounded redials so failure paths
/// resolve in milliseconds, not the operator-scale defaults.
fn fast_net() -> NetOptions {
    NetOptions {
        connect_timeout_ms: 2_000,
        request_timeout_ms: 10_000,
        reconnect_tries: 2,
        reconnect_base_ms: 10,
        reconnect_max_ms: 50,
        ..NetOptions::default()
    }
}

/// Serve `opt:n` over `addr` and check every remote shard answers
/// bitwise what its freshly built in-process twin answers — three
/// requests deep, so the per-shard noise streams advance in lockstep.
/// Returns the remote client's metrics registry for telemetry asserts.
fn parity_case(n: usize, partition: Partition, addr: &Addr, medium: &Medium) -> Registry {
    // Noisy optics stay ON: parity must hold through shot + read noise,
    // not just the deterministic physics.
    let params = OpuParams::default();
    let topo = Topology::homogeneous(DeviceKind::Optical, n)
        .with_partition(partition)
        .with_backing_of(medium);
    let mut local = topo
        .build_devices(params, medium, 7, &Registry::new())
        .unwrap();
    let served: Vec<_> = topo
        .build_devices(params, medium, 7, &Registry::new())
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, d)| (i as u32, d))
        .collect();
    let server = ProjectorServer::bind(addr, served, Registry::new()).unwrap();
    let ep = server.local_addr().to_string();
    let net_reg = Registry::new();
    let mut remote = Topology::parse(&format!("opt:{n}!{ep}"))
        .unwrap()
        .with_partition(partition)
        .with_backing_of(medium)
        .with_net(fast_net())
        .build_devices(params, medium, 7, &net_reg)
        .unwrap();
    assert_eq!(remote.len(), n);
    assert_eq!(remote[0].kind(), "remote");
    for step in 0..3u64 {
        for s in 0..n {
            let e = ternary_batch(4 + s, D_IN, 500 + 10 * step + s as u64);
            let (lp1, lp2) = local[s].project(&e).unwrap();
            let (rp1, rp2) = remote[s].project(&e).unwrap();
            let tag = format!("{} n={n} shard {s} step {step}", partition.name());
            assert_eq!(lp1, rp1, "{tag} p1");
            assert_eq!(lp2, rp2, "{tag} p2");
            assert_eq!(local[s].sim_seconds(), remote[s].sim_seconds(), "{tag} clock");
        }
    }
    net_reg
}

#[test]
fn tcp_loopback_remote_shards_are_bitwise_in_process() {
    let medium = Medium::Dense(TransmissionMatrix::sample(91, D_IN, 64));
    let addr = Addr::parse("tcp:127.0.0.1:0").unwrap();
    for n in [1usize, 2, 4] {
        for partition in [Partition::Modes, Partition::Batch] {
            let reg = parity_case(n, partition, &addr, &medium);
            // Telemetry contract: one hello + three projects per shard
            // client, a round trip observed per project, no redials.
            assert_eq!(reg.counter(NET_FRAMES_TX).get(), 4 * n as u64);
            assert_eq!(reg.counter(NET_FRAMES_RX).get(), 4 * n as u64);
            assert_eq!(reg.histogram(NET_RTT).count(), 3 * n as u64);
            assert_eq!(reg.counter(NET_RECONNECTS).get(), 0);
        }
    }
}

#[test]
fn uds_loopback_remote_shards_are_bitwise_in_process() {
    let medium = Medium::Dense(TransmissionMatrix::sample(91, D_IN, 64));
    for n in [1usize, 2, 4] {
        for partition in [Partition::Modes, Partition::Batch] {
            let path = std::env::temp_dir().join(format!(
                "litl_np_{}_{n}_{}.sock",
                std::process::id(),
                partition.name()
            ));
            let _ = std::fs::remove_file(&path);
            let addr = Addr::parse(&format!("uds:{}", path.display())).unwrap();
            parity_case(n, partition, &addr, &medium);
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[test]
fn streamed_cached_medium_keeps_remote_parity() {
    // The seed-defined backing with a shared tile cache: the server
    // regenerates (or hits) the same tiles the in-process twin does.
    let medium =
        Medium::Streamed(StreamedMedium::new(33, D_IN, 96).with_tile_cache_mb(2));
    let addr = Addr::parse("tcp:127.0.0.1:0").unwrap();
    parity_case(2, Partition::Modes, &addr, &medium);
    parity_case(2, Partition::Batch, &addr, &medium);
}

/// Train through the sharded service with `topo`, returning the trainer
/// and the per-step losses.
fn train_through_service(
    topo: Topology,
    medium: &Medium,
    noise_seed: u64,
    layers: &[usize],
    modes: usize,
    steps: u64,
) -> (HostTrainer, Vec<f32>) {
    let svc = topo
        .build_service(
            OpuParams::default(),
            medium,
            noise_seed,
            D_IN,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 64,
                lane_depth: 4,
                partition: topo.partition,
                frame_rate_hz: 1500.0,
                ..Default::default()
            },
            Registry::new(),
        )
        .unwrap();
    let projector = Box::new(ClientProjector::new(svc.client(), modes));
    let mut tr = HostTrainer::new(
        11,
        layers,
        0.01,
        HostAlgo::DfaTernary { theta: 0.1 },
        projector,
    );
    let mut losses = Vec::new();
    for t in 0..steps {
        let (x, y) = task_batch(3_000 + t, 16, layers);
        losses.push(tr.step(&x, &y).unwrap());
    }
    svc.shutdown();
    (tr, losses)
}

#[test]
fn mixed_local_and_remote_fleet_matches_the_all_local_fleet_bitwise() {
    let modes = 48usize;
    let layers = [20usize, 48, 48, 10];
    let medium = Medium::Dense(TransmissionMatrix::sample(91, D_IN, modes));
    // The server hosts shard 1 of the stripped topology — exactly what
    // `litl serve --serve-shards 1` does for this fleet.
    let stripped = Topology::parse("opt:1+opt:1+dig:1")
        .unwrap()
        .with_backing_of(&medium);
    let served: Vec<_> = stripped
        .build_devices(OpuParams::default(), &medium, 7, &Registry::new())
        .unwrap()
        .into_iter()
        .enumerate()
        .filter(|(i, _)| *i == 1)
        .map(|(i, d)| (i as u32, d))
        .collect();
    let server = ProjectorServer::bind(
        &Addr::parse("tcp:127.0.0.1:0").unwrap(),
        served,
        Registry::new(),
    )
    .unwrap();
    let ep = server.local_addr().to_string();
    // All-local run first (it never dials), then the mixed fleet, so
    // the served device's noise stream starts fresh for its one run.
    let (tr_local, losses_local) =
        train_through_service(stripped, &medium, 7, &layers, modes, 25);
    let mixed = Topology::parse(&format!("opt:1+opt:1!{ep}+dig:1"))
        .unwrap()
        .with_backing_of(&medium)
        .with_net(fast_net());
    let (tr_remote, losses_remote) =
        train_through_service(mixed, &medium, 7, &layers, modes, 25);
    assert_eq!(losses_local, losses_remote, "per-step losses diverged");
    for (i, (a, b)) in
        tr_local.mlp.params.iter().zip(&tr_remote.mlp.params).enumerate()
    {
        assert_eq!(a, b, "param {i} diverged between local and mixed fleets");
    }
}

#[test]
fn server_survives_garbage_and_keeps_serving_bitwise() {
    let medium = Medium::Dense(TransmissionMatrix::sample(5, D_IN, 16));
    let served: Vec<_> = Topology::homogeneous(DeviceKind::Digital, 1)
        .with_backing_of(&medium)
        .build_devices(OpuParams::default(), &medium, 0, &Registry::new())
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, d)| (i as u32, d))
        .collect();
    let server = ProjectorServer::bind(
        &Addr::parse("tcp:127.0.0.1:0").unwrap(),
        served,
        Registry::new(),
    )
    .unwrap();
    let addr = server.local_addr().clone();
    let host = addr.to_string();
    let host = host.trim_start_matches("tcp:").to_string();
    // 1) Not our protocol at all.
    {
        let mut s = TcpStream::connect(&host).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink); // server errors/closes, never panics
    }
    // 2) Right magic and version, hostile declared length.
    {
        let mut s = TcpStream::connect(&host).unwrap();
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&frame::MAGIC);
        hdr.extend_from_slice(&frame::VERSION.to_le_bytes());
        hdr.extend_from_slice(&frame::OP_PROJECT.to_le_bytes());
        hdr.extend_from_slice(&u32::MAX.to_le_bytes());
        s.write_all(&hdr).unwrap();
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    // 3) Wrong version.
    {
        let mut s = TcpStream::connect(&host).unwrap();
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&frame::MAGIC);
        hdr.extend_from_slice(&(frame::VERSION + 1).to_le_bytes());
        hdr.extend_from_slice(&frame::OP_HELLO.to_le_bytes());
        hdr.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&hdr).unwrap();
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    // A legitimate client still gets exact service afterwards.
    let mut rp =
        RemoteProjector::connect(&addr, 0, fast_net(), &Registry::new()).unwrap();
    let e = ternary_batch(4, D_IN, 3);
    let (p1, p2) = rp.project(&e).unwrap();
    let tm = TransmissionMatrix::sample(5, D_IN, 16);
    assert_eq!(p1, matmul(&e, &tm.b_re));
    assert_eq!(p2, matmul(&e, &tm.b_im));
}

#[test]
fn dead_server_errors_in_flight_requests_without_hanging() {
    use litl::net::Msg;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    let fake = std::thread::spawn(move || {
        // Greet one client, swallow its first request, then vanish —
        // connection and listener both die with this thread.
        let (mut s, _) = listener.accept().unwrap();
        let (msg, _) = frame::recv(&mut s).unwrap();
        match msg {
            Msg::Hello { shard, session } => {
                assert_eq!(shard, 0);
                assert_eq!(session, 0, "resume off greets with session 0");
            }
            other => panic!("expected hello, got {other:?}"),
        }
        frame::send(
            &mut s,
            &Msg::HelloOk {
                modes: 16,
                requires_ternary: true,
                kind: "optical".to_string(),
            },
        )
        .unwrap();
        let _ = frame::recv(&mut s);
    });
    let addr = Addr::parse(&format!("tcp:127.0.0.1:{port}")).unwrap();
    let reg = Registry::new();
    let mut rp = RemoteProjector::connect(&addr, 0, fast_net(), &reg).unwrap();
    assert_eq!(rp.modes(), 16);
    let e = ternary_batch(4, D_IN, 1);
    let t0 = Instant::now();
    // The in-flight frame completes with an ERROR — never resent, never
    // hung — which is exactly what lets service failover trip the shard.
    assert!(rp.project(&e).is_err(), "dead server must fail the in-flight frame");
    fake.join().unwrap();
    // The next request redials with bounded backoff against a dead
    // address and errors too, quickly.
    assert!(rp.project(&e).is_err());
    assert!(reg.counter(NET_RECONNECTS).get() >= 1, "redial was attempted");
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "failure path must be bounded, not hung"
    );
}

#[test]
fn host_trainer_kill_and_resume_is_bitwise_uninterrupted() {
    let layers = [20usize, 16, 16, 10];
    let digital = || -> Box<dyn Projector> {
        Box::new(DigitalProjector::new(TransmissionMatrix::sample(99, D_IN, 16)))
    };
    let fresh = |seed: u64| {
        HostTrainer::new(seed, &layers, 0.01, HostAlgo::DfaTernary { theta: 0.1 }, digital())
    };
    // The uninterrupted reference: 20 straight steps.
    let mut full = fresh(0);
    for t in 0..20 {
        let (x, y) = task_batch(700 + t, 32, &layers);
        full.step(&x, &y).unwrap();
    }
    // The "killed" twin: 10 steps, checkpoint, then a brand-new process
    // stand-in (different init seed, so the restore must carry
    // everything) resumes for the remaining 10.
    let path = std::env::temp_dir().join(format!(
        "litl_resume_{}.ckpt",
        std::process::id()
    ));
    let path = path.to_str().unwrap().to_string();
    let mut first_half = fresh(0);
    for t in 0..10 {
        let (x, y) = task_batch(700 + t, 32, &layers);
        first_half.step(&x, &y).unwrap();
    }
    first_half.save_state(&path).unwrap();
    let mut resumed = fresh(12345);
    resumed.load_state(&path).unwrap();
    assert_eq!(resumed.opt.t, first_half.opt.t);
    for t in 10..20 {
        let (x, y) = task_batch(700 + t, 32, &layers);
        resumed.step(&x, &y).unwrap();
    }
    for (i, (a, b)) in full.mlp.params.iter().zip(&resumed.mlp.params).enumerate() {
        assert_eq!(a, b, "param {i}: resumed run diverged from uninterrupted");
    }
    for (a, b) in full.opt.m.iter().zip(&resumed.opt.m) {
        assert_eq!(a, b, "adam m diverged");
    }
    for (a, b) in full.opt.v.iter().zip(&resumed.opt.v) {
        assert_eq!(a, b, "adam v diverged");
    }
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// Multi-process smoke (CI `net-smoke` job: `cargo test -- --ignored net_smoke`)

/// A spawned `litl serve` child.  Killed (not just dropped) on scope
/// exit so a failing assert never leaks listeners.
struct ServeProc {
    child: Child,
}

impl ServeProc {
    /// Spawn `litl serve <args>` and block until it prints its
    /// `litl-serve listening on ADDR` sentinel; returns the bound ADDR.
    fn spawn(args: &[&str]) -> (ServeProc, String) {
        let mut child = Command::new(env!("CARGO_BIN_EXE_litl"))
            .arg("serve")
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn litl serve");
        let out = child.stdout.take().unwrap();
        let mut lines = BufReader::new(out).lines();
        let ep = loop {
            match lines.next() {
                Some(Ok(l)) => {
                    if let Some(rest) = l.strip_prefix("litl-serve listening on ") {
                        break rest.trim().to_string();
                    }
                }
                other => panic!("serve child exited before its sentinel: {other:?}"),
            }
        };
        (ServeProc { child }, ep)
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ServeProc {
    fn drop(&mut self) {
        self.kill();
    }
}

const TRAIN_SEED: u64 = 42;

#[test]
#[ignore = "multi-process: run via the CI net-smoke job (--ignored net_smoke)"]
fn net_smoke_multiprocess_training_parity() {
    let modes = 48usize;
    let layers = [20usize, 48, 48, 10];
    // The leader derives its medium/noise seeds exactly as `Trainer`
    // does from `--seed`; the children derive the same from
    // `--train-seed` — that agreement IS the cutover contract.
    let medium =
        Medium::Dense(TransmissionMatrix::sample(TRAIN_SEED ^ 0xB, D_IN, modes));
    let noise_seed = TRAIN_SEED ^ 0xF00;
    let base = [
        "--listen", "tcp:127.0.0.1:0", "--topology", "opt:2", "--partition",
        "modes", "--medium", "materialized", "--d-in", "10", "--modes", "48",
        "--train-seed", "42",
    ];
    let (_a, ep_a) = ServeProc::spawn(&[&base[..], &["--serve-shards", "0"]].concat());
    let (_b, ep_b) = ServeProc::spawn(&[&base[..], &["--serve-shards", "1"]].concat());
    let (tr_local, losses_local) = train_through_service(
        Topology::parse("opt:2").unwrap().with_backing_of(&medium),
        &medium,
        noise_seed,
        &layers,
        modes,
        25,
    );
    let remote_topo = Topology::parse(&format!("opt:1!{ep_a}+opt:1!{ep_b}"))
        .unwrap()
        .with_backing_of(&medium)
        .with_net(fast_net());
    let (tr_remote, losses_remote) =
        train_through_service(remote_topo, &medium, noise_seed, &layers, modes, 25);
    assert_eq!(
        losses_local, losses_remote,
        "multi-process fleet diverged from in-process"
    );
    for (i, (a, b)) in
        tr_local.mlp.params.iter().zip(&tr_remote.mlp.params).enumerate()
    {
        assert_eq!(a, b, "param {i} diverged across the process boundary");
    }
}

#[test]
#[ignore = "multi-process: run via the CI net-smoke job (--ignored net_smoke)"]
fn net_smoke_server_kill_failover_drains_to_survivors() {
    let modes = 48usize;
    let layers = [20usize, 48, 48, 10];
    let medium =
        Medium::Dense(TransmissionMatrix::sample(TRAIN_SEED ^ 0xB, D_IN, modes));
    let (mut victim, ep) = ServeProc::spawn(&[
        "--listen", "tcp:127.0.0.1:0", "--topology", "opt:1+dig:1",
        "--partition", "batch", "--medium", "materialized", "--d-in", "10",
        "--modes", "48", "--train-seed", "42", "--serve-shards", "0",
    ]);
    let topo = Topology::parse(&format!("opt:1!{ep}+dig:1"))
        .unwrap()
        .with_partition(Partition::Batch)
        .with_backing_of(&medium)
        .with_net(NetOptions {
            connect_timeout_ms: 500,
            request_timeout_ms: 2_000,
            reconnect_tries: 1,
            reconnect_base_ms: 10,
            reconnect_max_ms: 20,
            ..NetOptions::default()
        });
    let reg = Registry::new();
    let svc = topo
        .build_service(
            OpuParams::default(),
            &medium,
            TRAIN_SEED ^ 0xF00,
            D_IN,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 64,
                lane_depth: 4,
                partition: Partition::Batch,
                frame_rate_hz: 1500.0,
                failover: FailoverConfig {
                    enabled: true,
                    trip_errors: 1,
                    stall_ms: 2_000,
                    probation_ms: 500,
                },
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
    let projector = Box::new(ClientProjector::new(svc.client(), modes));
    let mut tr = HostTrainer::new(
        11,
        &layers,
        0.01,
        HostAlgo::DfaTernary { theta: 0.1 },
        projector,
    );
    // Ten healthy steps, kill the remote's process mid-run, then keep
    // training: the tripped shard's rows drain onto the digital
    // survivor.  A few client-visible errors are tolerated around the
    // kill; hangs are not (every step returns, Ok or Err).
    let mut errors = 0u32;
    let mut tail_ok = 0u32;
    for t in 0..40u64 {
        if t == 10 {
            victim.kill();
        }
        let (x, y) = task_batch(9_000 + t, 16, &layers);
        match tr.step(&x, &y) {
            Ok(_) => {
                if t >= 30 {
                    tail_ok += 1;
                }
            }
            Err(e) => {
                assert!(t >= 10, "pre-kill step {t} failed: {e:#}");
                errors += 1;
            }
        }
    }
    svc.shutdown();
    assert!(errors <= 5, "failover leaked {errors} errors to the client");
    assert!(tail_ok >= 9, "post-failover steps still failing ({tail_ok}/10 ok)");
    assert!(
        reg.snapshot()[SHARD_ERRORS] >= 1.0,
        "the kill never tripped the shard"
    );
}
