//! Frame-level tracing contract (ISSUE 8 acceptance):
//!
//! * **Balance** — a traced sharded run under either partition drains
//!   to a balanced span set (every begin matched by an end), with a
//!   per-frame stage breakdown whose critical-path sum stays within
//!   the frame's end-to-end `request` latency.
//! * **Bounded rings** — overflowing a per-thread ring drops newest
//!   events and counts them; the drain stays clean (no corruption, no
//!   panic), it never invents spans.
//! * **Off is off** — an `Off` session records nothing.
//! * **Export smoke** (`--ignored`, dedicated CI step) — a short
//!   heterogeneous training run over a streamed+cached medium produces
//!   a loadable Chrome trace and a Prometheus dump with the generation
//!   profiling histograms populated.
//!
//! The tracer is process-global (one session at a time), so every test
//! here serializes on `SESSION_LOCK` — same discipline as the unit
//! tests in `metrics::trace`.

use std::sync::Mutex;

use litl::config::Partition;
use litl::coordinator::host::{HostAlgo, HostTrainer};
use litl::coordinator::service::{
    ClientProjector, ShardServiceConfig, ShardedProjectionService,
};
use litl::coordinator::topology::{DeviceKind, Topology};
use litl::metrics::export::{chrome_trace_json, write_chrome_trace, write_prometheus};
use litl::metrics::trace::{self, TraceClock, TraceLevel, TraceSession};
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::{Medium, StreamedMedium};
use litl::optics::OpuParams;

mod common;
use common::{task_batch, ternary_batch, topology_devices};

const D_IN: usize = 10;

/// One session at a time: serialize every test that installs one.
static SESSION_LOCK: Mutex<()> = Mutex::new(());

fn lock_session() -> std::sync::MutexGuard<'static, ()> {
    SESSION_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn sharded_service(
    medium: &TransmissionMatrix,
    shards: usize,
    partition: Partition,
) -> ShardedProjectionService {
    let devices = topology_devices(
        DeviceKind::Digital,
        OpuParams::default(),
        &Medium::Dense(medium.clone()),
        0,
        shards,
        partition,
    )
    .unwrap();
    ShardedProjectionService::start(
        devices,
        D_IN,
        ShardServiceConfig {
            max_batch: 16,
            queue_depth: 64,
            lane_depth: 4,
            partition,
            ..Default::default()
        },
        Registry::new(),
    )
    .unwrap()
}

/// A full-level traced run through a 3-shard digital service, both
/// partitions: the drained span set balances, nothing is dropped, and
/// every frame's attributed stage sum fits inside its `request` span.
#[test]
fn sharded_spans_balance_and_breakdown_fits_e2e() {
    let _guard = lock_session();
    let medium = TransmissionMatrix::sample(61, D_IN, 28);
    for partition in [Partition::Modes, Partition::Batch] {
        let session = TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 1 << 16);
        let svc = sharded_service(&medium, 3, partition);
        let client = svc.client();
        let sizes: &[usize] = &[1, 3, 2, 5, 8, 1, 4, 7, 2, 6];
        let replies: Vec<_> = sizes
            .iter()
            .enumerate()
            .map(|(i, &b)| client.submit(ternary_batch(b, D_IN, 300 + i as u64)).unwrap())
            .collect();
        for reply in replies {
            reply.wait().unwrap().unwrap();
        }
        svc.shutdown();
        let report = session.finish();

        assert!(
            report.is_balanced(),
            "{partition:?}: {} unmatched begins, {} unmatched ends",
            report.unmatched_begins,
            report.unmatched_ends
        );
        assert_eq!(report.dropped, 0, "{partition:?}: ring overflowed");
        assert!(!report.spans.is_empty(), "{partition:?}: no spans recorded");

        let breakdown = report.frame_breakdown();
        // Every request got its own trace frame with an e2e span.
        let with_e2e = breakdown.values().filter(|b| b.e2e_ns.is_some()).count();
        assert_eq!(with_e2e, sizes.len(), "{partition:?}: request spans");
        for (frame, b) in &breakdown {
            let Some(e2e) = b.e2e_ns else {
                panic!("{partition:?}: frame {frame} has stages but no request span");
            };
            assert!(
                b.stage_sum_ns() <= e2e,
                "{partition:?}: frame {frame} stage sum {} > e2e {e2e}",
                b.stage_sum_ns()
            );
        }
        // The pipeline stages actually show up: at least one frame
        // carried the scheduled work (coalescing may fold several
        // requests into one scheduled frame, attributed to its first).
        assert!(
            breakdown.values().any(|b| {
                b.stages.contains_key(trace::STAGE_PROJECT)
                    && b.stages.contains_key(trace::STAGE_GATHER)
                    && b.stages.contains_key(trace::STAGE_SCHEDULE)
            }),
            "{partition:?}: no frame carries schedule/project/gather stages"
        );
    }
}

/// Overflowing one thread's ring: newest events drop (and are counted),
/// the surviving prefix still pairs up, and the drain never fabricates
/// spans for dropped events.
#[test]
fn ring_overflow_drops_newest_and_drains_clean() {
    let _guard = lock_session();
    let session = TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 16);
    for frame in 0..100u64 {
        trace::begin(trace::STAGE_PROJECT, frame, 0);
        trace::end(trace::STAGE_PROJECT, frame, 0);
    }
    let report = session.finish();
    // 200 events offered, ring keeps the oldest 16 = 8 begin/end pairs.
    assert_eq!(report.dropped, 184);
    assert_eq!(report.spans.len(), 8);
    assert!(report.is_balanced(), "kept prefix is whole pairs");
    assert!(report.spans.iter().all(|s| s.frame < 8), "kept oldest, not newest");
}

/// An `Off` session records no events and allocates no buffers — the
/// disabled path a production run takes by default.
#[test]
fn off_session_records_nothing() {
    let _guard = lock_session();
    let session = TraceSession::begin(TraceLevel::Off, TraceClock::wall(), 1 << 16);
    assert!(!trace::enabled());
    assert!(!trace::recording());
    let medium = TransmissionMatrix::sample(62, D_IN, 24);
    let svc = sharded_service(&medium, 2, Partition::Modes);
    let client = svc.client();
    for i in 0..4u64 {
        client.project(ternary_batch(3, D_IN, 500 + i)).unwrap();
    }
    svc.shutdown();
    let report = session.finish();
    assert!(report.spans.is_empty());
    assert_eq!(report.threads, 0, "no thread ever registered a buffer");
    assert_eq!(report.dropped, 0);
}

/// The CI `trace-smoke` scenario: a heterogeneous weighted topology
/// (2 optical @ weight 2 + 1 digital) over a streamed, tile-cached,
/// metric-bound medium trains a host DFA model under `--trace full`,
/// then exports the Chrome trace and the Prometheus dump.  The CI job
/// validates the artifacts with jq / a text parser; this test pins the
/// semantic half (balance, histogram population, non-empty exports).
#[test]
#[ignore = "trace smoke: run with --ignored (dedicated CI step)"]
fn trace_smoke_export() {
    let _guard = lock_session();
    let trace_out = std::env::var("TRACE_SMOKE_TRACE_OUT")
        .unwrap_or_else(|_| "target/trace_smoke/trace.json".to_string());
    let metrics_out = std::env::var("TRACE_SMOKE_METRICS_OUT")
        .unwrap_or_else(|_| "target/trace_smoke/metrics.prom".to_string());

    let modes = 64usize;
    let layers = [20usize, modes, modes, 10];
    let reg = Registry::new();
    let medium = Medium::Streamed(
        StreamedMedium::new(91, D_IN, modes)
            .with_metrics(&reg)
            .with_tile_cache_mb(8),
    );
    let topo = Topology::parse("hetero:opt:2@2+dig:1").unwrap().with_backing_of(&medium);
    let svc = topo
        .build_service(
            OpuParams::default(),
            &medium,
            7,
            D_IN,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 64,
                lane_depth: 4,
                partition: Partition::Modes,
                frame_rate_hz: 1500.0,
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();

    let session = TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 1 << 18);
    let projector = Box::new(ClientProjector::new(svc.client(), modes));
    let mut tr = HostTrainer::new(
        11,
        &layers,
        0.01,
        HostAlgo::DfaTernary { theta: 0.1 },
        projector,
    );
    let batch = 16usize;
    for t in 0..40u64 {
        let (x, y) = task_batch(3_000 + t, batch, &layers);
        tr.step(&x, &y).unwrap();
    }
    svc.shutdown();
    let report = session.finish();

    assert!(report.is_balanced(), "smoke spans unbalanced");
    assert!(!report.spans.is_empty());
    let json = chrome_trace_json(&report);
    assert!(json.contains("\"traceEvents\""));
    write_chrome_trace(&trace_out, &report).unwrap();
    write_prometheus(&metrics_out, &reg).unwrap();

    let prom = std::fs::read_to_string(&metrics_out).unwrap();
    // The generation profiling hooks fed the histograms (cache hits
    // need repeated steps over the same tiles — 40 steps is plenty).
    assert!(prom.contains("# TYPE stream_gen_ns histogram"), "gen histogram missing");
    assert!(
        prom.contains("# TYPE stream_cache_hit_ns histogram"),
        "cache-hit histogram missing"
    );
    assert!(!std::fs::read_to_string(&trace_out).unwrap().is_empty());
    eprintln!(
        "trace-smoke: {} spans / {} threads -> {trace_out}, metrics -> {metrics_out}",
        report.spans.len(),
        report.threads
    );
}
