//! Integration: the full three-layer stack on the `small` build config.
//!
//! Loads real AOT artifacts (requires `make artifacts`), runs every
//! trainer a few steps on synthetic digits, and cross-checks the XLA
//! path against the pure-rust host oracle.

use litl::config::{Algo, ProjectorKind, TrainConfig};
use litl::coordinator::host::{HostAlgo, HostTrainer};
use litl::coordinator::projector::DigitalProjector;
use litl::coordinator::Trainer;
use litl::data::{self, Split};
use litl::optics::medium::TransmissionMatrix;
use litl::runtime::Engine;
use litl::tensor::Tensor;
use litl::util::rng::Pcg64;

mod common;
use common::artifacts_available;

fn cfg(algo: Algo) -> TrainConfig {
    TrainConfig {
        artifact_config: "small".into(),
        algo,
        projector: ProjectorKind::OpticalNative,
        epochs: 1,
        train_size: 640,
        test_size: 200,
        lr: 0.01,
        theta: 0.1,
        seed: 7,
        artifacts_dir: "artifacts".into(),
        out_dir: None,
        eval_every: 0,
        n_ph: None,
        read_sigma: None,
        account_frames: true,
        shards: 1,
        partition: litl::config::Partition::Modes,
        medium: litl::config::MediumBacking::Materialized,
        ..TrainConfig::default()
    }
}

fn loss_drops(algo: Algo, lr: f32, steps: usize) -> (f32, f32) {
    let mut c = cfg(algo);
    c.lr = lr;
    let ds = data::load_or_synth(c.seed, c.train_size, c.test_size).unwrap();
    let mut tr = Trainer::new(c).unwrap();
    tr.warmup().unwrap();
    let mut rng = Pcg64::seeded(1);
    let batch = tr.model().batch;
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    let mut done = 0;
    'outer: loop {
        for (x, y) in ds.batches(Split::Train, batch, &mut rng) {
            let loss = tr.train_step(&x, &y).unwrap();
            if done == 0 {
                first = loss;
            }
            last = loss;
            done += 1;
            if done >= steps {
                break 'outer;
            }
        }
    }
    (first, last)
}

#[test]
fn bp_loss_decreases() {
    if !artifacts_available() {
        return;
    }
    let (first, last) = loss_drops(Algo::Bp, 0.01, 40);
    assert!(last < 0.6 * first, "bp: first={first} last={last}");
}

#[test]
fn dfa_float_loss_decreases() {
    if !artifacts_available() {
        return;
    }
    let (first, last) = loss_drops(Algo::DfaFloat, 0.01, 40);
    assert!(last < 0.7 * first, "dfa-float: first={first} last={last}");
}

#[test]
fn dfa_ternary_loss_decreases() {
    if !artifacts_available() {
        return;
    }
    // Ternary feedback is slow in the first steps (most wrong-class
    // errors quantize to zero), so give it a longer horizon.
    let (first, last) = loss_drops(Algo::DfaTernary, 0.001, 420);
    assert!(last < 0.85 * first, "dfa-ternary: first={first} last={last}");
}

#[test]
fn optical_loss_decreases() {
    if !artifacts_available() {
        return;
    }
    let (first, last) = loss_drops(Algo::Optical, 0.001, 420);
    assert!(last < 0.85 * first, "optical: first={first} last={last}");
}

#[test]
fn optical_accounts_device_time() {
    if !artifacts_available() {
        return;
    }
    let c = cfg(Algo::Optical);
    let ds = data::load_or_synth(c.seed, 128, 200).unwrap();
    let mut tr = Trainer::new(c).unwrap();
    tr.warmup().unwrap();
    let mut rng = Pcg64::seeded(2);
    let batch = tr.model().batch;
    let (x, y) = ds.batches(Split::Train, batch, &mut rng).next().unwrap();
    tr.train_step(&x, &y).unwrap();
    // one step = `batch` camera frames at 1.5 kHz
    let expect = batch as f64 / 1500.0;
    assert!((tr.sim_device_seconds() - expect).abs() < 1e-9);
}

#[test]
fn bp_step_matches_host_oracle() {
    if !artifacts_available() {
        return;
    }
    // Same init (shared seed derivation), same batch → XLA bp_step and
    // the pure-rust host trainer agree to f32 accumulation tolerance.
    let c = cfg(Algo::Bp);
    let ds = data::load_or_synth(c.seed, 64, 64).unwrap();
    let mut tr = Trainer::new(c.clone()).unwrap();
    tr.warmup().unwrap();

    let layers = tr.model().layers.clone();
    let medium = TransmissionMatrix::sample(0, 10, layers[1]);
    let mut host = HostTrainer::new(
        c.seed,
        &layers,
        c.lr,
        HostAlgo::Bp,
        Box::new(DigitalProjector::new(medium)),
    );
    // init parity
    for (a, b) in tr.model().params.iter().zip(&host.mlp.params) {
        assert_eq!(a.shape(), b.shape());
        assert!(a.max_abs_diff(b) < 1e-6, "init diverges");
    }

    let mut rng = Pcg64::seeded(3);
    let batch = tr.model().batch;
    let (x, y) = ds.batches(Split::Train, batch, &mut rng).next().unwrap();
    let l_xla = tr.train_step(&x, &y).unwrap();
    let l_host = host.step(&x, &y).unwrap();
    assert!((l_xla - l_host).abs() < 1e-4, "loss {l_xla} vs {l_host}");
    for (i, (a, b)) in tr.model().params.iter().zip(&host.mlp.params).enumerate() {
        let d = a.max_abs_diff(b);
        assert!(d < 5e-3, "param {i} diverged by {d}");
    }
}

#[test]
fn eval_batch_matches_host_accuracy() {
    if !artifacts_available() {
        return;
    }
    let c = cfg(Algo::Bp);
    let ds = data::load_or_synth(c.seed, 64, 200).unwrap();
    let mut tr = Trainer::new(c.clone()).unwrap();
    let ev = tr.evaluate(&ds, Split::Test).unwrap();

    let layers = tr.model().layers.clone();
    let host = litl::coordinator::host::HostMlp::init(c.seed, &layers);
    let idxs: Vec<usize> = (0..200).collect();
    let (x, y) = ds.gather(Split::Test, &idxs);
    let host_acc = host.accuracy(&x, &y) as f64;
    assert!(
        (ev.accuracy - host_acc).abs() < 0.02,
        "xla {} vs host {host_acc}",
        ev.accuracy
    );
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    if !artifacts_available() {
        return;
    }
    let c = cfg(Algo::DfaTernary);
    let ds = data::load_or_synth(c.seed, 128, 64).unwrap();
    let mut tr = Trainer::new(c.clone()).unwrap();
    tr.warmup().unwrap();
    let mut rng = Pcg64::seeded(4);
    let batch = tr.model().batch;
    for (x, y) in ds.batches(Split::Train, batch, &mut rng).take(3) {
        tr.train_step(&x, &y).unwrap();
    }
    let path = std::env::temp_dir().join("litl_e2e_ckpt.bin");
    let path = path.to_str().unwrap();
    tr.save_checkpoint(path).unwrap();

    let mut tr2 = Trainer::new(c).unwrap();
    tr2.load_checkpoint(path).unwrap();
    for (a, b) in tr.model().params.iter().zip(&tr2.model().params) {
        assert_eq!(a, b);
    }
    assert_eq!(tr.model().t, tr2.model().t);
}

#[test]
fn engine_rejects_wrong_shapes() {
    if !artifacts_available() {
        return;
    }
    let mut engine = Engine::new("artifacts").unwrap();
    let bad = Tensor::zeros(&[1, 1]);
    let err = engine
        .call("project_exact", "small", &[&bad, &bad, &bad])
        .unwrap_err()
        .to_string();
    assert!(err.contains("shape"), "{err}");
}
