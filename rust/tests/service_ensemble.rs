//! Integration: ensemble training through one shared projection service
//! (the Perspectives scenario: "ensembles of networks" on a single OPU).
//!
//! N host DFA trainers share one simulated OPU via the projection
//! service.  Checks: all members learn, the device is charged for every
//! member's frames, and batching actually happens (fewer device batches
//! than requests).

use std::sync::{Arc, Mutex};

use litl::config::Partition;
use litl::coordinator::host::{HostMlp, HostTrainer};
use litl::coordinator::projector::NativeOpticalProjector;
use litl::coordinator::service::{
    ClientProjector, ProjectionService, ServiceConfig, ShardServiceConfig,
    ShardedProjectionService,
};
use litl::coordinator::topology::DeviceKind;
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::Medium;
use litl::optics::OpuParams;
use litl::tensor::{matmul, Tensor};

mod common;
use common::{task_batch, ternary_batch, topology_farm};

const LAYERS: &[usize] = &[20, 16, 16, 10];

#[test]
fn ensemble_shares_one_opu() {
    let modes = LAYERS[1];
    let medium = TransmissionMatrix::sample(42, 10, modes);
    let device = Box::new(NativeOpticalProjector::new(
        OpuParams::default(),
        medium,
        7,
    ));
    let reg = Registry::new();
    let svc = ProjectionService::start(
        device,
        10,
        ServiceConfig {
            max_batch: 96,
            queue_depth: 64,
        },
        reg.clone(),
    );

    const MEMBERS: usize = 4;
    const STEPS: u64 = 60;
    const BATCH: usize = 16;
    let results: Arc<Mutex<Vec<(usize, f32, f32, HostMlp)>>> =
        Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = (0..MEMBERS)
        .map(|i| {
            let client = svc.client();
            let results = results.clone();
            std::thread::spawn(move || {
                let projector = Box::new(ClientProjector::new(client, modes));
                let mut tr = HostTrainer::new(
                    100 + i as u64, // independent inits: a real ensemble
                    LAYERS,
                    0.01,
                    litl::coordinator::host::HostAlgo::DfaTernary { theta: 0.1 },
                    projector,
                );
                let mut first = 0.0;
                let mut last = 0.0;
                for t in 0..STEPS {
                    let (x, y) = task_batch(1000 + i as u64 * 500 + t, BATCH, LAYERS);
                    let loss = tr.step(&x, &y).unwrap();
                    if t == 0 {
                        first = loss;
                    }
                    last = loss;
                }
                results.lock().unwrap().push((i, first, last, tr.mlp.clone()));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    svc.shutdown();

    let results = results.lock().unwrap();
    assert_eq!(results.len(), MEMBERS);
    for (i, first, last, _) in results.iter() {
        assert!(
            last < &(0.95 * first),
            "member {i}: first={first} last={last}"
        );
    }

    // Ensemble members differ (independent seeds, shared physics).
    let (_, _, _, m0) = &results[0];
    let (_, _, _, m1) = &results[1];
    assert!(m0.params[0].max_abs_diff(&m1.params[0]) > 1e-3);

    // The device saw every frame, batched into fewer calls.
    let snap = reg.snapshot();
    let expected_frames = (MEMBERS as u64 * STEPS * BATCH as u64) as f64;
    assert_eq!(snap["service_frames"], expected_frames);
    assert!(
        snap["service_batches"] < expected_frames / BATCH as f64,
        "no batching happened: {} batches",
        snap["service_batches"]
    );

    // Ensemble prediction beats (or matches) the worst member: sanity
    // that the members are usable together.
    let (px, py) = task_batch(9_999, 200, LAYERS);
    let accs: Vec<f32> = results.iter().map(|(_, _, _, m)| m.accuracy(&px, &py)).collect();
    let mut vote_correct = 0usize;
    for r in 0..200 {
        let mut scores = [0.0f32; 10];
        for (_, _, _, m) in results.iter() {
            let probs = m.forward(&row_of(&px, r)).probs;
            for c in 0..10 {
                scores[c] += probs.data()[c];
            }
        }
        let pred = argmax(&scores);
        let truth = argmax(&py.data()[r * 10..(r + 1) * 10]);
        if pred == truth {
            vote_correct += 1;
        }
    }
    let vote_acc = vote_correct as f32 / 200.0;
    let worst = accs.iter().cloned().fold(f32::INFINITY, f32::min);
    assert!(
        vote_acc >= worst - 0.02,
        "ensemble {vote_acc} vs worst member {worst}"
    );
}

/// Concurrency soak: N threaded clients × M mixed-size submissions
/// against a 4-shard shard-aware service, both partition policies.
/// Asserts: no deadlock (the test finishes), no dropped or duplicated
/// responses (every reply arrives once and is bitwise the digital
/// oracle for exactly that client's frames — a cross-routed, re-ordered
/// or double-consumed frame would break bit equality), and the
/// per-shard metrics explain the client-observed totals.
///
/// Slow by design (thousands of scheduled frames through tiny lanes);
/// runs in the dedicated `cargo test -- --ignored` CI step.
#[test]
#[ignore = "soak: run with --ignored (dedicated CI step)"]
fn soak_concurrent_clients_on_four_shard_service() {
    const CLIENTS: usize = 8;
    const SUBMISSIONS: usize = 40;
    let d_in = 10usize;
    let medium = TransmissionMatrix::sample(77, d_in, 32);
    for partition in [Partition::Modes, Partition::Batch] {
        let reg = Registry::new();
        let farm = topology_farm(
            DeviceKind::Digital,
            OpuParams::default(),
            &Medium::Dense(medium.clone()),
            0,
            4,
            partition,
            Registry::new(),
        )
        .unwrap();
        let svc = ShardedProjectionService::over_farm(
            farm,
            d_in,
            ShardServiceConfig {
                max_batch: 32,
                queue_depth: 16, // small: exercises client backpressure
                lane_depth: 2,   // small: exercises scheduler backpressure
                partition,
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let client = svc.client();
                let medium = medium.clone();
                std::thread::spawn(move || {
                    let mut rows = 0usize;
                    for j in 0..SUBMISSIONS {
                        // Mixed sizes 1..=12, client-dependent phase.
                        let b = 1 + (c * 7 + j * 3) % 12;
                        let e = ternary_batch(b, d_in, (c * 1000 + j) as u64);
                        let (p1, p2) = client.project(e.clone()).unwrap();
                        assert_eq!(
                            p1,
                            matmul(&e, &medium.b_re),
                            "client {c} submission {j}"
                        );
                        assert_eq!(p2, matmul(&e, &medium.b_im));
                        rows += b;
                    }
                    rows
                })
            })
            .collect();
        let total_rows: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        svc.shutdown();

        let snap = reg.snapshot();
        assert_eq!(
            snap["service_frames"], total_rows as f64,
            "{partition:?}: scheduler saw a different row total than clients"
        );
        let shard_frames = reg.sum_counters("service_shard", "_frames");
        let shard_slots = reg.sum_counters("service_shard", "_slots");
        match partition {
            // Every shard images every frame.
            Partition::Modes => {
                assert_eq!(shard_frames, (total_rows * 4) as f64);
                assert_eq!(shard_slots, (total_rows * 4) as f64);
            }
            // Row ranges partition the frames exactly.
            Partition::Batch => {
                assert_eq!(shard_frames, total_rows as f64);
                assert_eq!(shard_slots, total_rows as f64);
            }
        }
        assert_eq!(snap[litl::coordinator::service::SHARD_ERRORS], 0.0);
    }
}

fn row_of(x: &Tensor, r: usize) -> Tensor {
    Tensor::from_vec(&[1, x.cols()], x.row(r).to_vec())
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}
