//! Streamed-medium determinism contract (ISSUE 3 acceptance):
//!
//! * For any seed/shape, the streamed (memory-less) projection is
//!   **bitwise equal** to the materialized one — digital and noiseless
//!   optics, at shard counts 1/2/4 under both partitions (and, because
//!   the field at the camera is identical bit for bit, the *noisy*
//!   optics agree too: same field → same noise draws → same counts).
//! * `shards = 1` streamed equals the classic single-device path.
//! * Streamed shards compose with the shard-aware projection service
//!   under both partitions.
//! * A 1e5-mode streamed projection completes within the memory-less
//!   budget (`#[ignore]`d here for the release soak job; the CI
//!   `stream-smoke` job additionally enforces the ceiling with a hard
//!   `ulimit -v` around `benches/e6_streaming.rs`, where the dense
//!   allocation provably fails).

use litl::config::Partition;
use litl::coordinator::projector::{DigitalProjector, NativeOpticalProjector, Projector};
use litl::coordinator::service::{ShardServiceConfig, ShardedProjectionService};
use litl::coordinator::topology::DeviceKind;
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::{Medium, StreamedMedium};
use litl::optics::OpuParams;
use litl::tensor::{matmul, Tensor};

mod common;
use common::{noiseless_params, ternary_batch, topology_devices, topology_farm};

const D_IN: usize = 10;
const MODES: usize = 48;
const SEED: u64 = 21;
const NOISE_SEED: u64 = 77;

fn dense() -> Medium {
    Medium::Dense(TransmissionMatrix::sample(SEED, D_IN, MODES))
}

fn streamed() -> Medium {
    // A deliberately small tile so multi-tile gathers are exercised.
    Medium::Streamed(StreamedMedium::new(SEED, D_IN, MODES).with_tile_cols(13))
}

#[test]
fn streamed_digital_farm_is_bitwise_dense_at_shards_1_2_4() {
    let reference = TransmissionMatrix::sample(SEED, D_IN, MODES);
    for partition in [Partition::Modes, Partition::Batch] {
        for shards in [1usize, 2, 4] {
            let mut df = topology_farm(
                DeviceKind::Digital,
                OpuParams::default(),
                &dense(),
                0,
                shards,
                partition,
                Registry::new(),
            )
            .unwrap();
            let mut sf = topology_farm(
                DeviceKind::Digital,
                OpuParams::default(),
                &streamed(),
                0,
                shards,
                partition,
                Registry::new(),
            )
            .unwrap();
            let e = ternary_batch(6, D_IN, 100 + shards as u64);
            let (d1, d2) = df.project(&e).unwrap();
            let (s1, s2) = sf.project(&e).unwrap();
            assert_eq!(d1, s1, "{partition:?} shards={shards}");
            assert_eq!(d2, s2, "{partition:?} shards={shards}");
            // Both equal the single-device dense reference exactly.
            assert_eq!(s1, matmul(&e, &reference.b_re), "{partition:?} shards={shards}");
            assert_eq!(s2, matmul(&e, &reference.b_im), "{partition:?} shards={shards}");
        }
    }
}

#[test]
fn streamed_noiseless_optical_farm_is_bitwise_dense_at_shards_1_2_4() {
    for partition in [Partition::Modes, Partition::Batch] {
        for shards in [1usize, 2, 4] {
            let mut df = topology_farm(
                DeviceKind::Optical,
                noiseless_params(),
                &dense(),
                NOISE_SEED,
                shards,
                partition,
                Registry::new(),
            )
            .unwrap();
            let mut sf = topology_farm(
                DeviceKind::Optical,
                noiseless_params(),
                &streamed(),
                NOISE_SEED,
                shards,
                partition,
                Registry::new(),
            )
            .unwrap();
            for step in 0..2 {
                let e = ternary_batch(5, D_IN, 200 + 10 * shards as u64 + step);
                let (d1, d2) = df.project(&e).unwrap();
                let (s1, s2) = sf.project(&e).unwrap();
                assert_eq!(d1, s1, "{partition:?} shards={shards} step={step}");
                assert_eq!(d2, s2, "{partition:?} shards={shards} step={step}");
            }
            assert_eq!(df.sim_seconds(), sf.sim_seconds());
            assert_eq!(df.energy_joules(), sf.energy_joules());
        }
    }
}

#[test]
fn streamed_noisy_optical_farm_is_bitwise_dense_too() {
    // Stronger than the contract asks: the backing decides how the field
    // is computed, not what it is, so even the noisy draws line up.
    for partition in [Partition::Modes, Partition::Batch] {
        for shards in [1usize, 2, 4] {
            let mut df = topology_farm(
                DeviceKind::Optical,
                OpuParams::default(),
                &dense(),
                NOISE_SEED,
                shards,
                partition,
                Registry::new(),
            )
            .unwrap();
            let mut sf = topology_farm(
                DeviceKind::Optical,
                OpuParams::default(),
                &streamed(),
                NOISE_SEED,
                shards,
                partition,
                Registry::new(),
            )
            .unwrap();
            let e = ternary_batch(4, D_IN, 300 + shards as u64);
            assert_eq!(
                df.project(&e).unwrap(),
                sf.project(&e).unwrap(),
                "{partition:?} shards={shards}"
            );
        }
    }
}

#[test]
fn one_streamed_shard_is_bitwise_the_classic_single_device_path() {
    // The pre-farm path: a bare NativeOpticalProjector over the dense
    // medium, default noise stream.  Streamed shards=1 (farm) and the
    // bare streamed device must both reproduce it bit for bit across
    // sequential batches (noise-stream continuity included).
    let mut classic = NativeOpticalProjector::new(
        OpuParams::default(),
        TransmissionMatrix::sample(SEED, D_IN, MODES),
        NOISE_SEED,
    );
    let mut bare =
        NativeOpticalProjector::with_medium(OpuParams::default(), streamed(), NOISE_SEED);
    let mut farm1 = topology_farm(
                DeviceKind::Optical,
                OpuParams::default(),
                &streamed(),
                NOISE_SEED,
                1,
                Partition::Modes,
                Registry::new(),
            )
    .unwrap();
    for step in 0..3 {
        let e = ternary_batch(4, D_IN, 400 + step);
        let want = classic.project(&e).unwrap();
        assert_eq!(bare.project(&e).unwrap(), want, "bare, step {step}");
        assert_eq!(farm1.project(&e).unwrap(), want, "farm, step {step}");
    }
    assert_eq!(classic.sim_seconds(), bare.sim_seconds());
}

#[test]
fn streamed_digital_single_device_is_bitwise_dense() {
    let mut d = DigitalProjector::with_medium(dense());
    let mut s = DigitalProjector::with_medium(streamed());
    for step in 0..3 {
        let e = ternary_batch(7, D_IN, 500 + step);
        assert_eq!(d.project(&e).unwrap(), s.project(&e).unwrap(), "step {step}");
    }
}

#[test]
fn streamed_shards_compose_with_the_sharded_service() {
    // Same submission order into a dense-shard service and a
    // streamed-shard service: the frame-slot schedules are identical
    // (single scheduler thread), so replies must match bit for bit.
    for partition in [Partition::Modes, Partition::Batch] {
        let run = |medium: Medium| -> Vec<(Tensor, Tensor)> {
            let devices = topology_devices(
                DeviceKind::Optical,
                noiseless_params(),
                &medium,
                NOISE_SEED,
                3,
                partition,
            )
            .unwrap();
            let svc = ShardedProjectionService::start(
                devices,
                D_IN,
                ShardServiceConfig {
                    max_batch: 16,
                    queue_depth: 32,
                    lane_depth: 4,
                    partition,
                    frame_rate_hz: 1500.0,
                    ..Default::default()
                },
                Registry::new(),
            )
            .unwrap();
            let client = svc.client();
            let out: Vec<(Tensor, Tensor)> = (0..5)
                .map(|i| client.project(ternary_batch(3, D_IN, 600 + i)).unwrap())
                .collect();
            svc.shutdown();
            out
        };
        let dense_replies = run(dense());
        let streamed_replies = run(streamed());
        assert_eq!(dense_replies, streamed_replies, "{partition:?}");
    }
}

/// A streamed medium with the cross-step tile cache attached (budget in
/// MiB, `stripes` lock stripes — phase 3 rounds the count up to a power
/// of two), same deliberately small tile as [`streamed`].
fn streamed_cached(mb: usize, stripes: usize) -> (litl::optics::stream::StreamedMedium, Medium) {
    let sm = StreamedMedium::new(SEED, D_IN, MODES)
        .with_tile_cols(13)
        .with_tile_cache_mb_striped(mb, stripes);
    let medium = Medium::Streamed(sm.clone());
    (sm, medium)
}

#[test]
fn cached_streamed_farm_is_bitwise_the_uncached_one_at_shards_1_2_4() {
    // The cache contract: hits replay stored tiles bit for bit, so a
    // cached farm equals the uncached (and hence the dense) one at any
    // shard count under either partition — digital exact, *noisy*
    // optics included — and from step 2 the modes-partition farm serves
    // from cache instead of regenerating.
    let cases = [
        ("digital", DeviceKind::Digital, OpuParams::default()),
        ("noiseless", DeviceKind::Optical, noiseless_params()),
        ("noisy", DeviceKind::Optical, OpuParams::default()),
    ];
    for (label, kind, params) in cases {
        for partition in [Partition::Modes, Partition::Batch] {
            for shards in [1usize, 2, 4] {
                let mut plain = topology_farm(
                    kind,
                    params,
                    &streamed(),
                    NOISE_SEED,
                    shards,
                    partition,
                    Registry::new(),
                )
                .unwrap();
                let (handle, medium) = streamed_cached(4, 1);
                let mut cached = topology_farm(
                    kind,
                    params,
                    &medium,
                    NOISE_SEED,
                    shards,
                    partition,
                    Registry::new(),
                )
                .unwrap();
                for step in 0..3 {
                    let e = ternary_batch(5, D_IN, 800 + 10 * shards as u64 + step);
                    assert_eq!(
                        plain.project(&e).unwrap(),
                        cached.project(&e).unwrap(),
                        "{label} {partition:?} shards={shards} step={step}"
                    );
                }
                let st = handle.stats();
                assert!(
                    st.cache_hits > 0,
                    "steps 2+ must hit ({label} {partition:?} shards={shards}): {st:?}"
                );
            }
        }
    }
}

#[test]
fn cached_streamed_shards_compose_with_the_sharded_service() {
    // Same submission order into an uncached and a cached streamed-shard
    // service: bitwise-identical replies (the schedule is a pure
    // function of arrival order; the cache only changes where tile
    // bytes come from).
    for partition in [Partition::Modes, Partition::Batch] {
        let run = |medium: Medium| -> Vec<(Tensor, Tensor)> {
            let devices = topology_devices(
                DeviceKind::Optical,
                noiseless_params(),
                &medium,
                NOISE_SEED,
                3,
                partition,
            )
            .unwrap();
            let svc = ShardedProjectionService::start(
                devices,
                D_IN,
                ShardServiceConfig {
                    max_batch: 16,
                    queue_depth: 32,
                    lane_depth: 4,
                    partition,
                    frame_rate_hz: 1500.0,
                    ..Default::default()
                },
                Registry::new(),
            )
            .unwrap();
            let client = svc.client();
            let out: Vec<(Tensor, Tensor)> = (0..5)
                .map(|i| client.project(ternary_batch(3, D_IN, 900 + i)).unwrap())
                .collect();
            svc.shutdown();
            out
        };
        let plain_replies = run(streamed());
        let (handle, medium) = streamed_cached(4, 1);
        let cached_replies = run(medium);
        assert_eq!(plain_replies, cached_replies, "{partition:?}");
        let st = handle.stats();
        assert!(st.cache_hits > 0, "{partition:?}: repeat frames must hit: {st:?}");
        assert!(
            st.cache_resident_bytes <= st.cache_budget_bytes,
            "budget respected: {st:?}"
        );
    }
}

#[test]
fn striped_cache_is_bitwise_single_stripe_through_the_farm() {
    // The phase-3 contract end to end: stripes partition locks and
    // residency, never bits.  A 4-stripe cached farm equals the
    // 1-stripe one at shards 1/2/4 under both partitions for digital,
    // noiseless and noisy optics alike, and both stay within budget.
    let cases = [
        ("digital", DeviceKind::Digital, OpuParams::default()),
        ("noiseless", DeviceKind::Optical, noiseless_params()),
        ("noisy", DeviceKind::Optical, OpuParams::default()),
    ];
    for (label, kind, params) in cases {
        for partition in [Partition::Modes, Partition::Batch] {
            for shards in [1usize, 2, 4] {
                let (h1, m1) = streamed_cached(4, 1);
                let (h4, m4) = streamed_cached(4, 4);
                assert_eq!(h1.tile_cache().unwrap().stripe_count(), 1);
                assert_eq!(h4.tile_cache().unwrap().stripe_count(), 4);
                let mut f1 = topology_farm(
                    kind,
                    params,
                    &m1,
                    NOISE_SEED,
                    shards,
                    partition,
                    Registry::new(),
                )
                .unwrap();
                let mut f4 = topology_farm(
                    kind,
                    params,
                    &m4,
                    NOISE_SEED,
                    shards,
                    partition,
                    Registry::new(),
                )
                .unwrap();
                for step in 0..3 {
                    let e = ternary_batch(5, D_IN, 1000 + 10 * shards as u64 + step);
                    assert_eq!(
                        f1.project(&e).unwrap(),
                        f4.project(&e).unwrap(),
                        "{label} {partition:?} shards={shards} step={step}"
                    );
                }
                for (tag, h) in [("1-stripe", &h1), ("4-stripe", &h4)] {
                    let st = h.stats();
                    assert!(
                        st.cache_hits > 0,
                        "{tag} steps 2+ must hit ({label} {partition:?} shards={shards}): {st:?}"
                    );
                    assert!(
                        st.cache_resident_bytes <= st.cache_budget_bytes,
                        "{tag} budget respected: {st:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn striped_cache_composes_with_the_sharded_service() {
    // Frame-slot scheduling path: identical submission order into a
    // 1-stripe and an 8-stripe cached service gives bitwise-identical
    // replies — the stripe map only decides which lock a tile lives
    // behind.
    for partition in [Partition::Modes, Partition::Batch] {
        let run = |medium: Medium| -> Vec<(Tensor, Tensor)> {
            let devices = topology_devices(
                DeviceKind::Optical,
                noiseless_params(),
                &medium,
                NOISE_SEED,
                3,
                partition,
            )
            .unwrap();
            let svc = ShardedProjectionService::start(
                devices,
                D_IN,
                ShardServiceConfig {
                    max_batch: 16,
                    queue_depth: 32,
                    lane_depth: 4,
                    partition,
                    frame_rate_hz: 1500.0,
                    ..Default::default()
                },
                Registry::new(),
            )
            .unwrap();
            let client = svc.client();
            let out: Vec<(Tensor, Tensor)> = (0..5)
                .map(|i| client.project(ternary_batch(3, D_IN, 1100 + i)).unwrap())
                .collect();
            svc.shutdown();
            out
        };
        let (h1, m1) = streamed_cached(4, 1);
        let (h8, m8) = streamed_cached(4, 8);
        assert_eq!(h8.tile_cache().unwrap().stripe_count(), 8);
        let one = run(m1);
        let eight = run(m8);
        assert_eq!(one, eight, "{partition:?}");
        for h in [&h1, &h8] {
            let st = h.stats();
            assert!(st.cache_hits > 0, "{partition:?}: repeat frames must hit: {st:?}");
            assert!(
                st.cache_resident_bytes <= st.cache_budget_bytes,
                "budget respected: {st:?}"
            );
        }
    }
}

#[test]
fn profiling_hooks_leave_streamed_projection_bitwise_unchanged() {
    // The ISSUE-8 generation profiling hooks (`stream_gen_ns` /
    // `stream_cache_hit_ns`) observe wall time only: a metric-bound,
    // cached, *noisy* farm under an enabled trace session returns the
    // same bits as the unprofiled run.  Summary level also records no
    // span events — it is histograms-only by contract.
    use litl::metrics::trace::{TraceClock, TraceLevel, TraceSession};
    use litl::optics::stream::{STREAM_CACHE_HIT_NS, STREAM_GEN_NS};
    let run = |registry: Option<&Registry>| -> Vec<(Tensor, Tensor)> {
        let (_, medium) = streamed_cached(4, 1);
        let medium = match (registry, medium) {
            (Some(reg), Medium::Streamed(sm)) => Medium::Streamed(sm.with_metrics(reg)),
            (_, m) => m,
        };
        let mut farm = topology_farm(
            DeviceKind::Optical,
            OpuParams::default(),
            &medium,
            NOISE_SEED,
            2,
            Partition::Modes,
            Registry::new(),
        )
        .unwrap();
        (0..3)
            .map(|step| farm.project(&ternary_batch(5, D_IN, 1200 + step)).unwrap())
            .collect()
    };
    let plain = run(None);
    let reg = Registry::new();
    let session = TraceSession::begin(TraceLevel::Summary, TraceClock::wall(), 1 << 12);
    let profiled = run(Some(&reg));
    let report = session.finish();
    assert_eq!(plain, profiled, "profiling hooks changed projection bits");
    assert!(report.spans.is_empty(), "summary level must not record span events");
    assert!(reg.histogram(STREAM_GEN_NS).count() > 0, "gen histogram unfed");
    assert!(reg.histogram(STREAM_CACHE_HIT_NS).count() > 0, "hit histogram unfed");
}

#[test]
fn streamed_farm_project_on_charges_one_shard_and_matches_the_slice() {
    let mut farm = topology_farm(
        DeviceKind::Digital,
        OpuParams::default(),
        &streamed(),
        0,
        3,
        Partition::Modes,
        Registry::new(),
    )
    .unwrap();
    let e = ternary_batch(5, D_IN, 700);
    let slices = TransmissionMatrix::sample(SEED, D_IN, MODES).split_modes(3);
    let (p1, p2) = farm.project_on(1, &e).unwrap();
    assert_eq!(p1, matmul(&e, &slices[1].b_re));
    assert_eq!(p2, matmul(&e, &slices[1].b_im));
    assert_eq!(farm.shard_slots(), &[0, 5, 0]);
}

/// The memory-less guarantee at paper scale: a 1e5-mode projection
/// completes with tile-scratch residency, where the dense slice would be
/// 1.6 GB.  `#[ignore]`d for the tier-1 suite (it is real compute); the
/// release soak job runs it, and the CI `stream-smoke` job enforces the
/// same bound with a hard `ulimit -v` around the e6 bench.
#[test]
#[ignore]
fn streamed_projection_at_1e5_modes_stays_within_the_memless_budget() {
    let (d_in, modes) = (2048usize, 100_000usize);
    let sm = StreamedMedium::new(9, d_in, modes);
    let dense_bytes = sm.dense_bytes() as u64;
    assert_eq!(dense_bytes, 2048 * 100_000 * 8);
    // All-bright frame: every input row contributes (worst case).
    let e = Tensor::from_vec(&[1, d_in], vec![1.0; d_in]);
    let (p1, p2) = sm.project(&e);
    // Output statistics: each mode is a sum of d_in unit-variance/2
    // couplings → variance d_in/2 per quadrature.
    let var: f64 = p1
        .data()
        .iter()
        .chain(p2.data())
        .map(|&x| (x as f64).powi(2))
        .sum::<f64>()
        / (2 * modes) as f64;
    let want = d_in as f64 / 2.0;
    assert!(
        (var - want).abs() < 0.05 * want,
        "projection variance {var} vs theory {want}"
    );
    let st = sm.stats();
    assert_eq!(st.tiles as usize, d_in * modes.div_ceil(litl::optics::stream::DEFAULT_TILE_COLS));
    assert_eq!(st.bytes_generated, dense_bytes, "every entry generated exactly once");
    // Residency bound: scratch per tile job is 5 orders below dense.
    assert!(sm.scratch_bytes_per_job() as u64 * 1000 < dense_bytes);
    assert!(st.gen_seconds > 0.0);
}
