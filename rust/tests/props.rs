//! Property tests over coordinator invariants (own mini-framework,
//! `litl::util::check`): frame packing, routing, quantization, state
//! round-trips — the "L3 proptest" requirement.

use litl::coordinator::checkpoint;
use litl::coordinator::projector::DigitalProjector;
use litl::coordinator::service::{ProjectionService, ServiceConfig};
use litl::metrics::Registry;
use litl::optics::holography::demod_quadrature;
use litl::optics::medium::TransmissionMatrix;
use litl::tensor::{matmul, ternarize, Tensor};
use litl::util::check::{forall, Gen, PairG, UsizeIn, VecF32};
use litl::util::fft::{fft, ifft};
use litl::util::rng::Pcg64;

/// Any batching of any request sizes: every request gets exactly its own
/// rows back (no loss, no duplication, no reordering, no cross-talk).
#[test]
fn prop_service_preserves_payloads() {
    struct Sizes;
    impl Gen<Vec<usize>> for Sizes {
        fn generate(&self, rng: &mut Pcg64) -> Vec<usize> {
            let n = 1 + rng.next_below(8) as usize;
            (0..n).map(|_| 1 + rng.next_below(40) as usize).collect()
        }
        fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
            let mut out = Vec::new();
            if v.len() > 1 {
                out.push(v[..v.len() / 2].to_vec());
            }
            if v.iter().any(|&s| s > 1) {
                out.push(v.iter().map(|_| 1).collect());
            }
            out
        }
    }

    forall("service preserves payloads", &Sizes, |sizes| {
        let medium = TransmissionMatrix::sample(3, 10, 8);
        let svc = ProjectionService::start(
            Box::new(DigitalProjector::new(medium.clone())),
            10,
            ServiceConfig {
                max_batch: 32,
                queue_depth: 64,
            },
            Registry::new(),
        );
        let client = svc.client();
        let mut rng = Pcg64::seeded(sizes.iter().sum::<usize>() as u64);
        // Submit all requests first (forces packing), then verify each.
        let reqs: Vec<(Tensor, _)> = sizes
            .iter()
            .map(|&s| {
                let mut e = Tensor::zeros(&[s, 10]);
                for v in e.data_mut() {
                    *v = (rng.next_below(3) as i64 - 1) as f32;
                }
                let reply = client.submit(e.clone()).unwrap();
                (e, reply)
            })
            .collect();
        let ok = reqs.into_iter().all(|(e, reply)| {
            let (p1, p2) = reply.wait().unwrap().unwrap();
            p1 == matmul(&e, &medium.b_re) && p2 == matmul(&e, &medium.b_im)
        });
        svc.shutdown();
        ok
    });
}

/// Eq. 4 invariants: range, sign preservation, idempotence, monotone
/// sparsity in θ.
#[test]
fn prop_ternarize_invariants() {
    let gen = PairG(
        VecF32 {
            len: UsizeIn(1, 200),
            scale: 0.5,
        },
        UsizeIn(0, 100),
    );
    forall("ternarize invariants", &gen, |(vals, th_pct)| {
        let theta = *th_pct as f32 / 100.0;
        let x = Tensor::from_vec(&[1, vals.len()], vals.clone());
        let t = ternarize(&x, theta);
        let in_range = t.data().iter().all(|&v| v == 0.0 || v == 1.0 || v == -1.0);
        let signs_ok = t
            .data()
            .iter()
            .zip(vals)
            .all(|(&q, &orig)| q == 0.0 || (q > 0.0) == (orig > 0.0));
        // idempotent at any smaller-or-equal threshold once ternary
        let twice = ternarize(&t, theta.min(0.9));
        let sparser = ternarize(&x, theta + 0.2);
        let nnz = |t: &Tensor| t.data().iter().filter(|&&v| v != 0.0).count();
        in_range && signs_ok && twice == t && nnz(&sparser) <= nnz(&t)
    });
}

/// Quadrature demod is exact (to float error) for ANY field when fed
/// unquantized intensities: the algebraic identity behind the device.
#[test]
fn prop_quadrature_demod_identity() {
    let gen = UsizeIn(1, 64);
    forall("quadrature demod identity", &gen, |&modes| {
        let mut rng = Pcg64::seeded(modes as u64);
        let amp = 16.0f64;
        let yre: Vec<f32> = (0..modes).map(|_| rng.next_normal_f32()).collect();
        let yim: Vec<f32> = (0..modes).map(|_| rng.next_normal_f32()).collect();
        // Build exact (ungained, unquantized) intensities.
        let mut counts = vec![0.0f32; 4 * modes];
        for m in 0..modes {
            for o in 0..4 {
                let ph = std::f64::consts::FRAC_PI_2 * (4 * m + o) as f64;
                let fre = yre[m] as f64 + amp * ph.cos();
                let fim = yim[m] as f64 + amp * ph.sin();
                counts[4 * m + o] = (fre * fre + fim * fim) as f32;
            }
        }
        let (re, im) = demod_quadrature(&counts, modes, amp, 1.0);
        re.iter()
            .zip(&yre)
            .chain(im.iter().zip(&yim))
            .all(|(a, b)| (a - b).abs() < 1e-3)
    });
}

/// FFT ∘ IFFT = identity for any power-of-two complex vector.
#[test]
fn prop_fft_roundtrip() {
    let gen = PairG(UsizeIn(0, 10), UsizeIn(0, u32::MAX as usize));
    forall("fft roundtrip", &gen, |&(log_n, seed)| {
        let n = 1usize << log_n;
        let mut rng = Pcg64::seeded(seed as u64);
        let x: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.next_normal(), rng.next_normal()))
            .collect();
        let back = ifft(&fft(&x));
        x.iter()
            .zip(&back)
            .all(|(a, b)| (a.0 - b.0).abs() < 1e-9 && (a.1 - b.1).abs() < 1e-9)
    });
}

/// Checkpoints round-trip arbitrary tensor sets exactly.
#[test]
fn prop_checkpoint_roundtrip() {
    struct Tensors;
    impl Gen<Vec<Tensor>> for Tensors {
        fn generate(&self, rng: &mut Pcg64) -> Vec<Tensor> {
            let n = 1 + rng.next_below(6) as usize;
            (0..n)
                .map(|_| {
                    let r = 1 + rng.next_below(8) as usize;
                    let c = 1 + rng.next_below(8) as usize;
                    Tensor::randn(&[r, c], rng, 1.0)
                })
                .collect()
        }
    }
    let dir = std::env::temp_dir().join("litl_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let counter = std::sync::atomic::AtomicU64::new(0);
    forall("checkpoint roundtrip", &Tensors, move |tensors| {
        let n = counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let path = dir.join(format!("ck_{n}.bin"));
        let refs: Vec<&Tensor> = tensors.iter().collect();
        checkpoint::save(&path, &refs, n as f32).unwrap();
        let (back, step) = checkpoint::load(&path).unwrap();
        step == n as f32 && back == *tensors
    });
}

/// The batched Box–Muller lane kernel is bitwise the scalar walk for
/// ANY (seed, stream, lengths) — including odd lengths (spare carry
/// across consecutive fills) and `advance`-seeked start offsets (the
/// streamed tile path).
#[test]
fn prop_batched_normal_kernel_is_bitwise_scalar() {
    struct Case;
    impl Gen<(u64, u64, usize, Vec<usize>)> for Case {
        fn generate(&self, rng: &mut Pcg64) -> (u64, u64, usize, Vec<usize>) {
            let seed = rng.next_u64();
            let stream = rng.next_u64();
            let pair_offset = rng.next_below(6000) as usize;
            let n = 1 + rng.next_below(4) as usize;
            let lens = (0..n).map(|_| rng.next_below(200) as usize).collect();
            (seed, stream, pair_offset, lens)
        }
        fn shrink(
            &self,
            v: &(u64, u64, usize, Vec<usize>),
        ) -> Vec<(u64, u64, usize, Vec<usize>)> {
            let (seed, stream, off, lens) = v.clone();
            let mut out = Vec::new();
            if off > 0 {
                out.push((seed, stream, 0, lens.clone()));
            }
            if lens.len() > 1 {
                out.push((seed, stream, off, lens[..1].to_vec()));
            }
            out
        }
    }
    forall("batched normals == scalar", &Case, |case| {
        let (seed, stream, pair_offset, lens) = case;
        let mut scalar = Pcg64::new(*seed, *stream);
        let mut batched = Pcg64::new(*seed, *stream);
        scalar.advance(2 * *pair_offset as u128);
        batched.advance(2 * *pair_offset as u128);
        for &len in lens {
            let mut a = vec![0.0f32; len];
            let mut b = vec![0.0f32; len];
            scalar.fill_normal_scalar(&mut a);
            batched.fill_normal(&mut b);
            if a.iter().zip(&b).any(|(x, y)| x.to_bits() != y.to_bits()) {
                return false;
            }
        }
        // Terminal states agree too (spare included).
        scalar.next_normal().to_bits() == batched.next_normal().to_bits()
    });
}

/// Medium sampling: unit mean power and linearity of projection for any
/// dims (the physics the simulator must preserve at every size).
#[test]
fn prop_medium_linearity() {
    let gen = PairG(UsizeIn(1, 30), UsizeIn(1, 60));
    forall("medium linearity", &gen, |&(d_in, modes)| {
        let medium = TransmissionMatrix::sample(7, d_in, modes);
        let mut rng = Pcg64::seeded((d_in * 31 + modes) as u64);
        let a = Tensor::randn(&[2, d_in], &mut rng, 1.0);
        let b = Tensor::randn(&[2, d_in], &mut rng, 1.0);
        let mut sum = a.clone();
        for (s, &bv) in sum.data_mut().iter_mut().zip(b.data()) {
            *s += bv;
        }
        let pa = matmul(&a, &medium.b_re);
        let pb = matmul(&b, &medium.b_re);
        let psum = matmul(&sum, &medium.b_re);
        pa.data()
            .iter()
            .zip(pb.data())
            .zip(psum.data())
            .all(|((x, y), z)| (x + y - z).abs() < 1e-3)
    });
}
