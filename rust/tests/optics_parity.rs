//! Cross-implementation parity: rust-native optics vs the JAX/Pallas
//! twin (through the AOT artifacts).  This is the test that licenses
//! using the fast native device for the headline experiments while the
//! L1/L2 stack remains the ground truth.

use litl::optics::medium::TransmissionMatrix;
use litl::optics::{OpticalOpu, OpuParams};
use litl::runtime::Engine;
use litl::tensor::{matmul, Tensor};
use litl::util::rng::Pcg64;

mod common;
use common::{artifacts_available, ternary_batch};

fn carrier_tables(carrier: f64, npix: usize) -> (Tensor, Tensor) {
    let mut cosk = Tensor::zeros(&[1, npix]);
    let mut sink = Tensor::zeros(&[1, npix]);
    for p in 0..npix {
        let ph = carrier * p as f64;
        cosk.data_mut()[p] = ph.cos() as f32;
        sink.data_mut()[p] = ph.sin() as f32;
    }
    (cosk, sink)
}

/// `project_exact` artifact == host matmul, bit-for-f32-tolerance.
#[test]
fn project_exact_artifact_matches_host() {
    if !artifacts_available() {
        return;
    }
    let mut engine = Engine::new("artifacts").unwrap();
    let cfg = engine.manifest().config("small").unwrap().clone();
    let medium = TransmissionMatrix::sample(5, 10, cfg.modes);
    let e = ternary_batch(cfg.batch, 10, 1);
    let outs = engine
        .call("project_exact", "small", &[&e, &medium.b_re, &medium.b_im])
        .unwrap();
    let host1 = matmul(&e, &medium.b_re);
    let host2 = matmul(&e, &medium.b_im);
    assert!(outs[0].max_abs_diff(&host1) < 1e-4);
    assert!(outs[1].max_abs_diff(&host2) < 1e-4);
}

/// Native OPU and the `opu_project` artifact implement the SAME device:
/// with noise disabled both recover the exact projection to ADC
/// precision, and their outputs agree with each other to ~1 LSB.
#[test]
fn opu_project_artifact_matches_native_physics() {
    if !artifacts_available() {
        return;
    }
    let mut engine = Engine::new("artifacts").unwrap();
    let cfg = engine.manifest().config("small").unwrap().clone();
    let opu_params = engine.manifest().opu;
    let medium = TransmissionMatrix::sample(6, 10, cfg.modes);
    let e = ternary_batch(cfg.batch, 10, 2);
    let npix = opu_params.oversample * cfg.modes;

    // HLO twin, zero noise draws + huge photon budget.
    let n1 = Tensor::zeros(&[cfg.batch, npix]);
    let n2 = Tensor::zeros(&[cfg.batch, npix]);
    let nph = Tensor::scalar(1e9);
    let sigma = Tensor::scalar(0.0);
    let (cosk, sink) = carrier_tables(opu_params.carrier, npix);
    let outs = engine
        .call(
            "opu_project",
            "small",
            &[&e, &medium.b_re, &medium.b_im, &n1, &n2, &nph, &sigma,
              &cosk, &sink],
        )
        .unwrap();

    // Native device, same noise settings.
    let mut params = opu_params;
    params.n_ph = 1e9;
    params.read_sigma = 0.0;
    let mut native = OpticalOpu::new(params, medium.clone(), 3);
    let (p1, p2) = native.project(&e).unwrap();

    let lsb = (params.gain_for(10) / (4.0 * params.amp)) as f32;
    let d1 = outs[0].max_abs_diff(&p1);
    let d2 = outs[1].max_abs_diff(&p2);
    assert!(d1 <= 1.5 * lsb, "re quadrature differs by {d1} (lsb {lsb})");
    assert!(d2 <= 1.5 * lsb, "im quadrature differs by {d2}");

    // And both match the exact projection to ADC precision.
    let exact = matmul(&e, &medium.b_re);
    assert!(outs[0].max_abs_diff(&exact) <= 1.5 * lsb);
    assert!(p1.max_abs_diff(&exact) <= 1.5 * lsb);
}

/// With the manifest's production noise levels, the two implementations
/// produce *statistically equivalent* devices: same recovery error
/// distribution against the exact projection (they use different RNG
/// streams, so values differ but the noise scale must match).
#[test]
fn noise_statistics_match_between_twins() {
    if !artifacts_available() {
        return;
    }
    let mut engine = Engine::new("artifacts").unwrap();
    let cfg = engine.manifest().config("small").unwrap().clone();
    let opu_params = engine.manifest().opu;
    let medium = TransmissionMatrix::sample(8, 10, cfg.modes);
    let e = ternary_batch(cfg.batch, 10, 4);
    let npix = opu_params.oversample * cfg.modes;
    let exact = matmul(&e, &medium.b_re);

    // HLO twin with rust-supplied normal draws.
    let mut rng = Pcg64::seeded(9);
    let mut n1 = Tensor::zeros(&[cfg.batch, npix]);
    let mut n2 = Tensor::zeros(&[cfg.batch, npix]);
    rng.fill_normal(n1.data_mut());
    rng.fill_normal(n2.data_mut());
    let nph = Tensor::scalar(opu_params.n_ph);
    let sigma = Tensor::scalar(opu_params.read_sigma);
    let (cosk, sink) = carrier_tables(opu_params.carrier, npix);
    let outs = engine
        .call(
            "opu_project",
            "small",
            &[&e, &medium.b_re, &medium.b_im, &n1, &n2, &nph, &sigma,
              &cosk, &sink],
        )
        .unwrap();
    let err_hlo = rms(&outs[0], &exact);

    let mut native = OpticalOpu::new(opu_params, medium, 10);
    let (p1, _) = native.project(&e).unwrap();
    let err_native = rms(&p1, &exact);

    let ratio = err_hlo / err_native;
    assert!(
        (0.66..1.5).contains(&ratio),
        "noise scales differ: hlo={err_hlo} native={err_native}"
    );
}

fn rms(a: &Tensor, b: &Tensor) -> f64 {
    let mut acc = 0.0f64;
    for (x, y) in a.data().iter().zip(b.data()) {
        acc += ((x - y) as f64).powi(2);
    }
    (acc / a.numel() as f64).sqrt()
}
