//! Serving control plane: shutdown-with-in-flight guarantees, adaptive
//! weights, shard failover (trip / drain / rebuild / probation) and
//! per-client admission control.
//!
//! The contract under test (see `coordinator::service` docs): a client
//! blocked on a reply must *always* be unblocked — with a result while
//! the fleet is healthy, with an error when its shard is gone — and
//! never hang, under both partition policies; adaptation and failover
//! change which shard serves a frame, never the frame's value.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use litl::config::Partition;
use litl::coordinator::projector::{DigitalProjector, Projector};
use litl::coordinator::service::{
    AdaptConfig, AdmissionConfig, FailoverConfig, ShardRebuild, ShardServiceConfig,
    ShardedProjectionService,
};
use litl::coordinator::topology::{DeviceKind, Topology};
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::Medium;
use litl::optics::OpuParams;
use litl::tensor::{matmul, Tensor};

mod common;
use common::ternary_batch;

const D_IN: usize = 10;
const MODES: usize = 24;

/// Device wrapper that sleeps a fixed time per call — a wedged camera
/// link, the stall-detector's target.
struct Wedge {
    inner: Box<dyn Projector + Send>,
    sleep_ms: u64,
}

impl Projector for Wedge {
    fn project(&mut self, frames: &Tensor) -> anyhow::Result<(Tensor, Tensor)> {
        thread::sleep(Duration::from_millis(self.sleep_ms));
        self.inner.project(frames)
    }

    fn modes(&self) -> usize {
        self.inner.modes()
    }

    fn sim_seconds(&self) -> f64 {
        self.inner.sim_seconds()
    }

    fn energy_joules(&self) -> f64 {
        self.inner.energy_joules()
    }

    fn kind(&self) -> &'static str {
        "wedge"
    }

    fn requires_ternary(&self) -> bool {
        self.inner.requires_ternary()
    }
}

/// Device wrapper that errors for the first `fail_remaining` calls —
/// an injected fault burst for the trip/rebuild path.
struct Flaky {
    inner: Box<dyn Projector + Send>,
    fail_remaining: Arc<AtomicUsize>,
}

impl Projector for Flaky {
    fn project(&mut self, frames: &Tensor) -> anyhow::Result<(Tensor, Tensor)> {
        let left = self.fail_remaining.load(Ordering::Relaxed);
        if left > 0 {
            self.fail_remaining.store(left - 1, Ordering::Relaxed);
            anyhow::bail!("injected device fault");
        }
        self.inner.project(frames)
    }

    fn modes(&self) -> usize {
        self.inner.modes()
    }

    fn sim_seconds(&self) -> f64 {
        self.inner.sim_seconds()
    }

    fn energy_joules(&self) -> f64 {
        self.inner.energy_joules()
    }

    fn kind(&self) -> &'static str {
        "flaky"
    }

    fn requires_ternary(&self) -> bool {
        self.inner.requires_ternary()
    }
}

/// Device wrapper that sleeps per row — a slow replica, the adaptive
/// planner's target.
struct Throttled {
    inner: Box<dyn Projector + Send>,
    us_per_row: u64,
}

impl Projector for Throttled {
    fn project(&mut self, frames: &Tensor) -> anyhow::Result<(Tensor, Tensor)> {
        thread::sleep(Duration::from_micros(self.us_per_row * frames.rows() as u64));
        self.inner.project(frames)
    }

    fn modes(&self) -> usize {
        self.inner.modes()
    }

    fn sim_seconds(&self) -> f64 {
        self.inner.sim_seconds()
    }

    fn energy_joules(&self) -> f64 {
        self.inner.energy_joules()
    }

    fn kind(&self) -> &'static str {
        "throttled"
    }

    fn requires_ternary(&self) -> bool {
        self.inner.requires_ternary()
    }
}

/// Full-medium digital replica pair for the batch partition, shard 1
/// wrapped by `wrap`.
fn replica_pair(
    medium: &TransmissionMatrix,
    wrap: impl FnOnce(Box<dyn Projector + Send>) -> Box<dyn Projector + Send>,
) -> Vec<Box<dyn Projector + Send>> {
    vec![
        Box::new(DigitalProjector::new(medium.clone())),
        wrap(Box::new(DigitalProjector::new(medium.clone()))),
    ]
}

/// Mode-windowed digital pair for the modes partition (via the
/// `Topology` build path), shard 1 wrapped by `wrap`.
fn windowed_pair(
    medium: &TransmissionMatrix,
    wrap: impl FnOnce(Box<dyn Projector + Send>) -> Box<dyn Projector + Send>,
) -> Vec<Box<dyn Projector + Send>> {
    let mut devices = Topology::homogeneous(DeviceKind::Digital, 2)
        .with_partition(Partition::Modes)
        .build_devices(
            OpuParams::default(),
            &Medium::Dense(medium.clone()),
            0,
            &Registry::new(),
        )
        .unwrap();
    let shard1 = devices.pop().unwrap();
    devices.push(wrap(shard1));
    devices
}

/// A reply that does not arrive within `secs` is a hang — the one
/// outcome the control plane must make impossible.
fn wait_bounded(
    reply: litl::exec::oneshot::Reply<Result<(Tensor, Tensor), String>>,
    secs: u64,
) -> Option<Result<(Tensor, Tensor), String>> {
    match reply.wait_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(_) => panic!("client hung for {secs}s waiting for a reply"),
    }
}

/// Shutdown with frames in flight on a wedged shard: the blocked
/// clients get errors, never hangs — the in-flight part is force-failed
/// and the queued lane is error-drained, under both partitions.
#[test]
fn shutdown_with_inflight_frames_errors_instead_of_hanging() {
    for partition in [Partition::Batch, Partition::Modes] {
        let medium = TransmissionMatrix::sample(71, D_IN, MODES);
        let wrap = |inner| -> Box<dyn Projector + Send> {
            Box::new(Wedge {
                inner,
                sleep_ms: 3000,
            })
        };
        let devices = match partition {
            Partition::Batch => replica_pair(&medium, wrap),
            Partition::Modes => windowed_pair(&medium, wrap),
        };
        let svc = ShardedProjectionService::start(
            devices,
            D_IN,
            ShardServiceConfig {
                max_batch: 16,
                queue_depth: 32,
                lane_depth: 4,
                partition,
                failover: FailoverConfig {
                    enabled: true,
                    stall_ms: 50,
                    ..FailoverConfig::default()
                },
                ..Default::default()
            },
            Registry::new(),
        )
        .unwrap();
        let client = svc.client();
        // First request occupies the wedged worker; the second's shard-1
        // part waits in the lane behind it.
        let waiters: Vec<_> = (0..2u64)
            .map(|i| {
                let reply = client.submit(ternary_batch(8, D_IN, 700 + i)).unwrap();
                let h = thread::spawn(move || wait_bounded(reply, 30));
                thread::sleep(Duration::from_millis(100));
                h
            })
            .collect();
        thread::sleep(Duration::from_millis(100));
        svc.shutdown();
        for (i, h) in waiters.into_iter().enumerate() {
            let outcome = h.join().unwrap();
            let err = match outcome {
                Some(Err(e)) => e,
                Some(Ok(_)) => panic!("{partition:?} req {i}: wedged frame returned Ok"),
                None => continue, // dropped sender: also a clean unblock
            };
            assert!(
                err.contains("shut down"),
                "{partition:?} req {i}: unexpected error '{err}'"
            );
        }
    }
}

/// A shard stalled mid-call trips on the scheduler's stall timeout: the
/// wedged frame's clients error (bounded, not hung), later frames route
/// to the survivor and stay exact.
#[test]
fn stalled_shard_trips_and_later_frames_route_to_survivors() {
    let medium = TransmissionMatrix::sample(72, D_IN, MODES);
    let devices = replica_pair(&medium, |inner| {
        Box::new(Wedge {
            inner,
            sleep_ms: 5000,
        })
    });
    let reg = Registry::new();
    let svc = ShardedProjectionService::start(
        devices,
        D_IN,
        ShardServiceConfig {
            max_batch: 16,
            queue_depth: 32,
            lane_depth: 4,
            partition: Partition::Batch,
            failover: FailoverConfig {
                enabled: true,
                trip_errors: 1000, // stall path only
                stall_ms: 100,
                probation_ms: 600_000,
            },
            ..Default::default()
        },
        reg.clone(),
    )
    .unwrap();
    let client = svc.client();
    let first = client.submit(ternary_batch(8, D_IN, 710)).unwrap();
    // Let the wedged worker pick up its part, then age past stall_ms.
    thread::sleep(Duration::from_millis(300));
    // Scheduling the next frame runs the health pass: trip + force-fail.
    let e = ternary_batch(8, D_IN, 711);
    let (p1, p2) = client.project(e.clone()).unwrap();
    assert_eq!(p1, matmul(&e, &medium.b_re));
    assert_eq!(p2, matmul(&e, &medium.b_im));
    let err = match wait_bounded(first, 30) {
        Some(Err(e)) => e,
        other => panic!("wedged frame should error, got {other:?}"),
    };
    assert!(err.contains("stalled"), "unexpected error '{err}'");
    let snap = reg.snapshot();
    assert!(snap["service_failovers"] >= 1.0);
    assert_eq!(snap["service_shard1_state"], 1.0, "shard 1 tripped");
    svc.shutdown();
}

/// Error-burst trip under the batch partition without a rebuild
/// factory: the frame that hit the fault errors, every later frame is
/// served exactly by the survivor.
#[test]
fn error_tripped_batch_shard_drains_onto_survivor() {
    let medium = TransmissionMatrix::sample(73, D_IN, MODES);
    let devices = replica_pair(&medium, |inner| {
        Box::new(Flaky {
            inner,
            fail_remaining: Arc::new(AtomicUsize::new(usize::MAX)),
        })
    });
    let reg = Registry::new();
    let svc = ShardedProjectionService::start(
        devices,
        D_IN,
        ShardServiceConfig {
            max_batch: 16,
            queue_depth: 32,
            lane_depth: 4,
            partition: Partition::Batch,
            failover: FailoverConfig {
                enabled: true,
                trip_errors: 1,
                stall_ms: 600_000,
                probation_ms: 600_000,
            },
            ..Default::default()
        },
        reg.clone(),
    )
    .unwrap();
    let client = svc.client();
    let first = client.submit(ternary_batch(8, D_IN, 720)).unwrap();
    match wait_bounded(first, 30) {
        Some(Err(e)) => assert!(e.contains("injected device fault"), "{e}"),
        other => panic!("faulted frame should error, got {other:?}"),
    }
    for i in 0..5u64 {
        let e = ternary_batch(8, D_IN, 721 + i);
        let (p1, p2) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re), "survivor frame {i}");
        assert_eq!(p2, matmul(&e, &medium.b_im), "survivor frame {i}");
    }
    let snap = reg.snapshot();
    assert!(snap["service_failovers"] >= 1.0);
    assert_eq!(snap["service_shard1_state"], 1.0);
    svc.shutdown();
}

/// Modes-partition recovery: a tripped mode window has no stand-in on
/// the survivors, so the worker rebuilds its own device through the
/// factory and re-enters on probation — after which results are exact
/// against the full medium again.
#[test]
fn modes_shard_heals_through_rebuild_factory_and_probation() {
    let medium = TransmissionMatrix::sample(74, D_IN, MODES);
    let devices = windowed_pair(&medium, |inner| {
        Box::new(Flaky {
            inner,
            fail_remaining: Arc::new(AtomicUsize::new(1)),
        })
    });
    let medium2 = medium.clone();
    let rebuild: ShardRebuild = Arc::new(move |shard| {
        let mut rebuilt = Topology::homogeneous(DeviceKind::Digital, 2)
            .with_partition(Partition::Modes)
            .build_devices(
                OpuParams::default(),
                &Medium::Dense(medium2.clone()),
                0,
                &Registry::new(),
            )?;
        anyhow::ensure!(shard < rebuilt.len(), "no shard {shard}");
        Ok(rebuilt.swap_remove(shard))
    });
    let reg = Registry::new();
    let svc = ShardedProjectionService::start_full(
        devices,
        vec![1, 1],
        D_IN,
        ShardServiceConfig {
            max_batch: 16,
            queue_depth: 32,
            lane_depth: 4,
            partition: Partition::Modes,
            failover: FailoverConfig {
                enabled: true,
                trip_errors: 1,
                stall_ms: 600_000,
                probation_ms: 1,
            },
            ..Default::default()
        },
        reg.clone(),
        Some(rebuild),
    )
    .unwrap();
    let client = svc.client();
    let first = client.submit(ternary_batch(8, D_IN, 730)).unwrap();
    match wait_bounded(first, 30) {
        Some(Err(e)) => assert!(e.contains("injected device fault"), "{e}"),
        other => panic!("faulted frame should error, got {other:?}"),
    }
    // The worker tripped, rebuilt in place and re-entered on probation;
    // the next frames run on both mode windows and are exact.
    for i in 0..3u64 {
        let e = ternary_batch(8, D_IN, 731 + i);
        let (p1, p2) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re), "healed frame {i}");
        assert_eq!(p2, matmul(&e, &medium.b_im), "healed frame {i}");
    }
    let snap = reg.snapshot();
    assert_eq!(snap["service_failovers"], 1.0);
    assert_eq!(snap["service_shard1_state"], 0.0, "healed to HEALTHY");
    svc.shutdown();
}

/// Adaptive weights shift scheduled rows toward the faster replica —
/// visibly in `service_replans`, the effective-weight gauges and the
/// slot accounts — while every result stays exact.
#[test]
fn adaptive_weights_shift_rows_toward_the_faster_shard() {
    let medium = TransmissionMatrix::sample(75, D_IN, MODES);
    let devices = replica_pair(&medium, |inner| {
        Box::new(Throttled {
            inner,
            us_per_row: 400,
        })
    });
    let reg = Registry::new();
    let svc = ShardedProjectionService::start(
        devices,
        D_IN,
        ShardServiceConfig {
            max_batch: 16,
            queue_depth: 32,
            lane_depth: 4,
            partition: Partition::Batch,
            adapt: AdaptConfig {
                enabled: true,
                replan_every: 2,
                alpha: 0.5,
                hysteresis: 0.01,
            },
            ..Default::default()
        },
        reg.clone(),
    )
    .unwrap();
    let client = svc.client();
    for i in 0..12u64 {
        let e = ternary_batch(8, D_IN, 740 + i);
        let (p1, p2) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re), "adaptive frame {i}");
        assert_eq!(p2, matmul(&e, &medium.b_im), "adaptive frame {i}");
    }
    let snap = reg.snapshot();
    assert!(snap["service_replans"] >= 1.0, "no re-plan committed: {snap:?}");
    assert!(
        snap["service_shard0_eff_weight"] > snap["service_shard1_eff_weight"],
        "weights did not shift toward the fast shard: {snap:?}"
    );
    assert!(
        snap["service_shard0_slots"] > snap["service_shard1_slots"],
        "slots did not follow the plan: {snap:?}"
    );
    assert!(
        snap.contains_key("service_shard1_rate_ewma"),
        "windowed rate gauge missing: {snap:?}"
    );
    svc.shutdown();
}

/// Admission control: a client that exhausts its token bucket gets a
/// bounded-wait error (counted in `service_admission_throttled`), a
/// fresh client handle has its own budget, and the end-to-end latency
/// histogram lands in the snapshot with p50/p95/p99.
#[test]
fn admission_throttles_per_client_and_latency_lands_in_snapshot() {
    let medium = TransmissionMatrix::sample(76, D_IN, MODES);
    let devices: Vec<Box<dyn Projector + Send>> =
        vec![Box::new(DigitalProjector::new(medium.clone()))];
    let reg = Registry::new();
    let svc = ShardedProjectionService::start(
        devices,
        D_IN,
        ShardServiceConfig {
            max_batch: 16,
            queue_depth: 32,
            lane_depth: 4,
            partition: Partition::Batch,
            admission: AdmissionConfig {
                enabled: true,
                rate_fps: 10.0,
                burst: 8.0,
                max_wait_ms: 1,
            },
            ..Default::default()
        },
        reg.clone(),
    )
    .unwrap();
    let client = svc.client();
    let e = ternary_batch(8, D_IN, 750);
    let (p1, _) = client.project(e.clone()).unwrap();
    assert_eq!(p1, matmul(&e, &medium.b_re));
    // The burst is spent; at 10 fps the next 8 rows are ~800 ms away,
    // far past the 1 ms wait budget.
    let err = client.project(ternary_batch(8, D_IN, 751)).unwrap_err();
    assert!(format!("{err:#}").contains("rate budget"), "{err:#}");
    // A fresh handle is a different client with its own bucket.
    let other = svc.client();
    let e2 = ternary_batch(8, D_IN, 752);
    let (q1, _) = other.project(e2.clone()).unwrap();
    assert_eq!(q1, matmul(&e2, &medium.b_re));
    let snap = reg.snapshot();
    assert!(snap["service_admission_throttled"] >= 1.0);
    for key in ["service_latency_p50", "service_latency_p95", "service_latency_p99"] {
        assert!(snap.contains_key(key), "missing {key}: {snap:?}");
    }
    svc.shutdown();
}
