//! Shared fixtures for the integration tests.  Each test binary
//! compiles its own copy via `mod common;`, so items unused by one
//! binary are expected — hence the allow.
#![allow(dead_code)]

use litl::optics::OpuParams;
use litl::tensor::Tensor;
use litl::util::rng::Pcg64;

/// AOT artifacts come from the python toolchain (`make artifacts`).
/// They are not present in the offline build image, so artifact-bound
/// integration tests skip (rather than fail) without them.
pub fn artifacts_available() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/manifest.json not found (run `make artifacts`)");
    }
    ok
}

/// Deterministic `[rows, cols]` ternary frame batch (the SLM's alphabet).
pub fn ternary_batch(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut rng = Pcg64::seeded(seed);
    let data = (0..rows * cols)
        .map(|_| (rng.next_below(3) as i64 - 1) as f32)
        .collect();
    Tensor::from_vec(&[rows, cols], data)
}

/// Noise-free OPU parameters: shot noise off (`n_ph <= 0` skips the
/// draw entirely) and zero read noise — the deterministic-physics
/// configuration used by exact-parity tests.
pub fn noiseless_params() -> OpuParams {
    OpuParams {
        n_ph: -1.0,
        read_sigma: 0.0,
        ..OpuParams::default()
    }
}
