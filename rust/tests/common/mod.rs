//! Shared fixtures for the integration tests.  Each test binary
//! compiles its own copy via `mod common;`, so items unused by one
//! binary are expected — hence the allow.
#![allow(dead_code)]

use litl::optics::OpuParams;
use litl::tensor::Tensor;
use litl::util::rng::Pcg64;

/// AOT artifacts come from the python toolchain (`make artifacts`).
/// They are not present in the offline build image, so artifact-bound
/// integration tests skip (rather than fail) without them.
pub fn artifacts_available() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/manifest.json not found (run `make artifacts`)");
    }
    ok
}

/// Deterministic `[rows, cols]` ternary frame batch (the SLM's alphabet).
pub fn ternary_batch(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut rng = Pcg64::seeded(seed);
    let data = (0..rows * cols)
        .map(|_| (rng.next_below(3) as i64 - 1) as f32)
        .collect();
    Tensor::from_vec(&[rows, cols], data)
}

/// Noise-free OPU parameters: shot noise off (`n_ph <= 0` skips the
/// draw entirely) and zero read noise — the deterministic-physics
/// configuration used by exact-parity tests.
pub fn noiseless_params() -> OpuParams {
    OpuParams {
        n_ph: -1.0,
        read_sigma: 0.0,
        ..OpuParams::default()
    }
}

use litl::config::Partition;
use litl::coordinator::farm::ProjectorFarm;
use litl::coordinator::projector::Projector;
use litl::coordinator::topology::{DeviceKind, Topology};
use litl::metrics::Registry;
use litl::optics::stream::Medium;

/// Equal-weight homogeneous farm via the `Topology` build path — the
/// post-PR-4 spelling of the legacy `optical_partitioned_backed` /
/// `digital_partitioned_backed` constructors (bit-identical to them).
pub fn topology_farm(
    kind: DeviceKind,
    params: OpuParams,
    medium: &Medium,
    noise_seed: u64,
    shards: usize,
    partition: Partition,
    registry: Registry,
) -> anyhow::Result<ProjectorFarm> {
    Topology::homogeneous(kind, shards)
        .with_partition(partition)
        .with_backing_of(medium)
        .build_farm(params, medium, noise_seed, registry)
}

/// Equal-weight homogeneous shard devices via the `Topology` build path
/// (the post-PR-4 `optical_shard_devices_backed`).
pub fn topology_devices(
    kind: DeviceKind,
    params: OpuParams,
    medium: &Medium,
    noise_seed: u64,
    shards: usize,
    partition: Partition,
) -> anyhow::Result<Vec<Box<dyn Projector + Send>>> {
    Topology::homogeneous(kind, shards)
        .with_partition(partition)
        .with_backing_of(medium)
        .build_devices(params, medium, noise_seed, &Registry::new())
}

use litl::tensor::matmul;

/// Fixed random linear task (stable prototype seed), sized to
/// `layers[0]` inputs and `layers.last()` classes — the shared trainer
/// fixture for the ensemble/topology integration tests.
pub fn task_batch(seed: u64, b: usize, layers: &[usize]) -> (Tensor, Tensor) {
    let d = layers[0];
    let classes = *layers.last().unwrap();
    let mut proto_rng = Pcg64::new(1234, 0);
    let proto = Tensor::randn(&[classes, d], &mut proto_rng, 1.0);
    let mut rng = Pcg64::seeded(seed);
    let x = Tensor::randn(&[b, d], &mut rng, 1.0);
    let mut pt = Tensor::zeros(&[d, classes]);
    for i in 0..classes {
        for j in 0..d {
            *pt.at_mut(j, i) = proto.at(i, j);
        }
    }
    let scores = matmul(&x, &pt);
    let mut yoh = Tensor::zeros(&[b, classes]);
    for r in 0..b {
        let row = scores.row(r);
        let mut best = 0;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        *yoh.at_mut(r, best) = 1.0;
    }
    (x, yoh)
}
