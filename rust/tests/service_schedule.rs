//! Shard-aware projection service: scheduler determinism, partition
//! parity, and shutdown-drain guarantees.
//!
//! The service's contract (see `coordinator::service` docs): for a fixed
//! submission order the frame-slot schedule is deterministic, scheduled
//! results are bitwise identical to the device-agnostic path at
//! `shards = 1`, and at any shard count both partition policies
//! reproduce the single-device reference — bitwise for digital shards,
//! to fp/ADC tolerance for noiseless optics.  Shutdown drains all
//! in-flight work: nothing submitted before `shutdown()` is lost.

use litl::config::Partition;
use litl::coordinator::projector::{NativeOpticalProjector, Projector};
use litl::coordinator::topology::DeviceKind;
use litl::coordinator::service::{
    ProjectionService, ServiceConfig, ShardServiceConfig, ShardedProjectionService,
};
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::OpuParams;
use litl::tensor::{matmul, Tensor};
use litl::util::check::{forall, PairG, UsizeIn};

mod common;
use common::{noiseless_params, ternary_batch, topology_devices, topology_farm};
use litl::optics::stream::Medium;

const D_IN: usize = 10;

/// Mixed request sizes for one fixed submission sequence (all ≤ the
/// max_batch used below, several summing past it to force flushes).
const SIZES: &[usize] = &[1, 3, 2, 5, 8, 1, 4, 7, 2, 6];

fn sharded_service(
    medium: &TransmissionMatrix,
    shards: usize,
    partition: Partition,
    registry: Registry,
) -> ShardedProjectionService {
    let devices = topology_devices(
        DeviceKind::Digital,
        OpuParams::default(),
        &Medium::Dense(medium.clone()),
        0,
        shards,
        partition,
    )
    .unwrap();
    ShardedProjectionService::start(
        devices,
        D_IN,
        ShardServiceConfig {
            max_batch: 16,
            queue_depth: 64,
            lane_depth: 4,
            partition,
            ..Default::default()
        },
        registry,
    )
    .unwrap()
}

/// Scheduler determinism / digital parity property: for a fixed
/// submission order and shard counts 1/2/4/7, both partition policies
/// return results bitwise equal to the single-device reference (the
/// digital projection is exact, so this pins the scheduler's packing,
/// splitting and gather — any mis-slice or reorder breaks bit equality).
#[test]
fn scheduler_is_deterministic_and_exact_for_digital_shards() {
    let medium = TransmissionMatrix::sample(61, D_IN, 28);
    for partition in [Partition::Modes, Partition::Batch] {
        for shards in [1usize, 2, 4, 7] {
            let svc = sharded_service(&medium, shards, partition, Registry::new());
            let client = svc.client();
            let replies: Vec<_> = SIZES
                .iter()
                .enumerate()
                .map(|(i, &b)| {
                    let e = ternary_batch(b, D_IN, 300 + i as u64);
                    (e.clone(), client.submit(e).unwrap())
                })
                .collect();
            for (i, (e, reply)) in replies.into_iter().enumerate() {
                let (p1, p2) = reply.wait().unwrap().unwrap();
                assert_eq!(
                    p1,
                    matmul(&e, &medium.b_re),
                    "{partition:?} shards={shards} req {i}"
                );
                assert_eq!(
                    p2,
                    matmul(&e, &medium.b_im),
                    "{partition:?} shards={shards} req {i}"
                );
            }
            svc.shutdown();
        }
    }
}

/// Same schedule through noiseless optical shards: physics is
/// deterministic and row/column-local, so both partitions agree with the
/// single noiseless device to fp/ADC tolerance at every shard count.
#[test]
fn noiseless_optical_schedule_matches_single_device_within_tolerance() {
    let medium = TransmissionMatrix::sample(62, D_IN, 28);
    for partition in [Partition::Modes, Partition::Batch] {
        for shards in [1usize, 2, 4, 7] {
            let devices = topology_devices(
                DeviceKind::Optical,
                noiseless_params(),
                &Medium::Dense(medium.clone()),
                5,
                shards,
                partition,
            )
            .unwrap();
            let svc = ShardedProjectionService::start(
                devices,
                D_IN,
                ShardServiceConfig {
                    max_batch: 16,
                    partition,
                    ..Default::default()
                },
                Registry::new(),
            )
            .unwrap();
            let client = svc.client();
            let mut oracle =
                NativeOpticalProjector::new(noiseless_params(), medium.clone(), 5);
            // Submit-and-wait: each request is scheduled alone, so the
            // oracle sees the exact same per-request frame sequences.
            for (i, &b) in SIZES.iter().enumerate() {
                let e = ternary_batch(b, D_IN, 400 + i as u64);
                let (p1, p2) = client.project(e.clone()).unwrap();
                let (w1, w2) = oracle.project(&e).unwrap();
                assert!(
                    p1.max_abs_diff(&w1) < 1e-5,
                    "{partition:?} shards={shards} req {i}: re diff {}",
                    p1.max_abs_diff(&w1)
                );
                assert!(
                    p2.max_abs_diff(&w2) < 1e-5,
                    "{partition:?} shards={shards} req {i}: im diff {}",
                    p2.max_abs_diff(&w2)
                );
            }
            svc.shutdown();
        }
    }
}

/// The `shards = 1` bitwise guarantee, *with noise on*: the scheduled
/// path, the device-agnostic path and the raw device produce identical
/// bits — same packing (one request per frame via submit-and-wait), same
/// medium, same noise stream, same draws.
#[test]
fn one_shard_schedule_is_bitwise_the_device_agnostic_path() {
    let medium = TransmissionMatrix::sample(63, D_IN, 20);
    let seed = 909u64;
    let requests: Vec<Tensor> = SIZES
        .iter()
        .enumerate()
        .map(|(i, &b)| ternary_batch(b, D_IN, 500 + i as u64))
        .collect();

    // (a) raw device.
    let mut raw =
        NativeOpticalProjector::new(OpuParams::default(), medium.clone(), seed);
    let want: Vec<(Tensor, Tensor)> =
        requests.iter().map(|e| raw.project(e).unwrap()).collect();

    // (b) device-agnostic service.
    let svc = ProjectionService::start(
        Box::new(NativeOpticalProjector::new(
            OpuParams::default(),
            medium.clone(),
            seed,
        )),
        D_IN,
        ServiceConfig::default(),
        Registry::new(),
    );
    let client = svc.client();
    for (e, (w1, w2)) in requests.iter().zip(&want) {
        let (p1, p2) = client.project(e.clone()).unwrap();
        assert_eq!(&p1, w1, "device-agnostic path diverged");
        assert_eq!(&p2, w2);
    }
    svc.shutdown();

    // (c)+(d) shard-aware service at shards=1, both partitions.
    for partition in [Partition::Modes, Partition::Batch] {
        let devices = topology_devices(
            DeviceKind::Optical,
            OpuParams::default(),
            &Medium::Dense(medium.clone()),
            seed,
            1,
            partition,
        )
        .unwrap();
        let svc = ShardedProjectionService::start(
            devices,
            D_IN,
            ShardServiceConfig {
                partition,
                ..Default::default()
            },
            Registry::new(),
        )
        .unwrap();
        let client = svc.client();
        for (e, (w1, w2)) in requests.iter().zip(&want) {
            let (p1, p2) = client.project(e.clone()).unwrap();
            assert_eq!(&p1, w1, "{partition:?} scheduled path diverged");
            assert_eq!(&p2, w2);
        }
        svc.shutdown();
    }
}

/// Random (shards, modes) pairs: the scheduled digital projection stays
/// exact for any partition geometry, including modes not divisible by
/// the shard count and frames smaller than the shard count.
#[test]
fn prop_scheduled_digital_parity() {
    let gen = PairG(UsizeIn(1, 8), UsizeIn(8, 40));
    forall("scheduled digital parity", &gen, |&(shards, modes)| {
        if shards > modes {
            return true; // mode partition rejects by construction
        }
        let medium =
            TransmissionMatrix::sample((shards * 97 + modes) as u64, D_IN, modes);
        for partition in [Partition::Modes, Partition::Batch] {
            let svc = sharded_service(&medium, shards, partition, Registry::new());
            let client = svc.client();
            let e = ternary_batch(1 + (modes + shards) % 9, D_IN, modes as u64);
            let ok = match client.project(e.clone()) {
                Ok((p1, p2)) => {
                    p1 == matmul(&e, &medium.b_re) && p2 == matmul(&e, &medium.b_im)
                }
                Err(_) => false,
            };
            svc.shutdown();
            if !ok {
                return false;
            }
        }
        true
    });
}

/// Shutdown drains in-flight work: every request submitted before
/// `shutdown()` is answered (not dropped), for the device-agnostic AND
/// the shard-aware service.  The submission total exceeds several
/// max_batch frames, so the drain crosses multiple scheduled frames.
#[test]
fn shutdown_drains_pending_requests_before_join() {
    let medium = TransmissionMatrix::sample(64, D_IN, 24);

    // Device-agnostic path.
    let svc = ProjectionService::start(
        Box::new(litl::coordinator::projector::DigitalProjector::new(
            medium.clone(),
        )),
        D_IN,
        ServiceConfig {
            max_batch: 8,
            queue_depth: 64,
        },
        Registry::new(),
    );
    let client = svc.client();
    let pending: Vec<_> = (0..20)
        .map(|i| {
            let e = ternary_batch(3, D_IN, 600 + i as u64);
            (e.clone(), client.submit(e).unwrap())
        })
        .collect();
    svc.shutdown();
    for (i, (e, reply)) in pending.into_iter().enumerate() {
        let got = reply.wait();
        let (p1, _) = got
            .unwrap_or_else(|| panic!("request {i} dropped at shutdown"))
            .unwrap_or_else(|e| panic!("request {i} errored at shutdown: {e}"));
        assert_eq!(p1, matmul(&e, &medium.b_re), "request {i}");
    }

    // Shard-aware path, both partitions.
    for partition in [Partition::Modes, Partition::Batch] {
        let reg = Registry::new();
        let farm = topology_farm(
            DeviceKind::Digital,
            OpuParams::default(),
            &Medium::Dense(medium.clone()),
            0,
            4,
            partition,
            Registry::new(),
        )
        .unwrap();
        let svc = ShardedProjectionService::over_farm(
            farm,
            D_IN,
            ShardServiceConfig {
                max_batch: 8,
                queue_depth: 64,
                lane_depth: 2,
                partition,
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
        let client = svc.client();
        let pending: Vec<_> = (0..20)
            .map(|i| {
                let e = ternary_batch(3, D_IN, 700 + i as u64);
                (e.clone(), client.submit(e).unwrap())
            })
            .collect();
        svc.shutdown();
        for (i, (e, reply)) in pending.into_iter().enumerate() {
            let got = reply.wait();
            let (p1, _) = got
                .unwrap_or_else(|| {
                    panic!("{partition:?}: request {i} dropped at shutdown")
                })
                .unwrap_or_else(|e| {
                    panic!("{partition:?}: request {i} errored at shutdown: {e}")
                });
            assert_eq!(p1, matmul(&e, &medium.b_re), "{partition:?} request {i}");
        }
        // Everything drained is also accounted: 60 rows total.
        assert_eq!(reg.snapshot()["service_frames"], 60.0);
        let per_shard = reg.sum_counters("service_shard", "_frames");
        match partition {
            Partition::Modes => assert_eq!(per_shard, 60.0 * 4.0),
            Partition::Batch => assert_eq!(per_shard, 60.0),
        }
    }
}

/// Tracing is observation only: replaying the pinned noisy-optical
/// schedule under a `--trace full` session returns bitwise identical
/// quadratures to the untraced replay — same packing, same (shard,
/// slot) assignment, same noise draws.  (Digital would pass trivially
/// since its projection is exact under any schedule; noise makes the
/// bits a function of the schedule itself.)  Balance/breakdown
/// assertions live in `trace_spans.rs`, which serializes on the
/// process-global session; here concurrent sibling tests may emit into
/// our session, so we only pin the projection bits.
#[test]
fn full_tracing_leaves_the_pinned_schedule_bitwise_unchanged() {
    use litl::metrics::trace::{TraceClock, TraceLevel, TraceSession};
    let medium = TransmissionMatrix::sample(66, D_IN, 28);
    let run = |traced: bool| -> Vec<(Tensor, Tensor)> {
        let session = traced
            .then(|| TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 1 << 16));
        let mut out = Vec::new();
        for partition in [Partition::Modes, Partition::Batch] {
            for shards in [1usize, 3] {
                let devices = topology_devices(
                    DeviceKind::Optical,
                    OpuParams::default(),
                    &Medium::Dense(medium.clone()),
                    9,
                    shards,
                    partition,
                )
                .unwrap();
                let svc = ShardedProjectionService::start(
                    devices,
                    D_IN,
                    ShardServiceConfig {
                        partition,
                        ..Default::default()
                    },
                    Registry::new(),
                )
                .unwrap();
                let client = svc.client();
                for (i, &b) in SIZES.iter().enumerate() {
                    let e = ternary_batch(b, D_IN, 900 + i as u64);
                    out.push(client.project(e).unwrap());
                }
                svc.shutdown();
            }
        }
        if let Some(s) = session {
            let report = s.finish();
            assert!(!report.spans.is_empty(), "traced replay recorded nothing");
        }
        out
    };
    let untraced = run(false);
    let traced = run(true);
    assert_eq!(untraced, traced, "tracing changed projection bits");
}

/// Quick (tier-1) concurrency check on a 4-shard service: concurrent
/// clients each get their own exact answers, and the per-shard metrics
/// explain the client-observed totals.  The heavyweight soak lives in
/// `service_ensemble.rs` behind `--ignored`.
#[test]
fn concurrent_clients_on_four_shards_route_correctly() {
    let medium = TransmissionMatrix::sample(65, D_IN, 32);
    for partition in [Partition::Modes, Partition::Batch] {
        let reg = Registry::new();
        let svc = sharded_service(&medium, 4, partition, reg.clone());
        let handles: Vec<_> = (0..6)
            .map(|c| {
                let client = svc.client();
                let medium = medium.clone();
                std::thread::spawn(move || {
                    let mut rows = 0usize;
                    for j in 0..5u64 {
                        let b = 1 + ((c as u64 + j) % 4) as usize;
                        let e = ternary_batch(b, D_IN, 800 + c as u64 * 50 + j);
                        let (p1, p2) = client.project(e.clone()).unwrap();
                        assert_eq!(p1, matmul(&e, &medium.b_re), "client {c} req {j}");
                        assert_eq!(p2, matmul(&e, &medium.b_im), "client {c} req {j}");
                        rows += b;
                    }
                    rows
                })
            })
            .collect();
        let total_rows: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_frames"], total_rows as f64, "{partition:?}");
        let per_shard_frames = reg.sum_counters("service_shard", "_frames");
        let per_shard_slots = reg.sum_counters("service_shard", "_slots");
        match partition {
            Partition::Modes => {
                assert_eq!(per_shard_frames, (total_rows * 4) as f64);
                assert_eq!(per_shard_slots, (total_rows * 4) as f64);
            }
            Partition::Batch => {
                assert_eq!(per_shard_frames, total_rows as f64);
                assert_eq!(per_shard_slots, total_rows as f64);
            }
        }
    }
}
