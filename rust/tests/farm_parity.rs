//! Farm ↔ single-device parity properties.
//!
//! The refactor's contract: a [`ProjectorFarm`] over any shard count is
//! observably the same *projection* as one device over the equivalent
//! stacked medium — exactly for digital shards, to fp/ADC tolerance for
//! noiseless optical shards — and its time/energy accounting is the
//! per-shard sum.  Shard counts 2, 4 and 7 (co-prime with typical mode
//! counts, exercising the unbalanced-remainder path) are pinned, and a
//! property sweep draws random (shards, modes, batch) triples.

use litl::config::Partition;
use litl::coordinator::farm::ProjectorFarm;
use litl::coordinator::projector::{DigitalProjector, NativeOpticalProjector, Projector};
use litl::coordinator::topology::DeviceKind;
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::Medium;
use litl::optics::OpuParams;
use litl::tensor::{matmul, Tensor};
use litl::util::check::{forall, PairG, UsizeIn};

mod common;
use common::{noiseless_params, ternary_batch, topology_farm};

/// Equal-weight optical farm through the unified `Topology` build path.
fn optical_farm(
    params: OpuParams,
    medium: &TransmissionMatrix,
    noise_seed: u64,
    shards: usize,
) -> anyhow::Result<ProjectorFarm> {
    topology_farm(
        DeviceKind::Optical,
        params,
        &Medium::Dense(medium.clone()),
        noise_seed,
        shards,
        Partition::Modes,
        Registry::new(),
    )
}

/// Equal-weight digital farm through the unified `Topology` build path.
fn digital_farm(medium: &TransmissionMatrix, shards: usize) -> anyhow::Result<ProjectorFarm> {
    topology_farm(
        DeviceKind::Digital,
        OpuParams::default(),
        &Medium::Dense(medium.clone()),
        0,
        shards,
        Partition::Modes,
        Registry::new(),
    )
}

#[test]
fn digital_farm_matches_stacked_medium_at_pinned_shard_counts() {
    let medium = TransmissionMatrix::sample(31, 10, 52);
    let e = ternary_batch(8, 10, 1);
    // The "equivalent stacked medium": concat of the farm's shard slices
    // must BE the medium, and a single device over it is the oracle.
    for shards in [2usize, 4, 7] {
        let stacked = TransmissionMatrix::concat_modes(&medium.split_modes(shards));
        assert_eq!(stacked.b_re, medium.b_re);
        let mut oracle = DigitalProjector::new(stacked);
        let (want1, want2) = oracle.project(&e).unwrap();
        let mut farm = digital_farm(&medium, shards).unwrap();
        let (p1, p2) = farm.project(&e).unwrap();
        assert_eq!(p1, want1, "{shards} shards");
        assert_eq!(p2, want2, "{shards} shards");
    }
}

#[test]
fn optical_farm_matches_stacked_medium_at_pinned_shard_counts() {
    let medium = TransmissionMatrix::sample(32, 10, 52);
    let e = ternary_batch(6, 10, 2);
    let mut oracle = NativeOpticalProjector::new(noiseless_params(), medium.clone(), 3);
    let (want1, want2) = oracle.project(&e).unwrap();
    for shards in [2usize, 4, 7] {
        let mut farm = optical_farm(noiseless_params(), &medium, 3, shards).unwrap();
        let (p1, p2) = farm.project(&e).unwrap();
        assert!(
            p1.max_abs_diff(&want1) < 1e-5,
            "{shards} shards: re diff {}",
            p1.max_abs_diff(&want1)
        );
        assert!(
            p2.max_abs_diff(&want2) < 1e-5,
            "{shards} shards: im diff {}",
            p2.max_abs_diff(&want2)
        );
    }
}

/// Random (shards, modes): the digital farm is exactly the stacked
/// projection for any partition, including modes not divisible by the
/// shard count.
#[test]
fn prop_digital_farm_parity() {
    let gen = PairG(UsizeIn(1, 8), UsizeIn(8, 64));
    forall("digital farm parity", &gen, |&(shards, modes)| {
        if shards > modes {
            return true; // rejected by construction; covered elsewhere
        }
        let medium = TransmissionMatrix::sample((shards * 131 + modes) as u64, 10, modes);
        let e = ternary_batch(3, 10, (modes + shards) as u64);
        let want1 = matmul(&e, &medium.b_re);
        let want2 = matmul(&e, &medium.b_im);
        let mut farm = match digital_farm(&medium, shards) {
            Ok(f) => f,
            Err(_) => return false,
        };
        match farm.project(&e) {
            Ok((p1, p2)) => p1 == want1 && p2 == want2,
            Err(_) => false,
        }
    });
}

/// Random shard counts: device-seconds and energy are per-shard sums,
/// and every shard charges the full batch (each virtual camera exposes
/// every sample of its mode range).
#[test]
fn prop_farm_accounting_sums() {
    let gen = PairG(UsizeIn(1, 6), UsizeIn(1, 20));
    forall("farm accounting sums", &gen, |&(shards, batches)| {
        let medium = TransmissionMatrix::sample(7, 10, 30);
        let mut farm =
            optical_farm(OpuParams::default(), &medium, 5, shards).unwrap();
        let b = 4usize;
        for i in 0..batches {
            farm.project(&ternary_batch(b, 10, i as u64)).unwrap();
        }
        let per_shard = (batches * b) as f64 / 1500.0;
        let shard_secs = farm.shard_sim_seconds();
        let sum: f64 = shard_secs.iter().sum();
        let max = shard_secs.iter().cloned().fold(0.0, f64::max);
        (farm.sim_seconds() - sum).abs() < 1e-12
            && (sum - shards as f64 * per_shard).abs() < 1e-9
            && (farm.sim_seconds_wall() - max).abs() < 1e-12
            && (farm.energy_joules() - sum * 30.0).abs() < 1e-9
    });
}

/// The noisy farm stays a faithful random projection: per-shard noise
/// streams change draws, not statistics.  Correlation with the exact
/// projection must match the single-device level.
#[test]
fn noisy_farm_keeps_projection_quality() {
    let medium = TransmissionMatrix::sample(33, 10, 64);
    let e = ternary_batch(16, 10, 9);
    let exact = matmul(&e, &medium.b_re);
    let corr_of = |p: &Tensor| {
        litl::util::stats::correlation(
            &p.data().iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &exact.data().iter().map(|&x| x as f64).collect::<Vec<_>>(),
        )
    };
    let mut single = NativeOpticalProjector::new(OpuParams::default(), medium.clone(), 4);
    let (s1, _) = single.project(&e).unwrap();
    let c_single = corr_of(&s1);
    for shards in [2usize, 4, 7] {
        let mut farm = optical_farm(OpuParams::default(), &medium, 4, shards).unwrap();
        let (p1, _) = farm.project(&e).unwrap();
        let c = corr_of(&p1);
        assert!(c > 0.97, "{shards} shards: correlation {c}");
        assert!(
            (c - c_single).abs() < 0.03,
            "{shards} shards: correlation {c} vs single {c_single}"
        );
    }
}

/// One-shard farm == plain device, bit for bit, including noise draws —
/// the `shards=1` parity guarantee of the refactor.
#[test]
fn one_shard_farm_is_the_single_device() {
    let medium = TransmissionMatrix::sample(34, 10, 40);
    let mut single = NativeOpticalProjector::new(OpuParams::default(), medium.clone(), 21);
    let mut farm = optical_farm(OpuParams::default(), &medium, 21, 1).unwrap();
    for step in 0..5 {
        let e = ternary_batch(4, 10, 100 + step);
        let (s1, s2) = single.project(&e).unwrap();
        let (f1, f2) = farm.project(&e).unwrap();
        assert_eq!(s1, f1, "step {step}");
        assert_eq!(s2, f2, "step {step}");
    }
    assert_eq!(single.sim_seconds(), farm.sim_seconds());
    assert_eq!(single.energy_joules(), farm.energy_joules());
}
