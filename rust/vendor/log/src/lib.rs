//! Offline stand-in for the `log` facade crate.
//!
//! Provides the subset the workspace uses: the five level macros, the
//! [`Log`] trait, [`set_boxed_logger`] / [`set_max_level`], and the
//! [`Level`] / [`LevelFilter`] / [`Metadata`] / [`Record`] types.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Global maximum-verbosity filter.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn as_usize(self) -> usize {
        self as usize
    }
}

/// Metadata about a log record (level + target module).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, as handed to [`Log::log`].
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not part of the public API surface.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level.as_usize() > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    struct CountingLogger(Arc<AtomicU64>);

    impl Log for CountingLogger {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }
        fn log(&self, record: &Record) {
            assert!(!record.target().is_empty());
            let _ = format!("{}", record.args());
            self.0.fetch_add(1, Ordering::SeqCst);
        }
        fn flush(&self) {}
    }

    #[test]
    fn filtering_and_dispatch() {
        let count = Arc::new(AtomicU64::new(0));
        let _ = set_boxed_logger(Box::new(CountingLogger(count.clone())));
        set_max_level(LevelFilter::Info);
        info!("hello {}", 1);
        debug!("filtered out");
        assert_eq!(count.load(Ordering::SeqCst), 1);
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
