//! Offline stand-in for the `anyhow` crate.
//!
//! Implements the subset of the real API this workspace uses: the
//! context-chaining [`Error`] type, the [`Result`] alias, the
//! [`Context`] extension trait for `Result` and `Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros.  Display shows the outermost
//! message; alternate display (`{:#}`) shows the whole chain joined by
//! `": "`, matching upstream behaviour.

use std::fmt;

/// A context-chaining error: a stack of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` with a defaulted error type, like upstream anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// Iterate the chain from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, msg) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {msg}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// Like upstream: `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "inner cause")
    }

    #[test]
    fn display_is_outermost_alternate_is_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("outer context")
            .unwrap_err();
        assert_eq!(e.to_string(), "outer context");
        assert_eq!(format!("{e:#}"), "outer context: inner cause");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<()> {
            ensure!(1 + 1 == 2, "math works");
            bail!("deliberate {}", "failure");
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "deliberate failure");
        let a: Error = anyhow!("x={}", 7);
        assert_eq!(a.to_string(), "x=7");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.with_context(|| "was empty").unwrap_err();
        assert_eq!(e.to_string(), "was empty");
    }
}
