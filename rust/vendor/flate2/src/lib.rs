//! Offline stand-in for the `flate2` crate (subset).
//!
//! * [`Crc`] — streaming CRC-32 (IEEE, the gzip polynomial).
//! * [`read::GzDecoder`] — gzip decompression implementing [`std::io::Read`];
//!   full RFC 1951 inflate (stored, fixed and dynamic Huffman blocks).
//! * [`write::GzEncoder`] — gzip compression implementing
//!   [`std::io::Write`]; emits stored (uncompressed) deflate blocks, which
//!   every inflater (including ours) accepts.
//!
//! The encoder trades ratio for simplicity — correctness and round-trip
//! compatibility are what the workspace needs offline.
#![allow(clippy::needless_range_loop)]

/// Compression level knob (accepted for API compatibility; the stored
/// encoder ignores it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Compression(pub u32);

impl Compression {
    pub fn none() -> Compression {
        Compression(0)
    }
    pub fn fast() -> Compression {
        Compression(1)
    }
    pub fn best() -> Compression {
        Compression(9)
    }
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }
}

const CRC_POLY: u32 = 0xEDB8_8320;

fn crc_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { CRC_POLY ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        table
    })
}

/// Streaming CRC-32 (IEEE).
#[derive(Clone, Debug, Default)]
pub struct Crc {
    state: u32,
    amount: u32,
}

impl Crc {
    pub fn new() -> Crc {
        Crc { state: 0, amount: 0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let table = crc_table();
        let mut c = !self.state;
        for &b in bytes {
            c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
        }
        self.state = !c;
        self.amount = self.amount.wrapping_add(bytes.len() as u32);
    }

    /// CRC of everything fed so far.
    pub fn sum(&self) -> u32 {
        self.state
    }

    /// Total bytes fed (mod 2³²), the gzip ISIZE field.
    pub fn amount(&self) -> u32 {
        self.amount
    }
}

// ---------------------------------------------------------------- inflate

mod inflate {
    use std::io;

    fn err(msg: &str) -> io::Error {
        io::Error::new(io::ErrorKind::InvalidData, format!("inflate: {msg}"))
    }

    struct BitReader<'a> {
        data: &'a [u8],
        pos: usize,
        acc: u32,
        nbits: u32,
    }

    impl<'a> BitReader<'a> {
        fn new(data: &'a [u8]) -> BitReader<'a> {
            BitReader {
                data,
                pos: 0,
                acc: 0,
                nbits: 0,
            }
        }

        /// Take `n` bits (n <= 16), LSB-first as DEFLATE packs them.
        fn take(&mut self, n: u32) -> io::Result<u32> {
            debug_assert!(n <= 16);
            while self.nbits < n {
                let byte = *self
                    .data
                    .get(self.pos)
                    .ok_or_else(|| err("unexpected end of stream"))?;
                self.pos += 1;
                self.acc |= (byte as u32) << self.nbits;
                self.nbits += 8;
            }
            let out = self.acc & ((1u32 << n) - 1);
            self.acc >>= n;
            self.nbits -= n;
            Ok(out)
        }

        fn align_byte(&mut self) {
            let drop = self.nbits % 8;
            self.acc >>= drop;
            self.nbits -= drop;
        }

        fn take_bytes(&mut self, n: usize) -> io::Result<&'a [u8]> {
            debug_assert_eq!(self.nbits % 8, 0);
            // Return buffered whole bytes to the input cursor first.
            let buffered = (self.nbits / 8) as usize;
            self.pos -= buffered;
            self.acc = 0;
            self.nbits = 0;
            if self.pos + n > self.data.len() {
                return Err(err("stored block overruns input"));
            }
            let out = &self.data[self.pos..self.pos + n];
            self.pos += n;
            Ok(out)
        }

        fn consumed(&self) -> usize {
            self.pos - (self.nbits / 8) as usize
        }
    }

    /// Canonical Huffman decoder built from code lengths.
    struct Huffman {
        /// counts[len] = number of codes with that bit length.
        counts: [u16; 16],
        /// Symbols ordered by (length, symbol) — canonical order.
        symbols: Vec<u16>,
    }

    impl Huffman {
        fn new(lengths: &[u8]) -> io::Result<Huffman> {
            let mut counts = [0u16; 16];
            for &l in lengths {
                if l > 15 {
                    return Err(err("code length > 15"));
                }
                counts[l as usize] += 1;
            }
            counts[0] = 0;
            // Over-subscription check.
            let mut left = 1i32;
            for len in 1..16 {
                left <<= 1;
                left -= counts[len] as i32;
                if left < 0 {
                    return Err(err("over-subscribed code"));
                }
            }
            let mut offsets = [0u16; 16];
            for len in 1..15 {
                offsets[len + 1] = offsets[len] + counts[len];
            }
            let mut symbols = vec![0u16; lengths.iter().filter(|&&l| l != 0).count()];
            for (sym, &l) in lengths.iter().enumerate() {
                if l != 0 {
                    symbols[offsets[l as usize] as usize] = sym as u16;
                    offsets[l as usize] += 1;
                }
            }
            Ok(Huffman { counts, symbols })
        }

        fn decode(&self, br: &mut BitReader) -> io::Result<u16> {
            let mut code = 0i32;
            let mut first = 0i32;
            let mut index = 0i32;
            for len in 1..16 {
                code |= br.take(1)? as i32;
                let count = self.counts[len] as i32;
                if code - count < first {
                    return Ok(self.symbols[(index + (code - first)) as usize]);
                }
                index += count;
                first += count;
                first <<= 1;
                code <<= 1;
            }
            Err(err("invalid Huffman code"))
        }
    }

    const LEN_BASE: [u16; 29] = [
        3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
        131, 163, 195, 227, 258,
    ];
    const LEN_EXTRA: [u8; 29] = [
        0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
    ];
    const DIST_BASE: [u16; 30] = [
        1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
        2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
    ];
    const DIST_EXTRA: [u8; 30] = [
        0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
        13, 13,
    ];
    const CLEN_ORDER: [usize; 19] = [
        16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15,
    ];

    fn fixed_tables() -> io::Result<(Huffman, Huffman)> {
        let mut litlen = [0u8; 288];
        for (i, l) in litlen.iter_mut().enumerate() {
            *l = match i {
                0..=143 => 8,
                144..=255 => 9,
                256..=279 => 7,
                _ => 8,
            };
        }
        let dist = [5u8; 30];
        Ok((Huffman::new(&litlen)?, Huffman::new(&dist)?))
    }

    fn dynamic_tables(br: &mut BitReader) -> io::Result<(Huffman, Huffman)> {
        let hlit = br.take(5)? as usize + 257;
        let hdist = br.take(5)? as usize + 1;
        let hclen = br.take(4)? as usize + 4;
        if hlit > 286 || hdist > 30 {
            return Err(err("bad code counts"));
        }
        let mut clen_lengths = [0u8; 19];
        for &slot in CLEN_ORDER.iter().take(hclen) {
            clen_lengths[slot] = br.take(3)? as u8;
        }
        let clen = Huffman::new(&clen_lengths)?;
        let mut lengths = vec![0u8; hlit + hdist];
        let mut at = 0usize;
        while at < lengths.len() {
            let sym = clen.decode(br)?;
            match sym {
                0..=15 => {
                    lengths[at] = sym as u8;
                    at += 1;
                }
                16 => {
                    if at == 0 {
                        return Err(err("repeat with no previous length"));
                    }
                    let prev = lengths[at - 1];
                    let reps = 3 + br.take(2)? as usize;
                    for _ in 0..reps {
                        if at >= lengths.len() {
                            return Err(err("length repeat overflow"));
                        }
                        lengths[at] = prev;
                        at += 1;
                    }
                }
                17 => {
                    let reps = 3 + br.take(3)? as usize;
                    at += reps;
                }
                18 => {
                    let reps = 11 + br.take(7)? as usize;
                    at += reps;
                }
                _ => return Err(err("bad code-length symbol")),
            }
            if at > lengths.len() {
                return Err(err("length repeat overflow"));
            }
        }
        let litlen = Huffman::new(&lengths[..hlit])?;
        let dist = Huffman::new(&lengths[hlit..])?;
        Ok((litlen, dist))
    }

    /// Inflate a raw DEFLATE stream; returns (output, bytes consumed).
    pub fn inflate(data: &[u8]) -> io::Result<(Vec<u8>, usize)> {
        let mut br = BitReader::new(data);
        let mut out: Vec<u8> = Vec::new();
        loop {
            let bfinal = br.take(1)?;
            let btype = br.take(2)?;
            match btype {
                0 => {
                    br.align_byte();
                    let header = br.take_bytes(4)?;
                    let len = u16::from_le_bytes([header[0], header[1]]) as usize;
                    let nlen = u16::from_le_bytes([header[2], header[3]]);
                    if nlen != !(len as u16) {
                        return Err(err("stored block LEN/NLEN mismatch"));
                    }
                    out.extend_from_slice(br.take_bytes(len)?);
                }
                1 | 2 => {
                    let (litlen, dist) = if btype == 1 {
                        fixed_tables()?
                    } else {
                        dynamic_tables(&mut br)?
                    };
                    loop {
                        let sym = litlen.decode(&mut br)?;
                        match sym {
                            0..=255 => out.push(sym as u8),
                            256 => break,
                            257..=285 => {
                                let idx = (sym - 257) as usize;
                                let length = LEN_BASE[idx] as usize
                                    + br.take(LEN_EXTRA[idx] as u32)? as usize;
                                let dsym = dist.decode(&mut br)? as usize;
                                if dsym >= 30 {
                                    return Err(err("bad distance symbol"));
                                }
                                let distance = DIST_BASE[dsym] as usize
                                    + br.take(DIST_EXTRA[dsym] as u32)? as usize;
                                if distance > out.len() {
                                    return Err(err("distance before start of output"));
                                }
                                let start = out.len() - distance;
                                for i in 0..length {
                                    let byte = out[start + i];
                                    out.push(byte);
                                }
                            }
                            _ => return Err(err("bad literal/length symbol")),
                        }
                    }
                }
                _ => return Err(err("reserved block type")),
            }
            if bfinal == 1 {
                break;
            }
        }
        Ok((out, br.consumed()))
    }
}

pub mod read {
    use std::io::{self, Read};

    enum State {
        Pending,
        Ready(Vec<u8>),
        /// Failure is latched (io::Error is not Clone, so keep parts):
        /// retried reads must replay the original cause, not a
        /// misleading "bad magic" from the drained inner reader.
        Failed(io::ErrorKind, String),
    }

    /// Gzip decompressor over any reader (whole-stream, buffered).
    pub struct GzDecoder<R> {
        inner: R,
        state: State,
        at: usize,
    }

    impl<R: Read> GzDecoder<R> {
        pub fn new(inner: R) -> GzDecoder<R> {
            GzDecoder {
                inner,
                state: State::Pending,
                at: 0,
            }
        }

        fn decompress(&mut self) -> io::Result<Vec<u8>> {
            let mut raw = Vec::new();
            self.inner.read_to_end(&mut raw)?;
            let bad = |msg: &str| io::Error::new(io::ErrorKind::InvalidData, msg.to_string());
            if raw.len() < 18 || raw[0] != 0x1f || raw[1] != 0x8b {
                return Err(bad("not a gzip stream (bad magic)"));
            }
            if raw[2] != 8 {
                return Err(bad("unsupported gzip compression method"));
            }
            let flags = raw[3];
            let mut at = 10usize;
            if flags & 0x04 != 0 {
                // FEXTRA
                if at + 2 > raw.len() {
                    return Err(bad("truncated FEXTRA"));
                }
                let xlen = u16::from_le_bytes([raw[at], raw[at + 1]]) as usize;
                at += 2 + xlen;
            }
            for mask in [0x08u8, 0x10] {
                // FNAME, FCOMMENT: zero-terminated strings
                if flags & mask != 0 {
                    while at < raw.len() && raw[at] != 0 {
                        at += 1;
                    }
                    at += 1;
                }
            }
            if flags & 0x02 != 0 {
                at += 2; // FHCRC
            }
            if at >= raw.len() {
                return Err(bad("truncated gzip header"));
            }
            let (out, used) = super::inflate::inflate(&raw[at..])?;
            // The 8-byte CRC32+ISIZE trailer is mandatory: a stream cut
            // after its last deflate block must fail, not silently pass.
            let trailer = at + used;
            if trailer + 8 > raw.len() {
                return Err(bad("truncated gzip stream (missing trailer)"));
            }
            let want_crc = u32::from_le_bytes(raw[trailer..trailer + 4].try_into().unwrap());
            let want_len = u32::from_le_bytes(raw[trailer + 4..trailer + 8].try_into().unwrap());
            let mut crc = super::Crc::new();
            crc.update(&out);
            if crc.sum() != want_crc {
                return Err(bad("gzip CRC mismatch"));
            }
            if want_len != out.len() as u32 {
                return Err(bad("gzip ISIZE mismatch"));
            }
            Ok(out)
        }
    }

    impl<R: Read> Read for GzDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if let State::Pending = self.state {
                self.state = match self.decompress() {
                    Ok(out) => State::Ready(out),
                    Err(e) => State::Failed(e.kind(), e.to_string()),
                };
            }
            match &self.state {
                State::Ready(out) => {
                    let n = buf.len().min(out.len() - self.at);
                    buf[..n].copy_from_slice(&out[self.at..self.at + n]);
                    self.at += n;
                    Ok(n)
                }
                State::Failed(kind, msg) => Err(io::Error::new(*kind, msg.clone())),
                State::Pending => unreachable!("decompression resolved above"),
            }
        }
    }
}

pub mod write {
    use std::io::{self, Write};

    /// Gzip compressor over any writer.  Buffers input and emits stored
    /// (uncompressed) deflate blocks on [`GzEncoder::finish`].
    pub struct GzEncoder<W: Write> {
        inner: Option<W>,
        buf: Vec<u8>,
    }

    impl<W: Write> GzEncoder<W> {
        pub fn new(inner: W, _level: super::Compression) -> GzEncoder<W> {
            GzEncoder {
                inner: Some(inner),
                buf: Vec::new(),
            }
        }

        /// Write header + stored blocks + trailer; returns the writer.
        pub fn finish(mut self) -> io::Result<W> {
            let mut w = self.inner.take().expect("finish called twice");
            // 10-byte header: magic, deflate, no flags, no mtime, OS=unknown.
            w.write_all(&[0x1f, 0x8b, 0x08, 0x00, 0, 0, 0, 0, 0, 0xff])?;
            let mut chunks = self.buf.chunks(0xffff).peekable();
            if self.buf.is_empty() {
                w.write_all(&[0x01, 0x00, 0x00, 0xff, 0xff])?;
            }
            while let Some(chunk) = chunks.next() {
                let bfinal: u8 = if chunks.peek().is_none() { 1 } else { 0 };
                let len = chunk.len() as u16;
                w.write_all(&[bfinal])?;
                w.write_all(&len.to_le_bytes())?;
                w.write_all(&(!len).to_le_bytes())?;
                w.write_all(chunk)?;
            }
            let mut crc = super::Crc::new();
            crc.update(&self.buf);
            w.write_all(&crc.sum().to_le_bytes())?;
            w.write_all(&(self.buf.len() as u32).to_le_bytes())?;
            w.flush()?;
            Ok(w)
        }
    }

    impl<W: Write> Write for GzEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn crc32_known_vector() {
        // CRC-32("123456789") = 0xCBF43926 (IEEE check value).
        let mut crc = Crc::new();
        crc.update(b"123456789");
        assert_eq!(crc.sum(), 0xCBF4_3926);
        assert_eq!(crc.amount(), 9);
    }

    #[test]
    fn crc32_streaming_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut a = Crc::new();
        a.update(&data);
        let mut b = Crc::new();
        for chunk in data.chunks(7) {
            b.update(chunk);
        }
        assert_eq!(a.sum(), b.sum());
    }

    fn roundtrip(payload: &[u8]) -> Vec<u8> {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(payload).unwrap();
        let gz = enc.finish().unwrap();
        let mut dec = read::GzDecoder::new(&gz[..]);
        let mut out = Vec::new();
        dec.read_to_end(&mut out).unwrap();
        out
    }

    #[test]
    fn gzip_roundtrip_small_and_empty() {
        assert_eq!(roundtrip(b"hello gzip world"), b"hello gzip world");
        assert_eq!(roundtrip(b""), b"");
    }

    #[test]
    fn gzip_roundtrip_multi_block() {
        let big: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        assert_eq!(roundtrip(&big), big);
    }

    #[test]
    fn missing_trailer_is_detected() {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(b"payload").unwrap();
        let gz = enc.finish().unwrap();
        let cut = &gz[..gz.len() - 8]; // deflate stream intact, trailer gone
        let mut dec = read::GzDecoder::new(cut);
        let mut out = Vec::new();
        assert!(dec.read_to_end(&mut out).is_err());
    }

    #[test]
    fn corrupt_crc_is_detected() {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(b"payload").unwrap();
        let mut gz = enc.finish().unwrap();
        let n = gz.len();
        gz[n - 6] ^= 0xff; // flip a CRC byte
        let mut dec = read::GzDecoder::new(&gz[..]);
        let mut out = Vec::new();
        assert!(dec.read_to_end(&mut out).is_err());
    }

    #[test]
    fn failure_is_latched_across_reads() {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(b"payload").unwrap();
        let mut gz = enc.finish().unwrap();
        let n = gz.len();
        gz[n - 6] ^= 0xff; // corrupt the CRC
        let mut dec = read::GzDecoder::new(&gz[..]);
        let mut buf = [0u8; 8];
        let first = dec.read(&mut buf).unwrap_err().to_string();
        let second = dec.read(&mut buf).unwrap_err().to_string();
        assert!(first.contains("CRC"), "{first}");
        assert_eq!(first, second, "retries must replay the original cause");
    }

    #[test]
    fn inflate_fixed_huffman_block() {
        // "abc" compressed with fixed-Huffman (hand-assembled):
        // bfinal=1, btype=01; literals 'a','b','c' (codes 0x30+0x61-0x30...),
        // then end-of-block (7 zero bits).
        // Instead of hand-assembling, decode a known-good stream produced
        // by zlib for "aaa...": 0x4B 0x4C 0x84 0x01 0x00 is "aaaa..."?
        // Keep it simple: fixed-block stream for "A" is 0x73 0x04 0x00.
        let (out, _) = super::inflate::inflate(&[0x73, 0x04, 0x00]).unwrap();
        assert_eq!(out, b"A");
    }
}
