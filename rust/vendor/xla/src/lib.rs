//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links libxla/PJRT, which is not present in this
//! offline image.  This stub keeps the whole host-side surface working
//! ([`Literal`] construction, reshape, readback — used by the engine's
//! tensor<->literal conversions and their tests) while gating device
//! execution: [`PjRtClient::compile`] returns a descriptive error, so
//! any code path that would actually run an HLO artifact fails fast
//! with a clear message instead of segfaulting on a missing runtime.
//!
//! The rust-native projector/trainer paths (`litl::optics`,
//! `litl::coordinator::host`, the `ProjectorFarm`) never touch this.

use std::fmt;

/// Error type mirroring xla-rs's stringly errors.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "XLA/PJRT device runtime is not available in this offline build; \
         use the rust-native projector paths (projector=native|digital)"
            .to_string(),
    )
}

/// Parsed HLO module (text payload is retained, not interpreted).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(Error(format!("{path}: not an HLO text module")));
        }
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _text_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _text_len: proto.text.len(),
        }
    }
}

/// PJRT client handle (host metadata only in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Compiled executable handle (unreachable through the stub client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer handle (unreachable through the stub client).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Sealed element-type support (f32 is all the workspace moves).
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

/// Host literal: row-major f32 array (or a tuple of literals).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
            tuple: None,
        }
    }

    /// Reshape to new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
            tuple: None,
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error("array_shape on a tuple literal".to_string()));
        }
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple
            .ok_or_else(|| Error("to_tuple on a non-tuple literal".to_string()))
    }

    /// Build a tuple literal (test/support helper).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            data: Vec::new(),
            tuple: Some(parts),
        }
    }
}

/// Array shape: dimension sizes.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
        // scalar
        let s = Literal::vec1(&[3.5]).reshape(&[]).unwrap();
        assert_eq!(s.array_shape().unwrap().dims(), &[] as &[i64]);
    }

    #[test]
    fn device_paths_are_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let proto = HloModuleProto {
            text: "HloModule x".into(),
        };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0]), Literal::vec1(&[2.0])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1.0]).to_tuple().is_err());
    }
}
