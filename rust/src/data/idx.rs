//! IDX file format loader (the MNIST distribution format).
//!
//! Handles both raw and gzip-compressed files (`train-images-idx3-ubyte`
//! or `train-images-idx3-ubyte.gz`).  Format: big-endian magic
//! `0x0000,dtype,ndim`, then one u32 per dimension, then row-major data.
//! MNIST uses dtype 0x08 (u8), images ndim=3, labels ndim=1.

use std::fs::File;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};
use flate2::read::GzDecoder;

use super::dataset::Dataset;

/// Read a (possibly gzipped) file fully into memory.
fn read_maybe_gz(path: &Path) -> Result<Vec<u8>> {
    // MNIST filenames contain dots that are not extensions
    // ("train-images-idx3-ubyte"), so append ".gz" textually.
    let mut gz_os = path.as_os_str().to_owned();
    gz_os.push(".gz");
    let gz_path = std::path::PathBuf::from(gz_os);
    let (file, gz) = if path.exists() {
        (File::open(path)?, false)
    } else if gz_path.exists() {
        (File::open(&gz_path)?, true)
    } else {
        bail!("neither {} nor {} exists", path.display(), gz_path.display());
    };
    let mut buf = Vec::new();
    if gz {
        GzDecoder::new(file).read_to_end(&mut buf)?;
    } else {
        let mut f = file;
        f.read_to_end(&mut buf)?;
    }
    Ok(buf)
}

fn be_u32(b: &[u8], at: usize) -> u32 {
    u32::from_be_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// Parse an IDX buffer into (dims, payload).
pub fn parse_idx(buf: &[u8]) -> Result<(Vec<usize>, &[u8])> {
    if buf.len() < 4 {
        bail!("IDX: truncated header");
    }
    if buf[0] != 0 || buf[1] != 0 {
        bail!("IDX: bad magic {:02x}{:02x}", buf[0], buf[1]);
    }
    if buf[2] != 0x08 {
        bail!("IDX: only u8 payloads supported (dtype 0x{:02x})", buf[2]);
    }
    let ndim = buf[3] as usize;
    let header = 4 + 4 * ndim;
    if buf.len() < header {
        bail!("IDX: truncated dims");
    }
    let dims: Vec<usize> = (0..ndim)
        .map(|i| be_u32(buf, 4 + 4 * i) as usize)
        .collect();
    let numel: usize = dims.iter().product();
    if buf.len() < header + numel {
        bail!(
            "IDX: payload short: {} < {}",
            buf.len() - header,
            numel
        );
    }
    Ok((dims, &buf[header..header + numel]))
}

fn load_images(path: &Path, limit: usize) -> Result<(usize, Vec<f32>)> {
    let buf = read_maybe_gz(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let (dims, payload) = parse_idx(&buf)?;
    if dims.len() != 3 {
        bail!("images: expected ndim=3, got {dims:?}");
    }
    let (n, h, w) = (dims[0].min(limit), dims[1], dims[2]);
    let dim = h * w;
    let out = payload[..n * dim]
        .iter()
        .map(|&b| b as f32 / 255.0)
        .collect();
    Ok((dim, out))
}

fn load_labels(path: &Path, limit: usize) -> Result<Vec<u8>> {
    let buf = read_maybe_gz(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let (dims, payload) = parse_idx(&buf)?;
    if dims.len() != 1 {
        bail!("labels: expected ndim=1, got {dims:?}");
    }
    Ok(payload[..dims[0].min(limit)].to_vec())
}

/// Load the four MNIST files from `dir`, truncated to the given sizes.
pub fn load_mnist(dir: &str, train_size: usize, test_size: usize) -> Result<Dataset> {
    let d = Path::new(dir);
    let (dim, train_x) =
        load_images(&d.join("train-images-idx3-ubyte"), train_size)?;
    let train_y = load_labels(&d.join("train-labels-idx1-ubyte"), train_size)?;
    let (dim2, test_x) = load_images(&d.join("t10k-images-idx3-ubyte"), test_size)?;
    let test_y = load_labels(&d.join("t10k-labels-idx1-ubyte"), test_size)?;
    if dim != dim2 {
        bail!("train/test image dims differ: {dim} vs {dim2}");
    }
    if train_x.len() / dim != train_y.len() || test_x.len() / dim != test_y.len() {
        bail!("image/label count mismatch");
    }
    Ok(Dataset {
        num_classes: 10,
        dim,
        train_x,
        train_y,
        test_x,
        test_y,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn idx3(n: usize, h: usize, w: usize, fill: u8) -> Vec<u8> {
        let mut buf = vec![0, 0, 0x08, 3];
        for d in [n, h, w] {
            buf.extend_from_slice(&(d as u32).to_be_bytes());
        }
        buf.extend(std::iter::repeat(fill).take(n * h * w));
        buf
    }

    fn idx1(labels: &[u8]) -> Vec<u8> {
        let mut buf = vec![0, 0, 0x08, 1];
        buf.extend_from_slice(&(labels.len() as u32).to_be_bytes());
        buf.extend_from_slice(labels);
        buf
    }

    #[test]
    fn parse_roundtrip() {
        let buf = idx3(2, 3, 3, 7);
        let (dims, payload) = parse_idx(&buf).unwrap();
        assert_eq!(dims, vec![2, 3, 3]);
        assert_eq!(payload.len(), 18);
        assert!(payload.iter().all(|&b| b == 7));
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse_idx(&[1, 0, 8, 1]).is_err());
        assert!(parse_idx(&[0, 0, 9, 1]).is_err());
        let mut short = idx1(&[1, 2, 3]);
        short.truncate(short.len() - 1);
        assert!(parse_idx(&short).is_err());
    }

    #[test]
    fn full_mnist_layout_roundtrip() {
        let dir = std::env::temp_dir().join("litl_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, bytes: &[u8]| {
            let mut f = std::fs::File::create(dir.join(name)).unwrap();
            f.write_all(bytes).unwrap();
        };
        write("train-images-idx3-ubyte", &idx3(5, 28, 28, 128));
        write("train-labels-idx1-ubyte", &idx1(&[0, 1, 2, 3, 4]));
        write("t10k-images-idx3-ubyte", &idx3(2, 28, 28, 255));
        write("t10k-labels-idx1-ubyte", &idx1(&[5, 6]));

        let ds = load_mnist(dir.to_str().unwrap(), usize::MAX, usize::MAX).unwrap();
        assert_eq!(ds.dim, 784);
        assert_eq!(ds.train_y, vec![0, 1, 2, 3, 4]);
        assert_eq!(ds.test_y, vec![5, 6]);
        assert!((ds.train_x[0] - 128.0 / 255.0).abs() < 1e-6);
        assert_eq!(ds.test_x[0], 1.0);

        // truncation honored
        let ds = load_mnist(dir.to_str().unwrap(), 3, 1).unwrap();
        assert_eq!(ds.train_y.len(), 3);
        assert_eq!(ds.test_y.len(), 1);
    }

    #[test]
    fn gzip_fallback() {
        let dir = std::env::temp_dir().join("litl_idx_gz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let raw = idx1(&[9, 8, 7]);
        let f = std::fs::File::create(dir.join("train-labels-idx1-ubyte.gz")).unwrap();
        let mut enc = flate2::write::GzEncoder::new(f, flate2::Compression::fast());
        enc.write_all(&raw).unwrap();
        enc.finish().unwrap();
        let labels =
            load_labels(&dir.join("train-labels-idx1-ubyte"), usize::MAX).unwrap();
        assert_eq!(labels, vec![9, 8, 7]);
    }
}
