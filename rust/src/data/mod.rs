//! Data substrate: MNIST loading and an offline synthetic fallback.
//!
//! The paper trains on MNIST.  This sandbox has no network access, so:
//!
//! * [`idx`] loads real MNIST IDX files (optionally gzipped) from
//!   `$LITL_MNIST_DIR` when the user has them;
//! * [`synth`] procedurally renders an MNIST-like 28×28 digit corpus
//!   (stroke skeletons + affine jitter + blur + pixel noise) so every
//!   experiment runs out of the box.  The substitution is documented in
//!   DESIGN.md §2 — the experiment validates the *relative* accuracy
//!   ordering of the four trainers, which is task-robust.
//! * [`dataset`] is the common container: split handling, shuffled
//!   mini-batches with one-hot labels, deterministic from a seed.

pub mod dataset;
pub mod idx;
pub mod synth;

pub use dataset::{BatchIter, Dataset, Split};

/// Load MNIST from `$LITL_MNIST_DIR` if present, else synthesize.
///
/// `train_size`/`test_size` truncate (or bound) the split sizes so the
/// single-core sandbox can run reduced-budget experiments; pass
/// `usize::MAX` for "everything available".
pub fn load_or_synth(
    seed: u64,
    train_size: usize,
    test_size: usize,
) -> crate::Result<Dataset> {
    let mut ds = if let Ok(dir) = std::env::var("LITL_MNIST_DIR") {
        log::info!("loading real MNIST from {dir}");
        idx::load_mnist(&dir, train_size, test_size)?
    } else {
        log::info!(
            "LITL_MNIST_DIR unset: synthesizing MNIST-like digits \
             (train={train_size}, test={test_size})"
        );
        synth::generate(seed, train_size, test_size)
    };
    let (mean, std) = ds.normalize();
    log::debug!("input standardization: mean={mean:.4} std={std:.4}");
    Ok(ds)
}
