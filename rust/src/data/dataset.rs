//! Dataset container and batch iteration.

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Which split of a dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// An in-memory image-classification dataset (f32 pixels in [0,1]).
pub struct Dataset {
    pub num_classes: usize,
    pub dim: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<u8>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<u8>,
}

impl Dataset {
    /// Standardize pixels in place: `x → (x - mean)/std` with scalar
    /// moments computed on the TRAIN split (the usual MNIST recipe,
    /// mean≈0.13/std≈0.31).  Centering matters for DFA: all-positive
    /// inputs give the ternary feedback a rank-1 common mode that drives
    /// the first tanh layer into saturation (see EXPERIMENTS.md §E5).
    pub fn normalize(&mut self) -> (f32, f32) {
        let n = self.train_x.len().max(1);
        let mean = self.train_x.iter().sum::<f32>() / n as f32;
        let var = self
            .train_x
            .iter()
            .map(|&v| (v - mean) * (v - mean))
            .sum::<f32>()
            / n as f32;
        let std = var.sqrt().max(1e-6);
        for v in self.train_x.iter_mut().chain(self.test_x.iter_mut()) {
            *v = (*v - mean) / std;
        }
        (mean, std)
    }

    pub fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.train_y.len(),
            Split::Test => self.test_y.len(),
        }
    }

    pub fn is_empty(&self, split: Split) -> bool {
        self.len(split) == 0
    }

    fn xy(&self, split: Split) -> (&[f32], &[u8]) {
        match split {
            Split::Train => (&self.train_x, &self.train_y),
            Split::Test => (&self.test_x, &self.test_y),
        }
    }

    /// Materialize one batch by (wrapped) indices: `(X [b, dim], one-hot
    /// Y [b, classes])`.
    pub fn gather(&self, split: Split, indices: &[usize]) -> (Tensor, Tensor) {
        let (xs, ys) = self.xy(split);
        let n = ys.len();
        let b = indices.len();
        let mut x = vec![0.0f32; b * self.dim];
        let mut y = vec![0.0f32; b * self.num_classes];
        for (row, &idx) in indices.iter().enumerate() {
            let idx = idx % n;
            x[row * self.dim..(row + 1) * self.dim]
                .copy_from_slice(&xs[idx * self.dim..(idx + 1) * self.dim]);
            y[row * self.num_classes + ys[idx] as usize] = 1.0;
        }
        (
            Tensor::from_vec(&[b, self.dim], x),
            Tensor::from_vec(&[b, self.num_classes], y),
        )
    }

    /// Shuffled epoch iterator over fixed-size batches (drops the ragged
    /// tail — artifact shapes are static).
    pub fn batches(&self, split: Split, batch: usize, rng: &mut Pcg64) -> BatchIter<'_> {
        let mut order: Vec<usize> = (0..self.len(split)).collect();
        rng.shuffle(&mut order);
        BatchIter {
            ds: self,
            split,
            order,
            batch,
            pos: 0,
        }
    }

    /// Sequential (unshuffled) batches, wrapping the tail to full size —
    /// used for evaluation where every sample must appear at least once.
    pub fn eval_batches(&self, split: Split, batch: usize) -> Vec<Vec<usize>> {
        let n = self.len(split);
        let mut out = Vec::new();
        let mut start = 0;
        while start < n {
            let idxs: Vec<usize> = (start..start + batch).map(|i| i % n).collect();
            out.push(idxs);
            start += batch;
        }
        out
    }
}

/// Iterator over shuffled fixed-size batches of one split.
pub struct BatchIter<'a> {
    ds: &'a Dataset,
    split: Split,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl Iterator for BatchIter<'_> {
    type Item = (Tensor, Tensor);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let idxs = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        Some(self.ds.gather(self.split, idxs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        // 10 samples, dim 4, 3 classes; pixel = sample index / 10.
        let n = 10;
        let dim = 4;
        Dataset {
            num_classes: 3,
            dim,
            train_x: (0..n * dim).map(|i| (i / dim) as f32 / 10.0).collect(),
            train_y: (0..n).map(|i| (i % 3) as u8).collect(),
            test_x: vec![0.0; 2 * dim],
            test_y: vec![1, 2],
        }
    }

    #[test]
    fn gather_shapes_and_onehot() {
        let ds = toy();
        let (x, y) = ds.gather(Split::Train, &[0, 3, 7]);
        assert_eq!(x.shape(), &[3, 4]);
        assert_eq!(y.shape(), &[3, 3]);
        // row 1 = sample 3 → class 0
        assert_eq!(y.row(1), &[1.0, 0.0, 0.0]);
        for r in 0..3 {
            assert_eq!(y.row(r).iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn batches_cover_epoch_without_repeats() {
        let ds = toy();
        let mut rng = Pcg64::seeded(0);
        let mut seen: Vec<usize> = Vec::new();
        for (x, _) in ds.batches(Split::Train, 3, &mut rng) {
            for r in 0..3 {
                // recover sample index from pixel value
                seen.push((x.row(r)[0] * 10.0).round() as usize);
            }
        }
        // 10 samples / batch 3 → 3 batches (tail dropped), all distinct.
        assert_eq!(seen.len(), 9);
        let mut sorted = seen.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 9);
    }

    #[test]
    fn batches_shuffle_differs_across_epochs() {
        let ds = toy();
        let mut rng = Pcg64::seeded(1);
        let e1: Vec<f32> = ds
            .batches(Split::Train, 3, &mut rng)
            .flat_map(|(x, _)| x.into_data())
            .collect();
        let e2: Vec<f32> = ds
            .batches(Split::Train, 3, &mut rng)
            .flat_map(|(x, _)| x.into_data())
            .collect();
        assert_ne!(e1, e2);
    }

    #[test]
    fn eval_batches_cover_all_with_wrap() {
        let ds = toy();
        let batches = ds.eval_batches(Split::Train, 4);
        assert_eq!(batches.len(), 3); // ceil(10/4)
        assert!(batches.iter().all(|b| b.len() == 4));
        let mut seen: Vec<usize> = batches.concat();
        seen.sort();
        seen.dedup();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }
}
