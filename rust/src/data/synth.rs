//! Procedural MNIST-like digit corpus (offline substitute for MNIST).
//!
//! Each digit class is a stroke skeleton (polyline control points in a
//! unit square).  A sample = random affine jitter (rotation, anisotropic
//! scale, translation, shear) + per-vertex wobble, rasterized by stamping
//! Gaussian ink blobs along the strokes onto a 28×28 canvas, then pixel
//! noise.  The result is a 10-class task with MNIST's geometry (28×28,
//! [0,1] grayscale, ~class-balanced) that a 784-1024-1024-10 MLP learns
//! to the high-90s — the regime where the paper's optical-vs-digital
//! comparison lives.  Substitution rationale: DESIGN.md §2.

use super::dataset::Dataset;
use crate::util::rng::Pcg64;

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;

/// A stroke: polyline through (x, y) control points in [0,1]².
type Stroke = &'static [(f32, f32)];

fn circle16(cx: f32, cy: f32, rx: f32, ry: f32) -> Vec<(f32, f32)> {
    (0..=16)
        .map(|i| {
            let a = i as f32 / 16.0 * std::f32::consts::TAU;
            (cx + rx * a.cos(), cy + ry * a.sin())
        })
        .collect()
}

/// Skeletons for digits 0-9.  Static segments are cheap to keep as
/// consts; loops are generated.
fn skeleton(digit: u8) -> Vec<Vec<(f32, f32)>> {
    const ONE: Stroke = &[(0.35, 0.25), (0.5, 0.12), (0.5, 0.88)];
    const ONE_BASE: Stroke = &[(0.32, 0.88), (0.68, 0.88)];
    const TWO: Stroke = &[
        (0.25, 0.3),
        (0.3, 0.15),
        (0.5, 0.1),
        (0.7, 0.18),
        (0.72, 0.35),
        (0.55, 0.55),
        (0.3, 0.75),
        (0.25, 0.88),
    ];
    const TWO_BASE: Stroke = &[(0.25, 0.88), (0.75, 0.88)];
    const FOUR_A: Stroke = &[(0.6, 0.1), (0.25, 0.6), (0.78, 0.6)];
    const FOUR_B: Stroke = &[(0.6, 0.1), (0.6, 0.9)];
    const FIVE_A: Stroke = &[(0.7, 0.12), (0.3, 0.12), (0.28, 0.45)];
    const SEVEN_A: Stroke = &[(0.25, 0.13), (0.75, 0.13), (0.45, 0.88)];
    const SEVEN_BAR: Stroke = &[(0.35, 0.5), (0.62, 0.5)];

    match digit {
        0 => vec![circle16(0.5, 0.5, 0.24, 0.36)],
        1 => vec![ONE.to_vec(), ONE_BASE.to_vec()],
        2 => vec![TWO.to_vec(), TWO_BASE.to_vec()],
        3 => vec![
            // two right-facing arcs
            (0..=8)
                .map(|i| {
                    let a = -0.45 * std::f32::consts::PI
                        + i as f32 / 8.0 * 0.95 * std::f32::consts::PI;
                    (0.42 + 0.22 * a.cos(), 0.3 + 0.19 * a.sin())
                })
                .collect(),
            (0..=8)
                .map(|i| {
                    let a = -0.5 * std::f32::consts::PI
                        + i as f32 / 8.0 * std::f32::consts::PI;
                    (0.42 + 0.24 * a.cos(), 0.68 + 0.21 * a.sin())
                })
                .collect(),
        ],
        4 => vec![FOUR_A.to_vec(), FOUR_B.to_vec()],
        5 => vec![
            FIVE_A.to_vec(),
            (0..=10)
                .map(|i| {
                    let a = -0.6 * std::f32::consts::PI
                        + i as f32 / 10.0 * 1.35 * std::f32::consts::PI;
                    (0.42 + 0.26 * a.cos(), 0.65 + 0.24 * a.sin())
                })
                .collect(),
        ],
        6 => vec![
            vec![(0.62, 0.1), (0.42, 0.3), (0.3, 0.55)],
            circle16(0.47, 0.68, 0.19, 0.2),
        ],
        7 => vec![SEVEN_A.to_vec(), SEVEN_BAR.to_vec()],
        8 => vec![
            circle16(0.5, 0.3, 0.17, 0.17),
            circle16(0.5, 0.68, 0.21, 0.2),
        ],
        9 => vec![
            circle16(0.52, 0.32, 0.19, 0.19),
            vec![(0.7, 0.35), (0.66, 0.65), (0.52, 0.9)],
        ],
        _ => unreachable!("digit out of range"),
    }
}

/// Random affine + wobble applied to the skeleton of one sample.
struct Jitter {
    rot: f32,
    sx: f32,
    sy: f32,
    shear: f32,
    dx: f32,
    dy: f32,
}

impl Jitter {
    fn sample(rng: &mut Pcg64) -> Self {
        Jitter {
            rot: (rng.next_f32() - 0.5) * 0.9,       // ±26°
            sx: 0.7 + 0.55 * rng.next_f32(),
            sy: 0.7 + 0.55 * rng.next_f32(),
            shear: (rng.next_f32() - 0.5) * 0.55,
            dx: (rng.next_f32() - 0.5) * 0.3,
            dy: (rng.next_f32() - 0.5) * 0.24,
        }
    }

    fn apply(&self, (x, y): (f32, f32)) -> (f32, f32) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let (sin, cos) = self.rot.sin_cos();
        let rx = cos * cx - sin * cy;
        let ry = sin * cx + cos * cy;
        let sx = self.sx * rx + self.shear * ry;
        let sy = self.sy * ry;
        (sx + 0.5 + self.dx, sy + 0.5 + self.dy)
    }
}

/// Stamp a Gaussian ink blob (3×3 support) at a subpixel position.
#[inline]
fn stamp(canvas: &mut [f32], x: f32, y: f32, ink: f32) {
    let px = x * SIDE as f32;
    let py = y * SIDE as f32;
    let ix = px.floor() as isize;
    let iy = py.floor() as isize;
    for oy in -1..=1 {
        for ox in -1..=1 {
            let cx = ix + ox;
            let cy = iy + oy;
            if cx < 0 || cy < 0 || cx >= SIDE as isize || cy >= SIDE as isize {
                continue;
            }
            let dx = px - (cx as f32 + 0.5);
            let dy = py - (cy as f32 + 0.5);
            let w = (-(dx * dx + dy * dy) / 0.55).exp();
            let cell = &mut canvas[cy as usize * SIDE + cx as usize];
            *cell = (*cell + ink * w).min(1.0);
        }
    }
}

/// Render one digit image into `out` (length DIM).
pub fn render(digit: u8, rng: &mut Pcg64, out: &mut [f32]) {
    debug_assert_eq!(out.len(), DIM);
    out.fill(0.0);
    let jit = Jitter::sample(rng);
    let wobble = 0.035;
    let ink = 0.35 + 0.3 * rng.next_f32(); // contrast variation
    for stroke in skeleton(digit) {
        let pts: Vec<(f32, f32)> = stroke
            .iter()
            .map(|&p| {
                let (x, y) = jit.apply(p);
                (
                    x + wobble * rng.next_normal_f32(),
                    y + wobble * rng.next_normal_f32(),
                )
            })
            .collect();
        for seg in pts.windows(2) {
            let (x0, y0) = seg[0];
            let (x1, y1) = seg[1];
            let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt();
            let steps = ((len * SIDE as f32 / 0.4).ceil() as usize).max(1);
            for s in 0..=steps {
                let t = s as f32 / steps as f32;
                stamp(out, x0 + t * (x1 - x0), y0 + t * (y1 - y0), ink);
            }
        }
    }
    // Distractor clutter: a few random ink blobs off the glyph.
    for _ in 0..3 {
        if rng.next_f32() < 0.5 {
            stamp(
                out,
                rng.next_f32(),
                rng.next_f32(),
                0.3 + 0.3 * rng.next_f32(),
            );
        }
    }
    // Random occlusion: a dark horizontal bar through the glyph.
    if rng.next_f32() < 0.25 {
        let row = 6 + rng.next_below(16) as usize;
        let col0 = rng.next_below(20) as usize;
        for c in col0..(col0 + 8).min(SIDE) {
            out[row * SIDE + c] = 0.0;
            out[(row + 1) * SIDE + c] = 0.0;
        }
    }
    // Sensor-like pixel noise (heavy: cheap camera).
    for v in out.iter_mut() {
        let n = 0.12 * rng.next_normal_f32();
        *v = (*v + n).clamp(0.0, 1.0);
    }
}

/// Generate a full dataset (round-robin class balance, seeded).
pub fn generate(seed: u64, train_size: usize, test_size: usize) -> Dataset {
    let train_size = train_size.min(200_000);
    let test_size = test_size.min(50_000);
    let mut rng = Pcg64::new(seed, 0x5f37);
    let make = |n: usize, rng: &mut Pcg64| {
        let mut xs = vec![0.0f32; n * DIM];
        let mut ys = vec![0u8; n];
        let mut order: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
        rng.shuffle(&mut order);
        for i in 0..n {
            ys[i] = order[i];
            render(order[i], rng, &mut xs[i * DIM..(i + 1) * DIM]);
        }
        (xs, ys)
    };
    let (train_x, train_y) = make(train_size, &mut rng);
    let (test_x, test_y) = make(test_size, &mut rng);
    Dataset {
        num_classes: 10,
        dim: DIM,
        train_x,
        train_y,
        test_x,
        test_y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Split;

    #[test]
    fn render_produces_ink_in_range() {
        let mut rng = Pcg64::seeded(0);
        let mut img = vec![0.0f32; DIM];
        for d in 0..10 {
            render(d, &mut rng, &mut img);
            let ink: f32 = img.iter().sum();
            assert!(ink > 5.0, "digit {d} almost blank (ink={ink})");
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn digits_are_distinguishable_by_template() {
        // Mean images of distinct classes should differ substantially.
        let mut rng = Pcg64::seeded(1);
        let mean = |d: u8, rng: &mut Pcg64| {
            let mut acc = vec![0.0f32; DIM];
            let mut img = vec![0.0f32; DIM];
            for _ in 0..20 {
                render(d, rng, &mut img);
                for (a, &v) in acc.iter_mut().zip(&img) {
                    *a += v / 20.0;
                }
            }
            acc
        };
        let m1 = mean(1, &mut rng);
        let m8 = mean(8, &mut rng);
        let dist: f32 = m1
            .iter()
            .zip(&m8)
            .map(|(a, b)| (a - b).powi(2))
            .sum::<f32>()
            .sqrt();
        assert!(dist > 1.0, "classes 1 and 8 too similar: {dist}");
    }

    #[test]
    fn generate_is_deterministic_and_balanced() {
        let a = generate(7, 100, 20);
        let b = generate(7, 100, 20);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
        assert_eq!(a.len(Split::Train), 100);
        assert_eq!(a.len(Split::Test), 20);
        let mut counts = [0usize; 10];
        for &y in &a.train_y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(1, 10, 0);
        let b = generate(2, 10, 0);
        assert_ne!(a.train_x, b.train_x);
    }
}
