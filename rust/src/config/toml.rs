//! TOML-subset parser: flat `key = value` tables with comments, plus
//! `[section]` headers flattened to `section.key`.  Values: strings,
//! integers, floats, booleans, and flat arrays.  Enough for experiment
//! configs; anything fancier is rejected loudly.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn want_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn want_int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(x) => Ok(*x),
            TomlValue::Float(x) if x.fract() == 0.0 => Ok(*x as i64),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    pub fn want_float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(x) => Ok(*x),
            TomlValue::Int(x) => Ok(*x as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    pub fn want_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }
}

/// Parse a single scalar (used by `--set key=value`).  Bare words that
/// are not numbers/bools are treated as strings for CLI ergonomics.
pub fn parse_scalar(text: &str) -> Result<TomlValue> {
    let t = text.trim();
    if t.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = t.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("unterminated string: {t}");
        };
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"")));
    }
    if t == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if t == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if t.starts_with('[') {
        let Some(inner) = t.strip_prefix('[').and_then(|s| s.strip_suffix(']')) else {
            bail!("unterminated array: {t}");
        };
        let items = split_top_level(inner)?;
        return Ok(TomlValue::Arr(
            items
                .into_iter()
                .map(|s| parse_scalar(&s))
                .collect::<Result<_>>()?,
        ));
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    // CLI ergonomics: bare identifier = string.  '@' and '+' admit the
    // topology shorthand (`opt:2@3+dig:1`) unquoted; anything numeric
    // (incl. `1e+5`) was already consumed by the parses above.
    if t.chars().all(|c| c.is_alphanumeric() || "-_./:@+".contains(c)) {
        return Ok(TomlValue::Str(t.to_string()));
    }
    bail!("cannot parse value: {t}")
}

fn split_top_level(s: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        bail!("unterminated string in array");
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    Ok(out)
}

/// Strip a trailing comment (respecting strings).
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a TOML-subset document into flat (key, value) pairs, with
/// `[section]` prefixes flattened as `section.key`.
pub fn parse(text: &str) -> Result<Vec<(String, TomlValue)>> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let Some(name) = inner.strip_suffix(']') else {
                bail!("line {}: bad section header: {raw}", lineno + 1);
            };
            section = name.trim().to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected key = value: {raw}", lineno + 1);
        };
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let parsed = parse_scalar(value.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        out.push((full_key, parsed));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse_scalar("42").unwrap(), TomlValue::Int(42));
        assert_eq!(parse_scalar("-1.5").unwrap(), TomlValue::Float(-1.5));
        assert_eq!(parse_scalar("true").unwrap(), TomlValue::Bool(true));
        assert_eq!(
            parse_scalar("\"hi\"").unwrap(),
            TomlValue::Str("hi".to_string())
        );
        assert_eq!(
            parse_scalar("bare-word").unwrap(),
            TomlValue::Str("bare-word".to_string())
        );
    }

    #[test]
    fn arrays() {
        assert_eq!(
            parse_scalar("[1, 2, 3]").unwrap(),
            TomlValue::Arr(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ])
        );
        assert_eq!(
            parse_scalar("[\"a\", \"b\"]").unwrap(),
            TomlValue::Arr(vec![
                TomlValue::Str("a".to_string()),
                TomlValue::Str("b".to_string())
            ])
        );
    }

    #[test]
    fn document_with_sections_and_comments() {
        let doc = r#"
# top comment
epochs = 10  # trailing
algo = "optical"

[opu]
n_ph = 100.0
"#;
        let kvs = parse(doc).unwrap();
        assert_eq!(kvs.len(), 3);
        assert_eq!(kvs[0].0, "epochs");
        assert_eq!(kvs[2].0, "opu.n_ph");
        assert_eq!(kvs[2].1, TomlValue::Float(100.0));
    }

    #[test]
    fn errors_are_located() {
        let err = parse("x == 1\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(parse("[unclosed\n").is_err());
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let kvs = parse("name = \"a#b\"\n").unwrap();
        assert_eq!(kvs[0].1, TomlValue::Str("a#b".to_string()));
    }
}
