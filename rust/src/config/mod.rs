//! Configuration system: TOML-subset files + CLI overrides.
//!
//! A run is described by a [`TrainConfig`] (experiment-level knobs) built
//! from defaults, an optional `--config file.toml`, and `--set key=value`
//! overrides, in that precedence order.  The TOML subset ([`toml`])
//! covers tables, strings, numbers, booleans and arrays — what config
//! files actually use.

pub mod toml;

use anyhow::{bail, Context, Result};

use self::toml::TomlValue;
use crate::coordinator::service::{AdaptConfig, AdmissionConfig, FailoverConfig};
use crate::coordinator::topology::{DeviceKind, PoolPolicy, Topology};
use crate::metrics::trace::TraceLevel;
use crate::net::{FaultPlanCfg, NetOptions, RESUME_TRIES_DEFAULT};

/// Which feedback path trains the hidden layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Backpropagation baseline (Eq. 2).
    Bp,
    /// Digital DFA with float error (paper: 97.7%).
    DfaFloat,
    /// Digital DFA with ternary error (paper: 97.6%).
    DfaTernary,
    /// Hybrid optical DFA through the simulated OPU (paper: 95.8%).
    Optical,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s {
            "bp" => Algo::Bp,
            "dfa-float" | "dfa_float" => Algo::DfaFloat,
            "dfa-ternary" | "dfa_ternary" => Algo::DfaTernary,
            "optical" => Algo::Optical,
            other => bail!("unknown algo '{other}' (bp|dfa-float|dfa-ternary|optical)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Bp => "bp",
            Algo::DfaFloat => "dfa-float",
            Algo::DfaTernary => "dfa-ternary",
            Algo::Optical => "optical",
        }
    }

    /// The paper's learning rate for this row (§III).
    pub fn paper_lr(&self) -> f32 {
        match self {
            Algo::Optical => 0.01,
            _ => 0.001,
        }
    }
}

/// How a multi-shard projector splits one projection across devices.
///
/// The axis choice is the ROADMAP's "batch-axis sharding" item realized
/// as a policy: `Modes` favours large-output regimes (each device images
/// its slice of the output modes), `Batch` favours small-mode /
/// large-batch regimes (each device holds the full medium and exposes a
/// contiguous row range of the frame sequence).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Partition {
    /// Every shard sees every frame and computes a contiguous slice of
    /// the output modes; shard outputs concatenate along columns.
    Modes,
    /// Shards hold full-medium replicas and each processes a contiguous
    /// row range of the frame batch; outputs concatenate along rows.
    Batch,
}

impl Partition {
    pub fn parse(s: &str) -> Result<Partition> {
        Ok(match s {
            "modes" | "mode" => Partition::Modes,
            "batch" => Partition::Batch,
            other => bail!("unknown partition '{other}' (modes|batch)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Partition::Modes => "modes",
            Partition::Batch => "batch",
        }
    }
}

/// How a projector holds its transmission medium.
///
/// `Materialized` caches the dense `[d_in, modes]` quadrature tensors —
/// right at MNIST scale.  `Streamed` never stores the slice: TM tiles
/// are regenerated per projection from the counter-addressable PCG row
/// streams (`optics::stream`), the paper's "the medium is physical,
/// nobody stores it" property at 1e5+ modes.  The two backings are the
/// same matrix for the same seed, so outputs are bitwise identical.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MediumBacking {
    /// Dense quadrature tensors held in memory.
    Materialized,
    /// Memory-less: tiles regenerated on the fly (`--medium streamed`).
    Streamed,
}

impl MediumBacking {
    pub fn parse(s: &str) -> Result<MediumBacking> {
        Ok(match s {
            "materialized" | "dense" => MediumBacking::Materialized,
            "streamed" | "stream" => MediumBacking::Streamed,
            other => bail!("unknown medium backing '{other}' (materialized|streamed)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            MediumBacking::Materialized => "materialized",
            MediumBacking::Streamed => "streamed",
        }
    }
}

/// Projector backend for DFA algos.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectorKind {
    /// Simulated OPU physics (rust-native optics).
    OpticalNative,
    /// Simulated OPU physics via the `opu_project` HLO artifact.
    OpticalHlo,
    /// Exact digital projection (the paper's GPU rows).
    Digital,
}

/// Experiment-level configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Artifact config name ("paper" = 1024 hidden, "small" = 256).
    pub artifact_config: String,
    pub algo: Algo,
    pub projector: ProjectorKind,
    pub epochs: usize,
    pub train_size: usize,
    pub test_size: usize,
    pub lr: f32,
    /// Eq. 4 threshold; < 0 disables quantization.
    pub theta: f32,
    pub seed: u64,
    /// Camera noise overrides (None = manifest defaults).
    pub n_ph: Option<f32>,
    pub read_sigma: Option<f32>,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Where to write metrics CSV/JSONL (None = no files).
    pub out_dir: Option<String>,
    /// Evaluate every N steps (0 = once per epoch).
    pub eval_every: usize,
    /// Simulated-OPU frame accounting on/off (timing model).
    pub account_frames: bool,
    /// Virtual projector devices: shard the projection across N
    /// concurrent devices (`ProjectorFarm`).  1 = the classic single
    /// device, bit-identical to the pre-farm path.
    pub shards: usize,
    /// Partition axis for a multi-shard projector (`modes` or `batch`).
    pub partition: Partition,
    /// Medium backing for the projection device(s): `materialized`
    /// (dense tensors) or `streamed` (memory-less tile regeneration;
    /// optical algo with the native or digital projector only).
    pub medium: MediumBacking,
    /// Bounded cross-step tile cache for the streamed backing, in MiB
    /// (`--tile-cache-mb`, `[topology] tile_cache_mb = N`).  `0` (the
    /// default) disables it: every projection regenerates its tiles.
    /// The budget folds into the streamed medium's resident-bytes
    /// ceiling; cached and uncached projections are bitwise equal.
    pub tile_cache_mb: usize,
    /// Lock stripes for the streamed tile cache (`--tile-cache-stripes`,
    /// `[topology] tile_cache_stripes = N`).  `0` (the default) picks
    /// automatically: the next power of two at or above the projection
    /// pool's thread count.  Explicit values round up to a power of
    /// two.  Stripes change contention and residency layout only —
    /// striped and single-stripe caches produce bitwise-identical
    /// projections.
    pub tile_cache_stripes: usize,
    /// Explicit device topology (`--topology opt:4+dig:2@3`-style
    /// shorthand, or a `[topology]` TOML section).  `None` = the
    /// homogeneous topology implied by `projector`/`shards`.  The
    /// topology's partition/backing/pool are stamped from the config
    /// knobs at resolve time ([`TrainConfig::projection_topology`]), so
    /// key order in a config file never matters.
    pub topology: Option<Topology>,
    /// Pool policy stamped onto the resolved topology (`[topology]
    /// pool = "shared"` / `--set topology.pool=shared`).
    pub topology_pool: PoolPolicy,
    /// Adaptive shard weights (`--adapt-weights on`, `[service]
    /// adapt_weights = true`): the frame-slot scheduler re-plans the
    /// declared topology weights from windowed per-shard service-rate
    /// EWMAs.  Off (the default) keeps the slot schedule a pure
    /// function of the config — bitwise deterministic across runs.
    pub adapt_weights: bool,
    /// Re-plan cadence for adaptive weights, in scheduled frame
    /// sequences (>= 1).
    pub adapt_replan_every: u64,
    /// EWMA smoothing factor in (0, 1] for the service-rate and
    /// occupancy windows (applies even with adaptation off: the
    /// `_util` gauges are windowed, never lifetime-cumulative).
    pub adapt_alpha: f64,
    /// Minimum relative share change that commits a new plan (>= 0).
    pub adapt_hysteresis: f64,
    /// Shard failover (`--failover on`, `[service] failover = true`):
    /// erroring or stalled shards trip out of the routable set, their
    /// queued lanes drain onto survivors, and they re-admit through
    /// probation after an in-place device rebuild.  Changes *which*
    /// shard serves a frame under faults, never the frame's value.
    pub failover: bool,
    /// Consecutive device errors that trip a healthy shard (>= 1).
    pub failover_trip_errors: u32,
    /// A device call running longer than this is a stall (ms, >= 1).
    pub failover_stall_ms: u64,
    /// Tripped → probation re-admission delay (ms).
    pub failover_probation_ms: u64,
    /// Per-client admission rate in frames/s (`--admit-rate-fps N`).
    /// `0` (the default) disables admission control; positive values
    /// token-bucket each client with `admit_burst` frames of credit
    /// and at most `admit_max_wait_ms` of backpressure before the
    /// submission errors instead of queueing.
    pub admit_rate_fps: f64,
    /// Token-bucket burst credit in frames (>= 1).
    pub admit_burst: f64,
    /// Longest a submission may wait for admission tokens (ms).
    pub admit_max_wait_ms: u64,
    /// Telemetry level (`--trace off|summary|full`, `[telemetry]
    /// trace = "..."`).  `off` (the default) keeps the serving and
    /// training paths free of span recording — pinned schedules stay
    /// bitwise; `summary` enables the profiling histograms and the
    /// periodic summary line; `full` additionally records span events
    /// for the Chrome-trace export.
    pub trace: TraceLevel,
    /// Chrome `trace_event` JSON output path (`--trace-out trace.json`,
    /// loadable at ui.perfetto.dev).  Requires `trace = "full"` — there
    /// are no span events to write below that.
    pub trace_out: Option<String>,
    /// Prometheus text-exposition dump of the full metrics registry,
    /// written at exit (`--metrics-out metrics.prom`).  Works at any
    /// trace level (counters and gauges always populate).
    pub metrics_out: Option<String>,
    /// Per-thread span ring capacity, in events (`[telemetry]
    /// trace_ring_events = N`).  Overflow drops the newest events and
    /// counts them — recording never blocks the pipeline.
    pub trace_ring_events: usize,
    /// Emit the human-readable telemetry summary line every N training
    /// batches (0 = never; needs `trace` at `summary` or `full`).
    pub summary_every_batches: usize,
    /// Resume from a training checkpoint (`--resume file.ckpt`): model +
    /// optimizer state load before the run and the already-trained
    /// batches are skipped, so killed-and-resumed equals uninterrupted
    /// for deterministic projectors.
    pub resume: Option<String>,
    /// Write a tile-cache snapshot at run end (`--tile-cache-save
    /// file.tiles`; needs `--medium streamed` + `--tile-cache-mb`).
    pub tile_cache_save: Option<String>,
    /// Warm-start the tile cache from a snapshot before training
    /// (`--tile-cache-load file.tiles`).  Tiles are keyed by
    /// (seed, row, col0, width), so replayed tiles are bitwise the
    /// regenerated ones — a stale or foreign snapshot is simply a miss.
    pub tile_cache_load: Option<String>,
    /// Per-attempt dial timeout for remote projector shards (ms, >= 1).
    pub net_connect_timeout_ms: u64,
    /// Reply timeout per remote projection (ms, >= 1); expiry errors
    /// the in-flight frame (never a silent retry).
    pub net_request_timeout_ms: u64,
    /// Dial attempts per remote (re)connection before giving up (>= 1).
    pub net_reconnect_tries: u32,
    /// Session-resume for remote shards (`--net-resume on`): a redialed
    /// client re-attaches its stream and re-requests the in-flight
    /// frame, which the server's replay journal executes exactly once —
    /// off (the default) keeps the pre-v2 semantics where any mid-frame
    /// failure errors into failover.
    pub net_resume: bool,
    /// Seeded deterministic fault plan for chaos drills
    /// (`--fault-plan seed=7,cut_every=50,...`); `None` = no injection,
    /// zero cost.  See `net::FaultPlanCfg::parse` for the spec grammar.
    pub fault_plan: Option<FaultPlanCfg>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact_config: "paper".to_string(),
            algo: Algo::Optical,
            projector: ProjectorKind::OpticalNative,
            epochs: 10,
            train_size: 60_000,
            test_size: 10_000,
            lr: 0.01,
            theta: 0.1,
            seed: 42,
            n_ph: None,
            read_sigma: None,
            artifacts_dir: "artifacts".to_string(),
            out_dir: None,
            eval_every: 0,
            account_frames: true,
            shards: 1,
            partition: Partition::Modes,
            medium: MediumBacking::Materialized,
            tile_cache_mb: 0,
            tile_cache_stripes: 0,
            topology: None,
            topology_pool: PoolPolicy::Owned,
            adapt_weights: false,
            adapt_replan_every: 16,
            adapt_alpha: 0.2,
            adapt_hysteresis: 0.05,
            failover: false,
            failover_trip_errors: 3,
            failover_stall_ms: 2000,
            failover_probation_ms: 250,
            admit_rate_fps: 0.0,
            admit_burst: 256.0,
            admit_max_wait_ms: 50,
            trace: TraceLevel::Off,
            trace_out: None,
            metrics_out: None,
            trace_ring_events: 65_536,
            summary_every_batches: 0,
            resume: None,
            tile_cache_save: None,
            tile_cache_load: None,
            net_connect_timeout_ms: NetOptions::default().connect_timeout_ms,
            net_request_timeout_ms: NetOptions::default().request_timeout_ms,
            net_reconnect_tries: NetOptions::default().reconnect_tries,
            net_resume: false,
            fault_plan: None,
        }
    }
}

impl TrainConfig {
    /// Apply a `key = value` pair (TOML file entry or `--set` override).
    pub fn set(&mut self, key: &str, value: &TomlValue) -> Result<()> {
        match key {
            "config" | "artifact_config" => {
                self.artifact_config = value.want_str()?.to_string()
            }
            "algo" => self.algo = Algo::parse(value.want_str()?)?,
            "projector" => {
                self.projector = match value.want_str()? {
                    "optical-native" | "native" => ProjectorKind::OpticalNative,
                    "optical-hlo" | "hlo" => ProjectorKind::OpticalHlo,
                    "digital" => ProjectorKind::Digital,
                    o => bail!("unknown projector '{o}'"),
                }
            }
            "epochs" => self.epochs = value.want_int()? as usize,
            "train_size" => self.train_size = value.want_int()? as usize,
            "test_size" => self.test_size = value.want_int()? as usize,
            "lr" => self.lr = value.want_float()? as f32,
            "theta" => self.theta = value.want_float()? as f32,
            "seed" => self.seed = value.want_int()? as u64,
            "n_ph" => self.n_ph = Some(value.want_float()? as f32),
            "read_sigma" => self.read_sigma = Some(value.want_float()? as f32),
            "artifacts_dir" => self.artifacts_dir = value.want_str()?.to_string(),
            "out_dir" => self.out_dir = Some(value.want_str()?.to_string()),
            "eval_every" => self.eval_every = value.want_int()? as usize,
            "account_frames" => self.account_frames = value.want_bool()?,
            "shards" => {
                let n = value.want_int()?;
                if n < 1 {
                    bail!("shards must be >= 1, got {n}");
                }
                self.shards = n as usize;
            }
            "partition" | "topology.partition" => {
                self.partition = Partition::parse(value.want_str()?)?
            }
            "medium" | "medium_backing" | "topology.medium" | "topology.backing" => {
                self.medium = MediumBacking::parse(value.want_str()?)?
            }
            "tile_cache_mb" | "topology.tile_cache_mb" => {
                let n = value.want_int()?;
                if n < 0 {
                    bail!("tile_cache_mb must be >= 0 (0 disables the cache), got {n}");
                }
                self.tile_cache_mb = n as usize;
            }
            "tile_cache_stripes" | "topology.tile_cache_stripes" => {
                let n = value.want_int()?;
                if n < 0 {
                    bail!("tile_cache_stripes must be >= 0 (0 picks automatically), got {n}");
                }
                self.tile_cache_stripes = n as usize;
            }
            "topology" | "topology.spec" => {
                self.topology = Some(Topology::parse(value.want_str()?)?)
            }
            "topology.pool" => {
                self.topology_pool = PoolPolicy::parse(value.want_str()?)?
            }
            "adapt_weights" | "service.adapt_weights" => {
                self.adapt_weights = value.want_bool()?
            }
            "adapt_replan_every" | "service.adapt_replan_every" => {
                let n = value.want_int()?;
                if n < 1 {
                    bail!("adapt_replan_every must be >= 1, got {n}");
                }
                self.adapt_replan_every = n as u64;
            }
            "adapt_alpha" | "service.adapt_alpha" => {
                let a = value.want_float()?;
                if !a.is_finite() || a <= 0.0 || a > 1.0 {
                    bail!("adapt_alpha must be in (0, 1], got {a}");
                }
                self.adapt_alpha = a;
            }
            "adapt_hysteresis" | "service.adapt_hysteresis" => {
                let h = value.want_float()?;
                if !h.is_finite() || h < 0.0 {
                    bail!("adapt_hysteresis must be finite and >= 0, got {h}");
                }
                self.adapt_hysteresis = h;
            }
            "failover" | "service.failover" => self.failover = value.want_bool()?,
            "failover_trip_errors" | "service.failover_trip_errors" => {
                let n = value.want_int()?;
                if n < 1 {
                    bail!("failover_trip_errors must be >= 1, got {n}");
                }
                self.failover_trip_errors = n as u32;
            }
            "failover_stall_ms" | "service.failover_stall_ms" => {
                let n = value.want_int()?;
                if n < 1 {
                    bail!("failover_stall_ms must be >= 1, got {n}");
                }
                self.failover_stall_ms = n as u64;
            }
            "failover_probation_ms" | "service.failover_probation_ms" => {
                let n = value.want_int()?;
                if n < 0 {
                    bail!("failover_probation_ms must be >= 0, got {n}");
                }
                self.failover_probation_ms = n as u64;
            }
            "admit_rate_fps" | "service.admit_rate_fps" => {
                let r = value.want_float()?;
                if !r.is_finite() || r < 0.0 {
                    bail!("admit_rate_fps must be finite and >= 0 (0 disables), got {r}");
                }
                self.admit_rate_fps = r;
            }
            "admit_burst" | "service.admit_burst" => {
                let b = value.want_float()?;
                if !b.is_finite() || b < 1.0 {
                    bail!("admit_burst must be >= 1 frame, got {b}");
                }
                self.admit_burst = b;
            }
            "admit_max_wait_ms" | "service.admit_max_wait_ms" => {
                let n = value.want_int()?;
                if n < 0 {
                    bail!("admit_max_wait_ms must be >= 0, got {n}");
                }
                self.admit_max_wait_ms = n as u64;
            }
            "trace" | "telemetry.trace" => {
                self.trace = TraceLevel::parse(value.want_str()?)?
            }
            "trace_out" | "telemetry.trace_out" => {
                self.trace_out = Some(value.want_str()?.to_string())
            }
            "metrics_out" | "telemetry.metrics_out" => {
                self.metrics_out = Some(value.want_str()?.to_string())
            }
            "trace_ring_events" | "telemetry.trace_ring_events" => {
                let n = value.want_int()?;
                if n < 1 {
                    bail!("trace_ring_events must be >= 1, got {n}");
                }
                self.trace_ring_events = n as usize;
            }
            "summary_every_batches" | "telemetry.summary_every_batches" => {
                let n = value.want_int()?;
                if n < 0 {
                    bail!("summary_every_batches must be >= 0 (0 disables), got {n}");
                }
                self.summary_every_batches = n as usize;
            }
            "resume" => self.resume = Some(value.want_str()?.to_string()),
            "tile_cache_save" | "topology.tile_cache_save" => {
                self.tile_cache_save = Some(value.want_str()?.to_string())
            }
            "tile_cache_load" | "topology.tile_cache_load" => {
                self.tile_cache_load = Some(value.want_str()?.to_string())
            }
            "net_connect_timeout_ms" | "net.connect_timeout_ms" => {
                let n = value.want_int()?;
                if n < 1 {
                    bail!("net_connect_timeout_ms must be >= 1, got {n}");
                }
                self.net_connect_timeout_ms = n as u64;
            }
            "net_request_timeout_ms" | "net.request_timeout_ms" => {
                let n = value.want_int()?;
                if n < 1 {
                    bail!("net_request_timeout_ms must be >= 1, got {n}");
                }
                self.net_request_timeout_ms = n as u64;
            }
            "net_reconnect_tries" | "net.reconnect_tries" => {
                let n = value.want_int()?;
                if n < 1 {
                    bail!("net_reconnect_tries must be >= 1, got {n}");
                }
                self.net_reconnect_tries = n as u32;
            }
            "net_resume" | "net.resume" => self.net_resume = value.want_bool()?,
            "fault_plan" | "net.fault_plan" => {
                self.fault_plan = Some(FaultPlanCfg::parse(value.want_str()?)?)
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Projection-path sanity, shared by the trainer and the CLI: every
    /// constraint here is a pure function of the config, so `litl
    /// train` fails fast — before artifacts load — and tests can cover
    /// the rules without an artifacts directory.
    pub fn validate_projection(&self) -> Result<()> {
        // Sharding only exists on the projector path — reject it loudly
        // elsewhere rather than silently running single-device.
        anyhow::ensure!(
            self.shards <= 1 || self.algo == Algo::Optical,
            "--shards {} only applies to --algo optical (the projection \
             device); algo '{}' has no projector to shard",
            self.shards,
            self.algo.name()
        );
        // The streamed backing only exists where a projector device owns
        // the medium; the digital-DFA artifacts take dense B tensors as
        // inputs and the HLO projector feeds them to XLA.
        anyhow::ensure!(
            self.medium == MediumBacking::Materialized || self.algo == Algo::Optical,
            "--medium streamed only applies to --algo optical (algo '{}' \
             passes the dense medium tensors into the AOT artifacts)",
            self.algo.name()
        );
        anyhow::ensure!(
            self.medium == MediumBacking::Materialized
                || self.projector != ProjectorKind::OpticalHlo,
            "projector=hlo does not support --medium streamed (the \
             opu_project artifact takes the dense medium as an input); \
             use projector=native or digital"
        );
        // The tile cache caches *regenerated* tiles; the materialized
        // backing already holds every tile resident, so a budget there
        // is a configuration error, not a silent no-op.
        anyhow::ensure!(
            self.tile_cache_mb == 0 || self.medium == MediumBacking::Streamed,
            "--tile-cache-mb {} only applies to --medium streamed (the \
             materialized backing holds the dense tensors already)",
            self.tile_cache_mb
        );
        // Same rule for the stripe knob: stripes partition the tile
        // cache, which only exists on the streamed backing.
        anyhow::ensure!(
            self.tile_cache_stripes == 0 || self.medium == MediumBacking::Streamed,
            "--tile-cache-stripes {} only applies to --medium streamed \
             (there is no tile cache to stripe on the materialized backing)",
            self.tile_cache_stripes
        );
        anyhow::ensure!(
            self.shards <= 1 || self.projector != ProjectorKind::OpticalHlo,
            "projector=hlo does not support --shards {} (the AOT artifact \
             is compiled for one device); use projector=native or digital",
            self.shards
        );
        if self.topology.is_some() {
            anyhow::ensure!(
                self.algo == Algo::Optical,
                "--topology only applies to --algo optical (the projection \
                 device); algo '{}' has no projector to shard",
                self.algo.name()
            );
            anyhow::ensure!(
                self.projector != ProjectorKind::OpticalHlo,
                "projector=hlo cannot drive a device topology (the AOT \
                 artifact is compiled for one device); use projector=native \
                 or digital"
            );
            anyhow::ensure!(
                self.shards <= 1,
                "--topology and --shards {} conflict: the shard count comes \
                 from the topology",
                self.shards
            );
            // Structural validation of the *resolved* topology (the
            // stamped partition decides whether explicit mode ranges
            // are legal).
            self.projection_topology().validate()?;
        }
        // A trace file needs span events, which only `full` records —
        // an output path below that would silently write an empty trace.
        anyhow::ensure!(
            self.trace_out.is_none() || self.trace == TraceLevel::Full,
            "--trace-out requires --trace full (level '{}' records no \
             span events)",
            self.trace.name()
        );
        // Tile-cache snapshots only exist where a tile cache exists:
        // the streamed backing with a nonzero budget.
        for (knob, path) in [
            ("--tile-cache-save", &self.tile_cache_save),
            ("--tile-cache-load", &self.tile_cache_load),
        ] {
            if path.is_some() {
                anyhow::ensure!(
                    self.medium == MediumBacking::Streamed,
                    "{knob} only applies to --medium streamed (the \
                     materialized backing has no tile cache to snapshot)"
                );
                anyhow::ensure!(
                    self.tile_cache_mb > 0,
                    "{knob} needs --tile-cache-mb >= 1 (with the cache \
                     disabled there is nothing to snapshot or warm)"
                );
            }
        }
        Ok(())
    }

    /// The remote-shard transport tuning these knobs describe
    /// (operational only — stamped onto the resolved topology but
    /// excluded from its canonical identity).
    pub fn net_options(&self) -> NetOptions {
        NetOptions {
            connect_timeout_ms: self.net_connect_timeout_ms,
            request_timeout_ms: self.net_request_timeout_ms,
            reconnect_tries: self.net_reconnect_tries,
            resume_tries: if self.net_resume {
                RESUME_TRIES_DEFAULT
            } else {
                0
            },
            faults: self.fault_plan,
            ..NetOptions::default()
        }
    }

    /// The device topology this config trains through: the explicit
    /// `[topology]` when given, else the homogeneous equivalent of the
    /// legacy `projector`/`shards` knobs.  Partition, backing and pool
    /// policy are stamped from the config in both cases, so the
    /// resolved topology is a pure function of the whole config.
    pub fn projection_topology(&self) -> Topology {
        let base = match &self.topology {
            Some(t) => t.clone(),
            None => {
                let kind = match self.projector {
                    ProjectorKind::Digital => DeviceKind::Digital,
                    // The HLO projector never reaches a topology build
                    // (validate_projection rejects the combination);
                    // native is the only other optical kind.
                    _ => DeviceKind::Optical,
                };
                Topology::homogeneous(kind, self.shards)
            }
        };
        base.with_partition(self.partition)
            .with_backing(self.medium)
            .with_pool(self.topology_pool)
            .with_net(self.net_options())
    }

    /// Map the control-plane knobs onto the sharded service's config
    /// structs.  `admit_rate_fps == 0` leaves admission disabled; the
    /// disabled struct keeps the service-side default rate so it stays
    /// valid if a caller flips `enabled` later.
    pub fn service_control(&self) -> (AdaptConfig, FailoverConfig, AdmissionConfig) {
        let adapt = AdaptConfig {
            enabled: self.adapt_weights,
            replan_every: self.adapt_replan_every,
            alpha: self.adapt_alpha,
            hysteresis: self.adapt_hysteresis,
        };
        let failover = FailoverConfig {
            enabled: self.failover,
            trip_errors: self.failover_trip_errors,
            stall_ms: self.failover_stall_ms,
            probation_ms: self.failover_probation_ms,
        };
        let enabled = self.admit_rate_fps > 0.0;
        let admission = AdmissionConfig {
            enabled,
            rate_fps: if enabled {
                self.admit_rate_fps
            } else {
                AdmissionConfig::default().rate_fps
            },
            burst: self.admit_burst,
            max_wait_ms: self.admit_max_wait_ms,
        };
        (adapt, failover, admission)
    }

    /// Load from a TOML file on top of `self`.
    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let table = toml::parse(&text)?;
        for (key, value) in table.iter() {
            self.set(key, value)
                .with_context(|| format!("config key '{key}'"))?;
        }
        Ok(())
    }

    /// Apply a `--set key=value` override (value parsed as TOML scalar).
    pub fn set_kv(&mut self, kv: &str) -> Result<()> {
        let (key, value) = kv
            .split_once('=')
            .with_context(|| format!("--set expects key=value, got '{kv}'"))?;
        let v = toml::parse_scalar(value.trim())?;
        self.set(key.trim(), &v)
    }

    /// Mirror the paper's per-algorithm learning-rate choice.
    pub fn with_paper_lr(mut self) -> Self {
        self.lr = self.algo.paper_lr();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.epochs, 10);
        assert_eq!(c.theta, 0.1);
        assert_eq!(c.algo, Algo::Optical);
        assert_eq!(c.lr, 0.01);
    }

    #[test]
    fn paper_lr_per_algo() {
        assert_eq!(Algo::Optical.paper_lr(), 0.01);
        assert_eq!(Algo::DfaTernary.paper_lr(), 0.001);
        assert_eq!(Algo::DfaFloat.paper_lr(), 0.001);
    }

    #[test]
    fn set_kv_overrides() {
        let mut c = TrainConfig::default();
        c.set_kv("epochs=3").unwrap();
        c.set_kv("algo=\"bp\"").unwrap();
        c.set_kv("lr=0.001").unwrap();
        c.set_kv("account_frames=false").unwrap();
        assert_eq!(c.epochs, 3);
        assert_eq!(c.algo, Algo::Bp);
        assert_eq!(c.lr, 0.001);
        assert!(!c.account_frames);
    }

    #[test]
    fn shards_knob_defaults_and_validates() {
        let mut c = TrainConfig::default();
        assert_eq!(c.shards, 1);
        c.set_kv("shards=4").unwrap();
        assert_eq!(c.shards, 4);
        assert!(c.set_kv("shards=0").is_err());
    }

    #[test]
    fn partition_knob_parses_and_validates() {
        let mut c = TrainConfig::default();
        assert_eq!(c.partition, Partition::Modes);
        c.set_kv("partition=batch").unwrap();
        assert_eq!(c.partition, Partition::Batch);
        c.set_kv("partition=\"modes\"").unwrap();
        assert_eq!(c.partition, Partition::Modes);
        assert!(c.set_kv("partition=rows").is_err());
        assert_eq!(Partition::Batch.name(), "batch");
        assert_eq!(Partition::Modes.name(), "modes");
    }

    #[test]
    fn medium_backing_knob_parses_and_validates() {
        let mut c = TrainConfig::default();
        assert_eq!(c.medium, MediumBacking::Materialized);
        c.set_kv("medium=streamed").unwrap();
        assert_eq!(c.medium, MediumBacking::Streamed);
        c.set_kv("medium=\"materialized\"").unwrap();
        assert_eq!(c.medium, MediumBacking::Materialized);
        c.set_kv("medium_backing=stream").unwrap();
        assert_eq!(c.medium, MediumBacking::Streamed);
        let err = c.set_kv("medium=holographic").unwrap_err();
        assert!(
            format!("{err:#}").contains("materialized|streamed"),
            "error names the allowed values: {err:#}"
        );
    }

    #[test]
    fn tile_cache_knob_parses_validates_and_round_trips() {
        let mut c = TrainConfig::default();
        assert_eq!(c.tile_cache_mb, 0, "cache is off by default");
        c.set_kv("tile_cache_mb=64").unwrap();
        assert_eq!(c.tile_cache_mb, 64);
        assert!(c.set_kv("tile_cache_mb=-1").is_err());
        // Cache without the streamed backing is a loud config error.
        let err = c.validate_projection().unwrap_err().to_string();
        assert!(err.contains("streamed"), "{err}");
        c.set_kv("medium=streamed").unwrap();
        c.validate_projection().unwrap();
        // The `[topology]` section spelling maps to the same knob.
        let path = std::env::temp_dir().join("litl_cfg_tile_cache_test.toml");
        std::fs::write(
            &path,
            "[topology]\ntile_cache_mb = 128\nmedium = \"streamed\"\n",
        )
        .unwrap();
        let mut c2 = TrainConfig::default();
        c2.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c2.tile_cache_mb, 128);
        assert_eq!(c2.medium, MediumBacking::Streamed);
        c2.validate_projection().unwrap();
    }

    #[test]
    fn tile_cache_stripes_knob_parses_validates_and_round_trips() {
        let mut c = TrainConfig::default();
        assert_eq!(c.tile_cache_stripes, 0, "auto stripe count by default");
        c.set_kv("tile_cache_stripes=8").unwrap();
        assert_eq!(c.tile_cache_stripes, 8);
        assert!(c.set_kv("tile_cache_stripes=-2").is_err());
        // Stripes without the streamed backing is a loud config error,
        // exactly like the budget knob.
        let err = c.validate_projection().unwrap_err().to_string();
        assert!(err.contains("streamed"), "{err}");
        c.set_kv("medium=streamed").unwrap();
        c.validate_projection().unwrap();
        // The `[topology]` section spelling maps to the same knob.
        let path = std::env::temp_dir().join("litl_cfg_tile_stripes_test.toml");
        std::fs::write(
            &path,
            "[topology]\ntile_cache_mb = 32\ntile_cache_stripes = 4\nmedium = \"streamed\"\n",
        )
        .unwrap();
        let mut c2 = TrainConfig::default();
        c2.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c2.tile_cache_stripes, 4);
        assert_eq!(c2.tile_cache_mb, 32);
        c2.validate_projection().unwrap();
    }

    #[test]
    fn control_plane_knobs_default_off_and_mirror_service_defaults() {
        let c = TrainConfig::default();
        assert!(!c.adapt_weights);
        assert!(!c.failover);
        assert_eq!(c.admit_rate_fps, 0.0, "admission off by default");
        let (a, f, ad) = c.service_control();
        assert!(!a.enabled && !f.enabled && !ad.enabled);
        // An untouched config maps onto exactly the service-side
        // Defaults, so `ShardServiceConfig::default()` and the config
        // path describe the same (deterministic) service.
        let (da, df, dad) = (
            AdaptConfig::default(),
            FailoverConfig::default(),
            AdmissionConfig::default(),
        );
        assert_eq!(a.replan_every, da.replan_every);
        assert_eq!(a.alpha, da.alpha);
        assert_eq!(a.hysteresis, da.hysteresis);
        assert_eq!(f.trip_errors, df.trip_errors);
        assert_eq!(f.stall_ms, df.stall_ms);
        assert_eq!(f.probation_ms, df.probation_ms);
        assert_eq!(ad.rate_fps, dad.rate_fps);
        assert_eq!(ad.burst, dad.burst);
        assert_eq!(ad.max_wait_ms, dad.max_wait_ms);
    }

    #[test]
    fn control_plane_knobs_parse_validate_and_map() {
        let mut c = TrainConfig::default();
        c.set_kv("adapt_weights=true").unwrap();
        c.set_kv("adapt_replan_every=8").unwrap();
        c.set_kv("adapt_alpha=0.5").unwrap();
        c.set_kv("adapt_hysteresis=0.1").unwrap();
        c.set_kv("failover=true").unwrap();
        c.set_kv("failover_trip_errors=2").unwrap();
        c.set_kv("failover_stall_ms=500").unwrap();
        c.set_kv("failover_probation_ms=100").unwrap();
        c.set_kv("admit_rate_fps=2000").unwrap();
        c.set_kv("admit_burst=64").unwrap();
        c.set_kv("admit_max_wait_ms=20").unwrap();
        let (a, f, ad) = c.service_control();
        assert!(a.enabled && f.enabled && ad.enabled);
        assert_eq!(a.replan_every, 8);
        assert_eq!(a.alpha, 0.5);
        assert_eq!(a.hysteresis, 0.1);
        assert_eq!(f.trip_errors, 2);
        assert_eq!(f.stall_ms, 500);
        assert_eq!(f.probation_ms, 100);
        assert_eq!(ad.rate_fps, 2000.0);
        assert_eq!(ad.burst, 64.0);
        assert_eq!(ad.max_wait_ms, 20);
        // Out-of-range values are loud, not clamped.
        assert!(c.set_kv("adapt_replan_every=0").is_err());
        assert!(c.set_kv("adapt_alpha=0").is_err());
        assert!(c.set_kv("adapt_alpha=1.5").is_err());
        assert!(c.set_kv("adapt_hysteresis=-0.1").is_err());
        assert!(c.set_kv("failover_trip_errors=0").is_err());
        assert!(c.set_kv("failover_stall_ms=0").is_err());
        assert!(c.set_kv("failover_probation_ms=-1").is_err());
        assert!(c.set_kv("admit_rate_fps=-1").is_err());
        assert!(c.set_kv("admit_burst=0.5").is_err());
        assert!(c.set_kv("admit_max_wait_ms=-5").is_err());
    }

    #[test]
    fn control_plane_service_section_round_trips() {
        // The `[service]` section spelling maps to the same knobs as
        // the bare `--set` keys.
        let path = std::env::temp_dir().join("litl_cfg_service_section_test.toml");
        std::fs::write(
            &path,
            "[service]\nadapt_weights = true\nfailover = true\n\
             failover_trip_errors = 5\nadmit_rate_fps = 800.0\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert!(c.adapt_weights);
        assert!(c.failover);
        assert_eq!(c.failover_trip_errors, 5);
        assert_eq!(c.admit_rate_fps, 800.0);
        let (_, _, ad) = c.service_control();
        assert!(ad.enabled);
        assert_eq!(ad.rate_fps, 800.0);
    }

    #[test]
    fn telemetry_defaults_are_off() {
        let c = TrainConfig::default();
        assert_eq!(c.trace, TraceLevel::Off);
        assert!(c.trace_out.is_none());
        assert!(c.metrics_out.is_none());
        assert_eq!(c.trace_ring_events, 65_536);
        assert_eq!(c.summary_every_batches, 0);
        // The defaults validate: no trace file is demanded without
        // span recording.
        c.validate_projection().unwrap();
    }

    #[test]
    fn telemetry_kv_overrides_and_bounds() {
        let mut c = TrainConfig::default();
        c.set_kv("trace=full").unwrap();
        c.set_kv("trace_out=trace.json").unwrap();
        c.set_kv("metrics_out=metrics.prom").unwrap();
        c.set_kv("trace_ring_events=1024").unwrap();
        c.set_kv("summary_every_batches=50").unwrap();
        assert_eq!(c.trace, TraceLevel::Full);
        assert_eq!(c.trace_out.as_deref(), Some("trace.json"));
        assert_eq!(c.metrics_out.as_deref(), Some("metrics.prom"));
        assert_eq!(c.trace_ring_events, 1024);
        assert_eq!(c.summary_every_batches, 50);
        c.validate_projection().unwrap();
        // Out-of-range values are loud, not clamped.
        assert!(c.set_kv("trace=verbose").is_err());
        assert!(c.set_kv("trace_ring_events=0").is_err());
        assert!(c.set_kv("summary_every_batches=-1").is_err());
        // A trace file without full-level recording is a config error.
        c.set_kv("trace=summary").unwrap();
        let err = c.validate_projection().unwrap_err();
        assert!(
            format!("{err:#}").contains("--trace full"),
            "error names the fix: {err:#}"
        );
    }

    #[test]
    fn telemetry_toml_section_round_trips() {
        // The `[telemetry]` section spelling maps to the same knobs as
        // the bare `--set` keys (the `[service]` pattern).
        let path = std::env::temp_dir().join("litl_cfg_telemetry_section_test.toml");
        std::fs::write(
            &path,
            "[telemetry]\ntrace = \"full\"\ntrace_out = \"out/trace.json\"\n\
             metrics_out = \"out/metrics.prom\"\ntrace_ring_events = 4096\n\
             summary_every_batches = 25\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.trace, TraceLevel::Full);
        assert_eq!(c.trace_out.as_deref(), Some("out/trace.json"));
        assert_eq!(c.metrics_out.as_deref(), Some("out/metrics.prom"));
        assert_eq!(c.trace_ring_events, 4096);
        assert_eq!(c.summary_every_batches, 25);
        // Re-emit via name() and reload: the level round trip is stable.
        std::fs::write(
            &path,
            format!("[telemetry]\ntrace = \"{}\"\n", c.trace.name()),
        )
        .unwrap();
        let mut c2 = TrainConfig::default();
        c2.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c2.trace, c.trace);
    }

    #[test]
    fn partition_and_medium_names_round_trip_through_parse() {
        for p in [Partition::Modes, Partition::Batch] {
            assert_eq!(Partition::parse(p.name()).unwrap(), p);
        }
        for m in [MediumBacking::Materialized, MediumBacking::Streamed] {
            assert_eq!(MediumBacking::parse(m.name()).unwrap(), m);
        }
        let perr = Partition::parse("rows").unwrap_err();
        assert!(
            format!("{perr:#}").contains("modes|batch"),
            "error names the allowed values: {perr:#}"
        );
    }

    #[test]
    fn toml_file_round_trips_partition_and_medium() {
        let path = std::env::temp_dir().join("litl_cfg_stream_test.toml");
        std::fs::write(
            &path,
            "partition = \"batch\"\nmedium = \"streamed\"\nshards = 4\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.partition, Partition::Batch);
        assert_eq!(c.medium, MediumBacking::Streamed);
        assert_eq!(c.shards, 4);
        // Re-emit via name() and reload: the round trip is stable.
        std::fs::write(
            &path,
            format!(
                "partition = \"{}\"\nmedium = \"{}\"\n",
                c.partition.name(),
                c.medium.name()
            ),
        )
        .unwrap();
        let mut c2 = TrainConfig::default();
        c2.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c2.partition, c.partition);
        assert_eq!(c2.medium, c.medium);
    }

    #[test]
    fn toml_file_rejects_invalid_partition_and_medium_with_context() {
        let path = std::env::temp_dir().join("litl_cfg_bad_stream_test.toml");
        for (body, want) in [
            ("partition = \"rows\"\n", "modes|batch"),
            ("medium = \"fourier\"\n", "materialized|streamed"),
        ] {
            std::fs::write(&path, body).unwrap();
            let mut c = TrainConfig::default();
            let err = c.load_file(path.to_str().unwrap()).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(want), "'{body}' → {msg}");
        }
    }

    #[test]
    fn topology_kv_and_toml_section_round_trip() {
        let mut c = TrainConfig::default();
        assert!(c.topology.is_none());
        // The full shorthand works bare through --set (':', '@' and '+'
        // are bare-string chars in the TOML-scalar subset) and quoted.
        c.set_kv("topology=opt:4").unwrap();
        assert_eq!(c.topology.as_ref().unwrap().shorthand(), "opt:4");
        c.set_kv("topology=opt:2@3+dig:1").unwrap();
        assert_eq!(c.topology.as_ref().unwrap().shorthand(), "opt:2@3+dig:1");
        c.set_kv("topology=\"hetero:opt:2@3+dig:1\"").unwrap();
        assert_eq!(c.topology.as_ref().unwrap().shorthand(), "opt:2@3+dig:1");
        assert!(c.set_kv("topology=laser:4").is_err());
        assert!(c.set_kv("topology=\"opt:1@0\"").is_err(), "zero weight");

        // `[topology]` section: the parser flattens it to topology.* keys.
        let path = std::env::temp_dir().join("litl_cfg_topology_test.toml");
        std::fs::write(
            &path,
            "[topology]\nspec = \"opt:2+dig:1\"\npartition = \"batch\"\n\
             medium = \"materialized\"\npool = \"shared\"\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.topology.as_ref().unwrap().shorthand(), "opt:2+dig:1");
        assert_eq!(c.partition, Partition::Batch);
        assert_eq!(c.topology_pool, PoolPolicy::Shared);
        let resolved = c.projection_topology();
        assert_eq!(resolved.partition, Partition::Batch);
        assert_eq!(resolved.pool, PoolPolicy::Shared);
        assert_eq!(resolved.shard_count(), 3);
        assert_eq!(resolved.weights(), vec![1, 1, 1]);
        // Resolution is stable: shorthand → parse → same resolved value.
        let reparsed = Topology::parse(&resolved.shorthand())
            .unwrap()
            .with_partition(c.partition)
            .with_backing(c.medium)
            .with_pool(c.topology_pool);
        assert_eq!(reparsed, resolved);
        assert_eq!(reparsed.stable_hash(), resolved.stable_hash());
    }

    #[test]
    fn projection_topology_defaults_to_the_legacy_knobs() {
        let mut c = TrainConfig::default();
        c.set_kv("shards=4").unwrap();
        c.set_kv("partition=batch").unwrap();
        let t = c.projection_topology();
        assert_eq!(t.shard_count(), 4);
        assert_eq!(t.partition, Partition::Batch);
        assert_eq!(t.weights(), vec![1; 4]);
        assert!(t.is_homogeneous());
        c.set_kv("projector=digital").unwrap();
        assert_eq!(
            c.projection_topology().kind_tag(),
            "farm-digital",
            "projector knob picks the device kind"
        );
    }

    #[test]
    fn validate_projection_rejects_bad_combinations() {
        // --shards off the optical path.
        let mut c = TrainConfig::default();
        c.set_kv("algo=bp").unwrap();
        c.set_kv("shards=2").unwrap();
        assert!(c.validate_projection().is_err());

        // streamed + hlo: the opu_project artifact needs dense tensors.
        let mut c = TrainConfig::default();
        c.set_kv("projector=hlo").unwrap();
        c.set_kv("medium=streamed").unwrap();
        let err = c.validate_projection().unwrap_err().to_string();
        assert!(err.contains("streamed"), "{err}");

        // topology + hlo: the artifact is compiled for one device.
        let mut c = TrainConfig::default();
        c.set_kv("projector=hlo").unwrap();
        c.set_kv("topology=opt:2").unwrap();
        let err = c.validate_projection().unwrap_err().to_string();
        assert!(err.contains("topology"), "{err}");

        // topology + --shards conflict.
        let mut c = TrainConfig::default();
        c.set_kv("topology=opt:2").unwrap();
        c.set_kv("shards=2").unwrap();
        assert!(c.validate_projection().is_err());

        // topology off the optical path.
        let mut c = TrainConfig::default();
        c.set_kv("algo=dfa-float").unwrap();
        c.set_kv("topology=dig:2").unwrap();
        assert!(c.validate_projection().is_err());

        // A valid heterogeneous weighted topology passes.
        let mut c = TrainConfig::default();
        c.set_kv("topology=\"opt:2@2+dig:1\"").unwrap();
        c.set_kv("partition=batch").unwrap();
        c.validate_projection().unwrap();
        assert_eq!(c.projection_topology().weights(), vec![2, 2, 1]);
    }

    #[test]
    fn warm_start_knobs_parse_and_validate() {
        let mut c = TrainConfig::default();
        assert!(c.resume.is_none());
        assert!(c.tile_cache_save.is_none() && c.tile_cache_load.is_none());
        c.set_kv("resume=run.ckpt").unwrap();
        assert_eq!(c.resume.as_deref(), Some("run.ckpt"));
        c.validate_projection().unwrap();
        // Snapshots demand a cache to snapshot: streamed + a budget.
        c.set_kv("tile_cache_save=warm.tiles").unwrap();
        let err = c.validate_projection().unwrap_err().to_string();
        assert!(err.contains("streamed"), "{err}");
        c.set_kv("medium=streamed").unwrap();
        let err = c.validate_projection().unwrap_err().to_string();
        assert!(err.contains("tile-cache-mb"), "{err}");
        c.set_kv("tile_cache_mb=16").unwrap();
        c.set_kv("tile_cache_load=warm.tiles").unwrap();
        c.validate_projection().unwrap();
        // The `[topology]` section spelling maps to the same knobs.
        let path = std::env::temp_dir().join("litl_cfg_warm_start_test.toml");
        std::fs::write(
            &path,
            "[topology]\nmedium = \"streamed\"\ntile_cache_mb = 8\n\
             tile_cache_save = \"a.tiles\"\ntile_cache_load = \"b.tiles\"\n",
        )
        .unwrap();
        let mut c2 = TrainConfig::default();
        c2.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c2.tile_cache_save.as_deref(), Some("a.tiles"));
        assert_eq!(c2.tile_cache_load.as_deref(), Some("b.tiles"));
        c2.validate_projection().unwrap();
    }

    #[test]
    fn net_knobs_parse_validate_and_stamp_the_topology() {
        let mut c = TrainConfig::default();
        assert_eq!(c.net_options(), NetOptions::default());
        c.set_kv("net_connect_timeout_ms=250").unwrap();
        c.set_kv("net_request_timeout_ms=5000").unwrap();
        c.set_kv("net_reconnect_tries=7").unwrap();
        let n = c.net_options();
        assert_eq!(n.connect_timeout_ms, 250);
        assert_eq!(n.request_timeout_ms, 5000);
        assert_eq!(n.reconnect_tries, 7);
        assert!(c.set_kv("net_connect_timeout_ms=0").is_err());
        assert!(c.set_kv("net_request_timeout_ms=0").is_err());
        assert!(c.set_kv("net_reconnect_tries=0").is_err());
        // The resolved topology carries the tuning (without it changing
        // the topology's canonical identity).
        c.set_kv("topology=\"opt:2!tcp:127.0.0.1:9000\"").unwrap();
        let t = c.projection_topology();
        assert_eq!(t.net.reconnect_tries, 7);
        assert_eq!(
            t.stable_hash(),
            Topology::parse("opt:2!tcp:127.0.0.1:9000")
                .unwrap()
                .with_partition(c.partition)
                .stable_hash()
        );
        // The `[net]` section spelling maps to the same knobs.
        let path = std::env::temp_dir().join("litl_cfg_net_section_test.toml");
        std::fs::write(
            &path,
            "[net]\nconnect_timeout_ms = 100\nrequest_timeout_ms = 2000\n\
             reconnect_tries = 2\n",
        )
        .unwrap();
        let mut c2 = TrainConfig::default();
        c2.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c2.net_connect_timeout_ms, 100);
        assert_eq!(c2.net_request_timeout_ms, 2000);
        assert_eq!(c2.net_reconnect_tries, 2);
    }

    #[test]
    fn resume_and_fault_plan_knobs_flow_into_net_options() {
        let mut c = TrainConfig::default();
        assert_eq!(c.net_options().resume_tries, 0, "resume defaults off");
        assert!(c.net_options().faults.is_none());
        c.set_kv("net_resume=true").unwrap();
        assert_eq!(c.net_options().resume_tries, RESUME_TRIES_DEFAULT);
        // The fault-plan spec contains '=' — set_kv splits on the FIRST
        // '=' so the whole spec reaches the parser as the value.
        c.set_kv("fault_plan=seed=7,cut_every=5,corrupt_ppm=20000")
            .unwrap();
        let fp = c.net_options().faults.expect("plan armed");
        assert_eq!(fp.seed, 7);
        assert_eq!(fp.cut_every, 5);
        assert_eq!(fp.corrupt_ppm, 20_000);
        assert!(c.set_kv("fault_plan=bogus_key=1").is_err());
        // Neither knob perturbs the topology's canonical identity.
        c.set_kv("topology=\"opt:2!tcp:127.0.0.1:9000\"").unwrap();
        assert_eq!(
            c.projection_topology().stable_hash(),
            Topology::parse("opt:2!tcp:127.0.0.1:9000")
                .unwrap()
                .with_partition(c.partition)
                .stable_hash()
        );
        // The `[net]` section spelling maps to the same knobs.
        let path = std::env::temp_dir().join("litl_cfg_net_resume_test.toml");
        std::fs::write(
            &path,
            "[net]\nresume = true\nfault_plan = \"seed=3,dev_err_ppm=1000\"\n",
        )
        .unwrap();
        let mut c2 = TrainConfig::default();
        c2.load_file(path.to_str().unwrap()).unwrap();
        assert!(c2.net_resume);
        assert_eq!(c2.fault_plan.unwrap().dev_err_ppm, 1000);
    }

    #[test]
    fn topology_with_remote_endpoint_parses_through_config() {
        let mut c = TrainConfig::default();
        c.set_kv("topology=\"opt:1!tcp:127.0.0.1:9000+dig:1\"").unwrap();
        c.validate_projection().unwrap();
        let t = c.projection_topology();
        assert_eq!(t.shard_count(), 2);
        assert_eq!(t.shards[0].endpoint.as_deref(), Some("tcp:127.0.0.1:9000"));
        assert!(t.shards[1].endpoint.is_none());
        assert!(c.set_kv("topology=\"opt:1!nowhere\"").is_err());
    }

    #[test]
    fn set_kv_accepts_bare_strings() {
        let mut c = TrainConfig::default();
        c.set_kv("algo=dfa-ternary").unwrap();
        assert_eq!(c.algo, Algo::DfaTernary);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = TrainConfig::default();
        assert!(c.set_kv("nope=1").is_err());
    }

    #[test]
    fn load_file_roundtrip() {
        let path = std::env::temp_dir().join("litl_cfg_test.toml");
        std::fs::write(
            &path,
            "# experiment\nepochs = 2\nalgo = \"dfa-float\"\ntheta = -1.0\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.epochs, 2);
        assert_eq!(c.algo, Algo::DfaFloat);
        assert_eq!(c.theta, -1.0);
    }
}
