//! The versioned wire format for networked projector servers.
//!
//! Every message on the link is one *frame*:
//!
//! ```text
//! magic    "LITL"              4 bytes
//! version  u16 LE              = 2
//! opcode   u16 LE              (see the OP_* constants)
//! len      u32 LE              payload byte count (<= MAX_PAYLOAD)
//! payload  len bytes
//! crc32    u32 LE              over version..payload (flate2's crc)
//! ```
//!
//! Design rules, in order:
//!
//! 1. **Never trust a length field.**  `len` is capped at
//!    [`MAX_PAYLOAD`] before any allocation, the allocation itself goes
//!    through `try_reserve_exact` (an adversarial header cannot abort
//!    the process), and tensor dimensions are re-capped inside the
//!    payload decode.
//! 2. **Typed errors, never panics.**  Every malformed input — short
//!    read, wrong magic, wrong version, unknown opcode, corrupt CRC,
//!    trailing bytes — maps to a [`WireError`] variant.  The decode
//!    robustness suite at the bottom of this file feeds truncations and
//!    bit flips at every byte position and requires an `Err`, not a
//!    panic.
//! 3. **Bit-exact tensors.**  `f32` values travel as their IEEE-754
//!    bits (`to_bits`/`from_bits`, little-endian), so a projection that
//!    crossed the wire is the same bits as one that never left the
//!    process — the parity pin in `tests/net_parity.rs` depends on it.
//!
//! The message vocabulary ([`Msg`]) is the projector-service submission
//! protocol, promoted: a client greets a shard (`Hello`/`HelloOk`,
//! carrying the device's modes/kind so the client can stand in for it
//! behind the [`crate::coordinator::projector::Projector`] trait, plus
//! a client-chosen session id for the server's replay journal), submits
//! frames (`Project`/`ProjectOk`, the request carrying a monotone
//! per-shard frame sequence number, the reply carrying the server-side
//! cumulative sim-clock and energy account), re-attaches after a
//! redial (`Resume`/`ResumeOk`, the session-resume handshake: the
//! client states the last sequence number it holds a reply for and the
//! server answers with its journal cursor, so an in-flight frame can be
//! re-requested *exactly once* — see `super::server` for the journal
//! semantics), and probes liveness (`Health`/`HealthOk`).  Any
//! server-side failure travels as `Error` with a machine-readable code
//! (the `ERR_*` constants) and a message, so a client never hangs on a
//! reply and can distinguish retryable conditions (an injected device
//! fault, a framing desync) from fatal ones (an application error, a
//! journal-cursor mismatch).
//!
//! **v1 → v2:** `Hello` gained `session`, `Project` gained `seq`,
//! `Error` gained `code`, and the `Resume`/`ResumeOk` pair is new.  The
//! layouts are incompatible, so the version was bumped: a v1 peer is
//! rejected with a typed [`WireError::BadVersion`] before any payload
//! is trusted.

use std::fmt;
use std::io::{Read, Write};

use crate::tensor::Tensor;

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"LITL";
/// Wire protocol version (bump on any incompatible layout change).
/// v2: session-resume handshake — `Hello` carries a session id,
/// `Project` a frame sequence number, `Error` a typed code, and the
/// `Resume`/`ResumeOk` opcodes exist.
pub const VERSION: u16 = 2;
/// Fixed header size: magic + version + opcode + payload length.
pub const HEADER_LEN: usize = 12;
/// Trailing CRC size.
pub const CRC_LEN: usize = 4;
/// Hard cap on a payload an untrusted peer can declare (1 GiB).
pub const MAX_PAYLOAD: u32 = 1 << 30;
/// Hard cap on either tensor dimension inside a payload.
pub const MAX_TENSOR_DIM: u32 = 1 << 24;

// Opcodes (request/response pairs, then the error/health singles).
pub const OP_HELLO: u16 = 1;
pub const OP_HELLO_OK: u16 = 2;
pub const OP_PROJECT: u16 = 3;
pub const OP_PROJECT_OK: u16 = 4;
pub const OP_ERROR: u16 = 5;
pub const OP_HEALTH: u16 = 6;
pub const OP_HEALTH_OK: u16 = 7;
pub const OP_RESUME: u16 = 8;
pub const OP_RESUME_OK: u16 = 9;

// `Msg::Error` codes: machine-readable failure classes, so clients can
// route without parsing prose.
/// The projection itself failed (device error / panic): fatal for this
/// frame — the client surfaces it to the failover plane, never retries.
pub const ERR_APP: u16 = 1;
/// The server could not trust this connection's framing (bad CRC,
/// truncation, …) and will close it: the request is retryable after a
/// redial + resume.
pub const ERR_PROTO: u16 = 2;
/// Transient server-side unavailability (e.g. an injected device error
/// burst): the request was NOT executed and may be retried as-is.
pub const ERR_UNAVAILABLE: u16 = 3;
/// Session-resume cursor mismatch: the server cannot prove the
/// in-flight frame's fate (journal evicted, server restarted, or a
/// stale session).  Fatal — the client errors deterministically into
/// failover instead of risking a double noise draw.
pub const ERR_CURSOR: u16 = 4;

/// Typed decode/transport failure.  Every variant is a protocol or I/O
/// condition a hostile or broken peer can cause; none of them panic.
#[derive(Debug)]
pub enum WireError {
    /// The peer closed the connection cleanly at a frame boundary.
    Closed,
    /// The stream ended (or a field overran its buffer) mid-frame.
    Truncated,
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version field differs from [`VERSION`].
    BadVersion(u16),
    /// Opcode outside the known vocabulary.
    BadOpcode(u16),
    /// A declared length exceeded its cap — rejected *before* any
    /// allocation or read.
    Oversize(u64),
    /// CRC32 over the frame body disagreed with the trailer.
    BadCrc { want: u32, got: u32 },
    /// `try_reserve` refused the (already capped) allocation.
    Alloc(usize),
    /// Structurally invalid payload (trailing bytes, bad UTF-8, …).
    Malformed(&'static str),
    /// Underlying transport error (timeouts surface here).
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported wire version {v} (want {VERSION})")
            }
            WireError::BadOpcode(op) => write!(f, "unknown opcode {op}"),
            WireError::Oversize(n) => write!(f, "declared length {n} exceeds cap"),
            WireError::BadCrc { want, got } => {
                write!(f, "frame CRC mismatch (want {want:08x}, got {got:08x})")
            }
            WireError::Alloc(n) => write!(f, "allocation of {n} bytes refused"),
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e)
        }
    }
}

/// The message vocabulary carried over frames.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Client → server: bind this connection's requests to `shard`.
    /// `session` keys the server's replay journal; 0 opts out of
    /// journaling entirely (the pre-resume semantics).
    Hello { shard: u32, session: u64 },
    /// Server → client: the greeted shard's device identity, so the
    /// remote client can answer `Projector` queries locally.
    HelloOk {
        modes: u32,
        requires_ternary: bool,
        kind: String,
    },
    /// Client → server: project `frames` on `shard`.  `seq` is the
    /// client's monotone per-shard frame number (1-based); the server's
    /// journal dedups on it so a resumed re-request executes exactly
    /// once.
    Project { shard: u32, seq: u64, frames: Tensor },
    /// Server → client: the two quadratures plus the shard device's
    /// *cumulative* sim-clock/energy account after this projection.
    ProjectOk {
        p1: Tensor,
        p2: Tensor,
        sim_seconds: f64,
        energy_joules: f64,
    },
    /// Client → server after a redial: `cursor` is the last seq the
    /// client holds a reply for; the server answers `ResumeOk` with its
    /// journal cursor (== `cursor` if the in-flight frame never
    /// executed, `cursor + 1` if it did and the reply is replayable) or
    /// `Error { code: ERR_CURSOR }` if it cannot prove either.
    Resume { session: u64, shard: u32, cursor: u64 },
    /// Server → client: the journal cursor for the resumed session.
    ResumeOk { cursor: u64 },
    /// Server → client: the request failed; `code` is one of the
    /// `ERR_*` constants, the message explains why.
    Error { code: u16, message: String },
    /// Liveness probe.
    Health,
    /// Liveness reply.
    HealthOk,
}

// ---------------------------------------------------------------------------
// Frame transport

/// Read exactly `buf.len()` bytes.  `clean_eof` marks a frame boundary:
/// EOF before the first byte is a graceful [`WireError::Closed`], EOF
/// anywhere else is [`WireError::Truncated`].
fn read_full(r: &mut impl Read, buf: &mut [u8], clean_eof: bool) -> Result<(), WireError> {
    let mut at = 0usize;
    while at < buf.len() {
        match r.read(&mut buf[at..]) {
            Ok(0) => {
                return Err(if clean_eof && at == 0 {
                    WireError::Closed
                } else {
                    WireError::Truncated
                })
            }
            Ok(n) => at += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::from(e)),
        }
    }
    Ok(())
}

/// Read one frame: validates magic, version, the length cap, and the
/// CRC; returns the raw `(opcode, payload)`.  Opcode vocabulary is
/// checked by [`decode`], not here, so a future version can skip
/// unknown frames without re-parsing.
pub fn read_frame(r: &mut impl Read) -> Result<(u16, Vec<u8>), WireError> {
    let mut header = [0u8; HEADER_LEN];
    read_full(r, &mut header, true)?;
    if header[..4] != MAGIC {
        return Err(WireError::BadMagic([header[0], header[1], header[2], header[3]]));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        return Err(WireError::BadVersion(version));
    }
    let opcode = u16::from_le_bytes([header[6], header[7]]);
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]);
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversize(len as u64));
    }
    let mut payload: Vec<u8> = Vec::new();
    payload
        .try_reserve_exact(len as usize)
        .map_err(|_| WireError::Alloc(len as usize))?;
    payload.resize(len as usize, 0);
    read_full(r, &mut payload, false)?;
    let mut crc_bytes = [0u8; CRC_LEN];
    read_full(r, &mut crc_bytes, false)?;
    let want = u32::from_le_bytes(crc_bytes);
    let mut hasher = flate2::Crc::new();
    hasher.update(&header[4..]);
    hasher.update(&payload);
    let got = hasher.sum();
    if got != want {
        return Err(WireError::BadCrc { want, got });
    }
    Ok((opcode, payload))
}

/// Write one frame; returns the total bytes put on the wire (for the
/// `net_bytes_tx` counter).
pub fn write_frame(w: &mut impl Write, opcode: u16, payload: &[u8]) -> Result<usize, WireError> {
    if payload.len() as u64 > MAX_PAYLOAD as u64 {
        return Err(WireError::Oversize(payload.len() as u64));
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4..6].copy_from_slice(&VERSION.to_le_bytes());
    header[6..8].copy_from_slice(&opcode.to_le_bytes());
    header[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    let mut hasher = flate2::Crc::new();
    hasher.update(&header[4..]);
    hasher.update(payload);
    let crc = hasher.sum().to_le_bytes();
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.write_all(&crc)?;
    Ok(HEADER_LEN + payload.len() + CRC_LEN)
}

/// Encode + write one message; returns bytes written.
pub fn send(w: &mut impl Write, msg: &Msg) -> Result<usize, WireError> {
    let (opcode, payload) = encode(msg);
    write_frame(w, opcode, &payload)
}

/// Read + decode one message; returns it with the bytes read (for the
/// `net_bytes_rx` counter).
pub fn recv(r: &mut impl Read) -> Result<(Msg, usize), WireError> {
    let (opcode, payload) = read_frame(r)?;
    let n = HEADER_LEN + payload.len() + CRC_LEN;
    Ok((decode(opcode, &payload)?, n))
}

// ---------------------------------------------------------------------------
// Payload codec

/// Bounds-checked little-endian payload reader.
struct Dec<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, at: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if n > self.buf.len() - self.at {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        // The `try_into().unwrap()`s below are infallible, not hostile-
        // reachable: `bytes(n)` either returns exactly `n` bytes or a
        // typed `Truncated` — the conversion can only see a correctly
        // sized slice.  (Audited; the decoder property fuzz at the
        // bottom of this file exercises every truncation.)
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(u64::from_le_bytes(
            self.bytes(8)?.try_into().unwrap(),
        )))
    }

    /// Decode must consume the payload exactly — trailing bytes mean a
    /// peer speaking a different dialect.
    fn done(&self) -> Result<(), WireError> {
        if self.at != self.buf.len() {
            return Err(WireError::Malformed("trailing payload bytes"));
        }
        Ok(())
    }
}

/// `[rows, cols]` + bit-exact little-endian f32 data.
fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    out.extend_from_slice(&(t.rows() as u32).to_le_bytes());
    out.extend_from_slice(&(t.cols() as u32).to_le_bytes());
    for &v in t.data() {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

fn get_tensor(d: &mut Dec) -> Result<Tensor, WireError> {
    let rows = d.u32()?;
    let cols = d.u32()?;
    if rows > MAX_TENSOR_DIM || cols > MAX_TENSOR_DIM {
        return Err(WireError::Oversize(rows.max(cols) as u64));
    }
    let numel = rows as u64 * cols as u64;
    if numel * 4 > MAX_PAYLOAD as u64 {
        return Err(WireError::Oversize(numel * 4));
    }
    let raw = d.bytes(numel as usize * 4)?;
    let mut data: Vec<f32> = Vec::new();
    data.try_reserve_exact(numel as usize)
        .map_err(|_| WireError::Alloc(numel as usize * 4))?;
    for c in raw.chunks_exact(4) {
        data.push(f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())));
    }
    Ok(Tensor::from_vec(&[rows as usize, cols as usize], data))
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(d: &mut Dec) -> Result<String, WireError> {
    let n = d.u32()?;
    if n > MAX_PAYLOAD {
        return Err(WireError::Oversize(n as u64));
    }
    let raw = d.bytes(n as usize)?;
    String::from_utf8(raw.to_vec()).map_err(|_| WireError::Malformed("non-utf8 string"))
}

/// Encode a message into `(opcode, payload)`.
pub fn encode(msg: &Msg) -> (u16, Vec<u8>) {
    let mut p = Vec::new();
    let op = match msg {
        Msg::Hello { shard, session } => {
            p.extend_from_slice(&shard.to_le_bytes());
            p.extend_from_slice(&session.to_le_bytes());
            OP_HELLO
        }
        Msg::HelloOk {
            modes,
            requires_ternary,
            kind,
        } => {
            p.extend_from_slice(&modes.to_le_bytes());
            p.push(u8::from(*requires_ternary));
            put_str(&mut p, kind);
            OP_HELLO_OK
        }
        Msg::Project { shard, seq, frames } => {
            p.extend_from_slice(&shard.to_le_bytes());
            p.extend_from_slice(&seq.to_le_bytes());
            put_tensor(&mut p, frames);
            OP_PROJECT
        }
        Msg::ProjectOk {
            p1,
            p2,
            sim_seconds,
            energy_joules,
        } => {
            put_tensor(&mut p, p1);
            put_tensor(&mut p, p2);
            p.extend_from_slice(&sim_seconds.to_bits().to_le_bytes());
            p.extend_from_slice(&energy_joules.to_bits().to_le_bytes());
            OP_PROJECT_OK
        }
        Msg::Resume {
            session,
            shard,
            cursor,
        } => {
            p.extend_from_slice(&session.to_le_bytes());
            p.extend_from_slice(&shard.to_le_bytes());
            p.extend_from_slice(&cursor.to_le_bytes());
            OP_RESUME
        }
        Msg::ResumeOk { cursor } => {
            p.extend_from_slice(&cursor.to_le_bytes());
            OP_RESUME_OK
        }
        Msg::Error { code, message } => {
            p.extend_from_slice(&code.to_le_bytes());
            put_str(&mut p, message);
            OP_ERROR
        }
        Msg::Health => OP_HEALTH,
        Msg::HealthOk => OP_HEALTH_OK,
    };
    (op, p)
}

/// Decode a raw `(opcode, payload)` into a [`Msg`].
pub fn decode(opcode: u16, payload: &[u8]) -> Result<Msg, WireError> {
    let mut d = Dec::new(payload);
    let msg = match opcode {
        OP_HELLO => Msg::Hello {
            shard: d.u32()?,
            session: d.u64()?,
        },
        OP_HELLO_OK => Msg::HelloOk {
            modes: d.u32()?,
            requires_ternary: d.u8()? != 0,
            kind: get_str(&mut d)?,
        },
        OP_PROJECT => Msg::Project {
            shard: d.u32()?,
            seq: d.u64()?,
            frames: get_tensor(&mut d)?,
        },
        OP_PROJECT_OK => Msg::ProjectOk {
            p1: get_tensor(&mut d)?,
            p2: get_tensor(&mut d)?,
            sim_seconds: d.f64()?,
            energy_joules: d.f64()?,
        },
        OP_RESUME => Msg::Resume {
            session: d.u64()?,
            shard: d.u32()?,
            cursor: d.u64()?,
        },
        OP_RESUME_OK => Msg::ResumeOk { cursor: d.u64()? },
        OP_ERROR => Msg::Error {
            code: d.u16()?,
            message: get_str(&mut d)?,
        },
        OP_HEALTH => Msg::Health,
        OP_HEALTH_OK => Msg::HealthOk,
        other => return Err(WireError::BadOpcode(other)),
    };
    d.done()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn frame_bytes(msg: &Msg) -> Vec<u8> {
        let mut out = Vec::new();
        send(&mut out, msg).unwrap();
        out
    }

    fn sample_msgs() -> Vec<Msg> {
        let mut rng = Pcg64::seeded(42);
        let t1 = Tensor::randn(&[3, 5], &mut rng, 1.0);
        let t2 = Tensor::randn(&[3, 5], &mut rng, 2.0);
        vec![
            Msg::Hello {
                shard: 7,
                session: 0xDEAD_BEEF_0042,
            },
            Msg::HelloOk {
                modes: 128,
                requires_ternary: true,
                kind: "optical-native".into(),
            },
            Msg::Project {
                shard: 2,
                seq: 19,
                frames: t1.clone(),
            },
            Msg::ProjectOk {
                p1: t1,
                p2: t2,
                sim_seconds: 0.125,
                energy_joules: 3.75,
            },
            Msg::Resume {
                session: 0xDEAD_BEEF_0042,
                shard: 2,
                cursor: 18,
            },
            Msg::ResumeOk { cursor: 19 },
            Msg::Error {
                code: ERR_APP,
                message: "shard 9 not hosted here".into(),
            },
            Msg::Health,
            Msg::HealthOk,
        ]
    }

    #[test]
    fn every_message_roundtrips_bit_exactly() {
        for msg in sample_msgs() {
            let bytes = frame_bytes(&msg);
            let mut r = &bytes[..];
            let (back, n) = recv(&mut r).unwrap();
            assert_eq!(back, msg);
            assert_eq!(n, bytes.len());
            assert!(r.is_empty(), "reader consumed the exact frame");
        }
    }

    #[test]
    fn tensor_bits_survive_the_wire() {
        // Values a lossy text/float path would mangle: negative zero,
        // subnormals, extreme magnitudes.
        let t = Tensor::from_vec(
            &[1, 4],
            vec![-0.0f32, f32::MIN_POSITIVE / 2.0, 3.4e38, -1.1754944e-38],
        );
        let msg = Msg::Project {
            shard: 0,
            seq: 1,
            frames: t.clone(),
        };
        let bytes = frame_bytes(&msg);
        let (back, _) = recv(&mut &bytes[..]).unwrap();
        let Msg::Project { frames, .. } = back else {
            panic!("wrong opcode back")
        };
        for (a, b) in t.data().iter().zip(frames.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn clean_eof_at_frame_boundary_is_closed() {
        let empty: &[u8] = &[];
        assert!(matches!(recv(&mut &empty[..]), Err(WireError::Closed)));
    }

    #[test]
    fn every_truncation_point_is_a_typed_error() {
        let bytes = frame_bytes(&Msg::Hello { shard: 3, session: 9 });
        for cut in 1..bytes.len() {
            let err = recv(&mut &bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated),
                "cut at {cut}: {err}"
            );
        }
    }

    #[test]
    fn corrupted_crc_is_detected() {
        let mut bytes = frame_bytes(&Msg::Hello { shard: 3, session: 9 });
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        assert!(matches!(
            recv(&mut &bytes[..]),
            Err(WireError::BadCrc { .. })
        ));
    }

    #[test]
    fn wrong_magic_and_version_are_rejected() {
        let mut bytes = frame_bytes(&Msg::Health);
        bytes[0] = b'X';
        assert!(matches!(recv(&mut &bytes[..]), Err(WireError::BadMagic(_))));

        let mut bytes = frame_bytes(&Msg::Health);
        bytes[4] = 0xff; // version LE low byte
        assert!(matches!(
            recv(&mut &bytes[..]),
            Err(WireError::BadVersion(_))
        ));
    }

    #[test]
    fn unknown_opcode_is_rejected() {
        let mut out = Vec::new();
        write_frame(&mut out, 0x7777, b"").unwrap();
        assert!(matches!(
            recv(&mut &out[..]),
            Err(WireError::BadOpcode(0x7777))
        ));
    }

    #[test]
    fn oversize_length_is_rejected_before_allocation() {
        // A header claiming u32::MAX payload bytes, followed by nothing:
        // must fail on the cap *without* attempting the allocation or a
        // read (the reader behind it is empty, so an attempted read
        // would surface Truncated instead).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&OP_HEALTH.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            recv(&mut &bytes[..]),
            Err(WireError::Oversize(_))
        ));
    }

    #[test]
    fn oversize_tensor_dims_are_rejected() {
        // A legal frame whose *payload* declares an absurd tensor: the
        // inner caps must catch it even though the frame layer passed.
        let mut p = Vec::new();
        p.extend_from_slice(&0u32.to_le_bytes()); // shard
        p.extend_from_slice(&(MAX_TENSOR_DIM + 1).to_le_bytes()); // rows
        p.extend_from_slice(&1u32.to_le_bytes()); // cols
        let mut out = Vec::new();
        write_frame(&mut out, OP_PROJECT, &p).unwrap();
        assert!(matches!(
            recv(&mut &out[..]),
            Err(WireError::Oversize(_))
        ));
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        let mut p = 5u32.to_le_bytes().to_vec();
        p.extend_from_slice(&7u64.to_le_bytes()); // session
        p.push(0xAB); // one byte beyond Hello's fixed payload
        let mut out = Vec::new();
        write_frame(&mut out, OP_HELLO, &p).unwrap();
        assert!(matches!(
            recv(&mut &out[..]),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn v1_frames_are_rejected_with_a_typed_bad_version() {
        // A pre-resume (v1) peer: same magic, version 1, a v1 Hello
        // payload (bare shard id).  The version gate must fire before
        // the payload shape is ever trusted.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&1u16.to_le_bytes());
        bytes.extend_from_slice(&OP_HELLO.to_le_bytes());
        let payload = 3u32.to_le_bytes();
        bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        let mut hasher = flate2::Crc::new();
        hasher.update(&bytes[4..]);
        hasher.update(&payload);
        let crc = hasher.sum().to_le_bytes();
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&crc);
        assert!(matches!(
            recv(&mut &bytes[..]),
            Err(WireError::BadVersion(1))
        ));
    }

    #[test]
    fn single_bit_flips_never_panic_and_never_pass_silently() {
        // Flip one bit at every position of a valid frame: decode must
        // return *something* (Ok only if the flip cancels out, which a
        // CRC makes practically impossible here) and must never panic.
        for msg in sample_msgs() {
            let clean = frame_bytes(&msg);
            for pos in 0..clean.len() {
                let mut dirty = clean.clone();
                dirty[pos] ^= 1 << (pos % 8);
                let res = recv(&mut &dirty[..]);
                assert!(res.is_err(), "bit flip at {pos} decoded silently");
            }
        }
    }

    #[test]
    fn random_garbage_never_panics() {
        let mut rng = Pcg64::seeded(9);
        for len in [0usize, 1, 7, 12, 13, 40, 256] {
            for _ in 0..64 {
                let bytes: Vec<u8> =
                    (0..len).map(|_| rng.next_below(256) as u8).collect();
                let _ = recv(&mut &bytes[..]); // must not panic
            }
        }
    }

    /// Seeded property fuzz beyond single-bit flips: mutated length
    /// fields, truncations at arbitrary offsets, and opcode/version
    /// extremes.  Every mutation must yield a typed [`WireError`] —
    /// never a panic, and never an allocation driven by the hostile
    /// length (the `Oversize` cap and `try_reserve` guard fire before
    /// any buffer exists).
    #[test]
    fn decoder_property_fuzz_yields_typed_errors_only() {
        let mut rng = Pcg64::seeded(0xC4A05);
        let samples: Vec<Vec<u8>> = sample_msgs().iter().map(frame_bytes).collect();
        for round in 0..200u64 {
            let clean = &samples[(round % samples.len() as u64) as usize];
            let mut dirty = clean.clone();
            match rng.next_below(5) {
                // Length field rewritten to an arbitrary u32 (including
                // values far beyond the real payload and beyond
                // MAX_PAYLOAD): the frame layer must either cap it or
                // fail the read/CRC — never trust it.
                0 => {
                    let len = rng.next_u64() as u32;
                    dirty[8..12].copy_from_slice(&len.to_le_bytes());
                }
                // Truncation at an arbitrary byte offset.
                1 => {
                    let cut = 1 + rng.next_below(dirty.len() as u64 - 1) as usize;
                    dirty.truncate(cut);
                }
                // Opcode extremes: 0, u16::MAX, and random unknowns.
                2 => {
                    let op = match rng.next_below(3) {
                        0 => 0u16,
                        1 => u16::MAX,
                        _ => rng.next_u64() as u16,
                    };
                    dirty[6..8].copy_from_slice(&op.to_le_bytes());
                }
                // Version extremes: 0, u16::MAX, VERSION±1.
                3 => {
                    let v = match rng.next_below(4) {
                        0 => 0u16,
                        1 => u16::MAX,
                        2 => VERSION.wrapping_sub(1),
                        _ => VERSION + 1,
                    };
                    dirty[4..6].copy_from_slice(&v.to_le_bytes());
                }
                // A random splice of garbage bytes mid-frame.
                _ => {
                    let at = rng.next_below(dirty.len() as u64) as usize;
                    let n = 1 + rng.next_below(16) as usize;
                    for i in 0..n {
                        if at + i < dirty.len() {
                            dirty[at + i] = rng.next_below(256) as u8;
                        }
                    }
                }
            }
            if dirty == *clean {
                continue; // the splice can no-op; nothing to assert
            }
            let res = recv(&mut &dirty[..]);
            assert!(
                res.is_err(),
                "round {round}: mutated frame decoded silently"
            );
        }
    }

    /// The declared-length mutations above must be rejected *by type*:
    /// anything above MAX_PAYLOAD is `Oversize` before any allocation,
    /// anything below the real payload breaks the CRC or framing.
    #[test]
    fn mutated_length_fields_never_drive_allocation() {
        let clean = frame_bytes(&Msg::Health);
        for len in [MAX_PAYLOAD + 1, u32::MAX, u32::MAX - 1] {
            let mut dirty = clean.clone();
            dirty[8..12].copy_from_slice(&len.to_le_bytes());
            assert!(matches!(
                recv(&mut &dirty[..]),
                Err(WireError::Oversize(_))
            ));
        }
    }
}
