//! The remote projector client: a [`Projector`] whose device lives in
//! another process.
//!
//! One [`RemoteProjector`] owns one connection to one shard of a
//! [`super::server::ProjectorServer`].  Construction dials eagerly and
//! exchanges `Hello`/`HelloOk`, caching the remote device's identity
//! (modes, ternary requirement, kind) so every `Projector` query after
//! that is answered locally; each `project` call is one
//! `Project`/`ProjectOk` round trip carrying a monotone per-shard frame
//! sequence number.
//!
//! **Failure semantics** (load-bearing for the serving layer's
//! failover): a connection is (re)established with bounded
//! exponential-backoff dial attempts, and what happens to an
//! *in-flight* request depends on the resume budget
//! ([`NetOptions::resume_tries`]):
//!
//! * **Resume off** (`resume_tries == 0`, the default): an in-flight
//!   request is never retried — a blindly resent frame would advance
//!   the remote device's noise stream a second time and silently
//!   diverge the bits.  Any transport error or reply timeout
//!   mid-request kills the connection and surfaces as `Err`, which the
//!   sharded service counts toward its error-streak trip; the *next*
//!   request redials (counting `net_reconnects`).  This is the pre-v2
//!   behavior, byte for byte.
//! * **Resume on**: the client greets with a nonzero session id, and a
//!   failed attempt redials, re-attaches the session with a
//!   `Resume`/`ResumeOk` cursor handshake (counting `net_resumes`),
//!   and re-requests the same sequence number — safe because the
//!   server's replay journal executes each `(session, seq)` exactly
//!   once and replays the journaled reply otherwise.  Fatal replies
//!   (`ERR_APP`, `ERR_CURSOR`) are never retried: they surface
//!   immediately so failover trips deterministically instead of
//!   burning the budget.
//!
//! When a [`FaultPlanCfg`] is armed ([`NetOptions::faults`]), the send
//! path injects the plan's wire faults — stalls, connection cuts,
//! partial writes, single-bit corruption — keyed on this client's
//! per-shard send-attempt counter, so chaos drills are reproducible
//! and retried attempts draw fresh decisions.  No plan means a single
//! `Option` test per request.

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::frame::{self, Msg, ERR_PROTO, ERR_UNAVAILABLE};
use super::{
    Addr, FaultPlanCfg, NetOptions, NetStream, NET_BYTES_RX, NET_BYTES_TX, NET_FAULTS_INJECTED,
    NET_FRAMES_RX, NET_FRAMES_TX, NET_RECONNECTS, NET_RESUMES, NET_RTT,
};
use crate::coordinator::projector::Projector;
use crate::metrics::trace::{self, STAGE_NET_RECV, STAGE_NET_RESUME, STAGE_NET_SEND};
use crate::metrics::{Counter, Histogram, Registry};
use crate::tensor::Tensor;

/// A process-unique, nonzero session id for the server's replay
/// journal.  Uniqueness (not secrecy) is the requirement: two clients
/// sharing an id would cross their journal cursors.  The id never
/// feeds any training draw, so wall-clock entropy here cannot perturb
/// the math.
fn fresh_session_id() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let x = (std::process::id() as u64)
        ^ nanos
        ^ COUNTER
            .fetch_add(1, Ordering::Relaxed)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
    // splitmix64 finalizer: spreads the xor'd entropy over all bits.
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    z.max(1)
}

/// One attempt's failure, classified for the resume loop.
enum Fail {
    /// Never retried: the frame's fate is decided (app error, cursor
    /// mismatch, protocol confusion on *our* side).
    Fatal(anyhow::Error),
    /// Retryable within the resume budget (dead transport, injected
    /// fault, transient server unavailability).  With resume off the
    /// budget is 1, so this surfaces unchanged.
    Retry(anyhow::Error),
}

/// Client half of one remote shard.
pub struct RemoteProjector {
    addr: Addr,
    shard: u32,
    opts: NetOptions,
    conn: Option<NetStream>,
    /// Nonzero iff session resume is enabled — 0 tells the server to
    /// skip journaling for this client entirely.
    session: u64,
    /// Last sequence number we hold a `ProjectOk` for; the next frame
    /// is `acked + 1`, and a resume handshake states this cursor.
    acked: u64,
    /// Whether any hello ever succeeded: the resume handshake only
    /// runs on *re*connects (a first connect has nothing in flight).
    ever_connected: bool,
    /// Armed fault plan (pre-filtered: `None` if absent or a no-op).
    faults: Option<FaultPlanCfg>,
    /// Send-attempt counter keying the client-side fault schedule.
    send_attempts: u64,
    // Cached from HelloOk.
    modes: usize,
    requires_ternary: bool,
    // Server-side cumulative accounts, updated from each ProjectOk.
    sim_seconds: f64,
    energy_joules: f64,
    // Observability.
    frames_tx: Counter,
    frames_rx: Counter,
    bytes_tx: Counter,
    bytes_rx: Counter,
    reconnects: Counter,
    resumes: Counter,
    faults_injected: Counter,
    rtt: Histogram,
}

impl RemoteProjector {
    /// Dial `addr`, greet `shard`, and cache its identity.  Fails fast
    /// (after the bounded dial attempts) if the server is unreachable —
    /// a topology build should not hand out dead devices.
    pub fn connect(
        addr: &Addr,
        shard: u32,
        opts: NetOptions,
        metrics: &Registry,
    ) -> Result<RemoteProjector> {
        let session = if opts.resume_tries > 0 {
            fresh_session_id()
        } else {
            0
        };
        let faults = opts.faults.filter(|f| !f.is_noop());
        let mut rp = RemoteProjector {
            addr: addr.clone(),
            shard,
            opts,
            conn: None,
            session,
            acked: 0,
            ever_connected: false,
            faults,
            send_attempts: 0,
            modes: 0,
            requires_ternary: true,
            sim_seconds: 0.0,
            energy_joules: 0.0,
            frames_tx: metrics.counter(NET_FRAMES_TX),
            frames_rx: metrics.counter(NET_FRAMES_RX),
            bytes_tx: metrics.counter(NET_BYTES_TX),
            bytes_rx: metrics.counter(NET_BYTES_RX),
            reconnects: metrics.counter(NET_RECONNECTS),
            resumes: metrics.counter(NET_RESUMES),
            faults_injected: metrics.counter(NET_FAULTS_INJECTED),
            rtt: metrics.histogram(NET_RTT),
        };
        rp.ensure_conn(true)
            .with_context(|| format!("connecting to projector server {addr} shard {shard}"))?;
        Ok(rp)
    }

    /// The endpoint this client talks to.
    pub fn addr(&self) -> &Addr {
        &self.addr
    }

    /// The remote shard id.
    pub fn shard(&self) -> u32 {
        self.shard
    }

    /// Dial + greet with bounded exponential backoff.  `first` skips
    /// the reconnect counter (an initial connect is not a reconnect).
    fn ensure_conn(&mut self, first: bool) -> Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        if !first {
            self.reconnects.inc();
        }
        let tries = self.opts.reconnect_tries.max(1);
        let mut backoff = Duration::from_millis(self.opts.reconnect_base_ms);
        let mut last_err = None;
        for attempt in 0..tries {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff = (backoff * 2)
                    .min(Duration::from_millis(self.opts.reconnect_max_ms));
            }
            match NetStream::connect(
                &self.addr,
                Duration::from_millis(self.opts.connect_timeout_ms),
            ) {
                Ok(stream) => match self.hello(stream) {
                    Ok(()) => return Ok(()),
                    Err(e) => last_err = Some(e),
                },
                Err(e) => last_err = Some(e),
            }
        }
        bail!(
            "projector server {} unreachable after {tries} attempts: {}",
            self.addr,
            last_err.map_or_else(|| "no error recorded".into(), |e| e.to_string())
        )
    }

    fn hello(&mut self, mut stream: NetStream) -> Result<()> {
        stream.set_read_timeout(Some(Duration::from_millis(self.opts.request_timeout_ms)))?;
        let n = frame::send(
            &mut stream,
            &Msg::Hello {
                shard: self.shard,
                session: self.session,
            },
        )?;
        stream.flush()?;
        self.frames_tx.inc();
        self.bytes_tx.add(n as u64);
        let (reply, n) = frame::recv(&mut stream)?;
        self.frames_rx.inc();
        self.bytes_rx.add(n as u64);
        match reply {
            Msg::HelloOk {
                modes,
                requires_ternary,
                kind: _,
            } => {
                self.modes = modes as usize;
                self.requires_ternary = requires_ternary;
            }
            Msg::Error { message, .. } => bail!("server rejected hello: {message}"),
            other => bail!("unexpected hello reply {other:?}"),
        }
        // Session-resume handshake, reconnects only: state the last seq
        // we hold a reply for, and require the server's journal cursor
        // to be there or exactly one ahead (the in-flight frame
        // executed and its reply is replayable).  Anything else means
        // the server cannot prove our in-flight frame's fate — error
        // out so failover trips instead of risking a double draw.
        if self.session != 0 && self.ever_connected {
            let token = trace::start();
            let n = frame::send(
                &mut stream,
                &Msg::Resume {
                    session: self.session,
                    shard: self.shard,
                    cursor: self.acked,
                },
            )?;
            stream.flush()?;
            self.frames_tx.inc();
            self.bytes_tx.add(n as u64);
            let (reply, n) = frame::recv(&mut stream)?;
            self.frames_rx.inc();
            self.bytes_rx.add(n as u64);
            match reply {
                Msg::ResumeOk { cursor }
                    if cursor == self.acked || cursor == self.acked + 1 =>
                {
                    trace::complete(STAGE_NET_RESUME, self.acked, self.shard, token);
                    self.resumes.inc();
                }
                Msg::ResumeOk { cursor } => bail!(
                    "resume cursor mismatch on shard {}: client acked {}, \
                     server journal at {cursor}",
                    self.shard,
                    self.acked
                ),
                Msg::Error { code, message } => {
                    bail!("server rejected resume (code {code}): {message}")
                }
                other => bail!("unexpected resume reply {other:?}"),
            }
        }
        self.conn = Some(stream);
        self.ever_connected = true;
        Ok(())
    }

    /// Health-check round trip on the current connection.
    pub fn health(&mut self) -> Result<()> {
        self.ensure_conn(false)?;
        let Some(stream) = self.conn.as_mut() else {
            bail!(
                "no live connection to {} after reconnect (internal invariant)",
                self.addr
            );
        };
        let res = (|| -> Result<()> {
            frame::send(stream, &Msg::Health)?;
            stream.flush()?;
            match frame::recv(stream)?.0 {
                Msg::HealthOk => Ok(()),
                other => bail!("unexpected health reply {other:?}"),
            }
        })();
        if res.is_err() {
            self.conn = None;
        }
        res
    }

    /// One round trip for frame `seq`: connect if needed (the resume
    /// handshake lives in [`hello`]), inject any scheduled wire faults,
    /// send, and classify the reply.
    ///
    /// [`hello`]: RemoteProjector::hello
    fn project_attempt(
        &mut self,
        seq: u64,
        frames: &Tensor,
    ) -> std::result::Result<(Tensor, Tensor), Fail> {
        // Reconnect (bounded backoff) happens here, BETWEEN round
        // trips; a redial with resume on re-attaches the session first.
        self.ensure_conn(false).map_err(Fail::Retry)?;
        let shard = self.shard;

        // Scheduled wire faults, keyed on the send-attempt counter (a
        // retry draws fresh — bounded budgets converge through bursts).
        let attempt_n = self.send_attempts;
        if let Some(fp) = &self.faults {
            self.send_attempts += 1;
            if let Some(d) = fp.stall(shard, attempt_n) {
                self.faults_injected.inc();
                std::thread::sleep(d);
            }
            if fp.cut(shard, attempt_n) {
                self.faults_injected.inc();
                self.conn = None;
                return Err(Fail::Retry(anyhow!(
                    "injected connection cut on shard {shard} (send attempt {attempt_n})"
                )));
            }
        }
        let msg = Msg::Project {
            shard,
            seq,
            frames: frames.clone(),
        };
        // Frame-level mutations need the encoded bytes; decide them
        // before borrowing the stream.
        let mut wire_bytes: Option<Vec<u8>> = None;
        let mut partial_cut: Option<usize> = None;
        if let Some(fp) = &self.faults {
            let (op, payload) = frame::encode(&msg);
            let mut buf = Vec::new();
            frame::write_frame(&mut buf, op, &payload)
                .map_err(|e| Fail::Fatal(anyhow!("encoding projection frame: {e}")))?;
            if let Some(bit) = fp.corrupt(shard, attempt_n, buf.len() as u64 * 8) {
                self.faults_injected.inc();
                buf[(bit / 8) as usize] ^= 1 << (bit % 8);
            }
            if fp.partial(shard, attempt_n) {
                self.faults_injected.inc();
                partial_cut = Some((buf.len() / 2).max(1));
            }
            wire_bytes = Some(buf);
        }

        let started = Instant::now();
        let token = trace::start();
        let send_res: Result<usize> = {
            let stream = match self.conn.as_mut() {
                Some(s) => s,
                None => {
                    return Err(Fail::Fatal(anyhow!(
                        "connection to {} vanished after reconnect (internal invariant)",
                        self.addr
                    )))
                }
            };
            (|| {
                if let Some(buf) = &wire_bytes {
                    let cut = partial_cut.unwrap_or(buf.len());
                    stream.write_all(&buf[..cut])?;
                    stream.flush()?;
                    Ok(cut)
                } else {
                    let n = frame::send(stream, &msg)?;
                    stream.flush()?;
                    Ok(n)
                }
            })()
        };
        if partial_cut.is_some() {
            // The frame is knowingly truncated mid-stream: this
            // connection's framing is unusable, whatever write_all said.
            self.conn = None;
            return Err(Fail::Retry(anyhow!(
                "injected partial write on shard {shard} (send attempt {attempt_n})"
            )));
        }
        let n = match send_res {
            Ok(n) => n,
            Err(e) => {
                // The frame may be half-written: the framing on this
                // connection is unusable, and the request must NOT be
                // blindly resent (the server may already have projected
                // it) — only a resume handshake can make a retry safe.
                self.conn = None;
                return Err(Fail::Retry(e.context("remote projection send failed")));
            }
        };
        trace::complete(STAGE_NET_SEND, seq, shard, token);
        self.frames_tx.inc();
        self.bytes_tx.add(n as u64);

        let token = trace::start();
        let recv_res = {
            let stream = match self.conn.as_mut() {
                Some(s) => s,
                None => {
                    return Err(Fail::Fatal(anyhow!(
                        "connection to {} vanished mid-request (internal invariant)",
                        self.addr
                    )))
                }
            };
            frame::recv(stream)
        };
        let (reply, n) = match recv_res {
            Ok(ok) => ok,
            Err(e) => {
                // Timeout or dead transport with a request in flight:
                // complete it with an error (never silence, never a
                // blind retry) so either the resume loop re-attaches or
                // the failover machinery sees the failure.
                self.conn = None;
                return Err(Fail::Retry(anyhow::Error::new(e).context(format!(
                    "remote projection reply from {} shard {} failed",
                    self.addr, shard
                ))));
            }
        };
        trace::complete(STAGE_NET_RECV, seq, shard, token);
        self.frames_rx.inc();
        self.bytes_rx.add(n as u64);
        self.rtt.observe(started.elapsed().as_secs_f64());
        match reply {
            Msg::ProjectOk {
                p1,
                p2,
                sim_seconds,
                energy_joules,
            } => {
                self.sim_seconds = sim_seconds;
                self.energy_joules = energy_joules;
                Ok((p1, p2))
            }
            // Transient server-side refusal: the frame was NOT executed
            // — retryable as-is, connection and framing are fine.
            Msg::Error {
                code: ERR_UNAVAILABLE,
                message,
            } => Err(Fail::Retry(anyhow!(
                "remote shard {shard} at {}: {message}",
                self.addr
            ))),
            // The server distrusts this connection's framing (e.g. an
            // injected corruption tripped its CRC) and will close it:
            // retryable after a redial — our frame was never parsed.
            Msg::Error {
                code: ERR_PROTO,
                message,
            } => {
                self.conn = None;
                Err(Fail::Retry(anyhow!(
                    "remote shard {shard} at {}: {message}",
                    self.addr
                )))
            }
            // ERR_APP, ERR_CURSOR, unknown codes: the frame's fate is
            // decided — surface immediately so failover trips.
            Msg::Error { message, .. } => Err(Fail::Fatal(anyhow!(
                "remote shard {shard} at {}: {message}",
                self.addr
            ))),
            other => {
                self.conn = None;
                Err(Fail::Fatal(anyhow!("unexpected projection reply {other:?}")))
            }
        }
    }
}

impl Projector for RemoteProjector {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        let seq = self.acked + 1;
        // Resume off → budget 1: one attempt, errors surface unchanged
        // (the pre-v2 semantics, byte for byte).
        let tries = self.opts.resume_tries.max(1);
        let mut last: Option<anyhow::Error> = None;
        for _ in 0..tries {
            match self.project_attempt(seq, frames) {
                Ok(out) => {
                    self.acked = seq;
                    return Ok(out);
                }
                Err(Fail::Fatal(e)) => return Err(e),
                Err(Fail::Retry(e)) => last = Some(e),
            }
        }
        let e = last.unwrap_or_else(|| anyhow!("no attempt recorded"));
        if tries > 1 {
            Err(e.context(format!(
                "projection seq {seq} on shard {} failed after {tries} resume attempts",
                self.shard
            )))
        } else {
            Err(e)
        }
    }

    fn modes(&self) -> usize {
        self.modes
    }

    fn sim_seconds(&self) -> f64 {
        self.sim_seconds
    }

    fn energy_joules(&self) -> f64 {
        self.energy_joules
    }

    fn kind(&self) -> &'static str {
        "remote"
    }

    fn requires_ternary(&self) -> bool {
        self.requires_ternary
    }
}
