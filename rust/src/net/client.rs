//! The remote projector client: a [`Projector`] whose device lives in
//! another process.
//!
//! One [`RemoteProjector`] owns one connection to one shard of a
//! [`super::server::ProjectorServer`].  Construction dials eagerly and
//! exchanges `Hello`/`HelloOk`, caching the remote device's identity
//! (modes, ternary requirement, kind) so every `Projector` query after
//! that is answered locally; each `project` call is one
//! `Project`/`ProjectOk` round trip.
//!
//! **Failure semantics** (load-bearing for the serving layer's
//! failover): a connection is (re)established with bounded
//! exponential-backoff dial attempts, but an *in-flight* request is
//! never retried — a resent frame would advance the remote device's
//! noise stream a second time and silently diverge the bits.  Any
//! transport error or reply timeout mid-request kills the connection
//! and surfaces as `Err`, which the sharded service counts toward its
//! error-streak trip; the *next* request redials (counting
//! `net_reconnects`).

use std::io::Write;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::frame::{self, Msg};
use super::{
    Addr, NetOptions, NetStream, NET_BYTES_RX, NET_BYTES_TX, NET_FRAMES_RX, NET_FRAMES_TX,
    NET_RECONNECTS, NET_RTT,
};
use crate::coordinator::projector::Projector;
use crate::metrics::trace::{self, STAGE_NET_RECV, STAGE_NET_SEND};
use crate::metrics::{Counter, Histogram, Registry};
use crate::tensor::Tensor;

/// Client half of one remote shard.
pub struct RemoteProjector {
    addr: Addr,
    shard: u32,
    opts: NetOptions,
    conn: Option<NetStream>,
    // Cached from HelloOk.
    modes: usize,
    requires_ternary: bool,
    // Server-side cumulative accounts, updated from each ProjectOk.
    sim_seconds: f64,
    energy_joules: f64,
    // Observability.
    frames_tx: Counter,
    frames_rx: Counter,
    bytes_tx: Counter,
    bytes_rx: Counter,
    reconnects: Counter,
    rtt: Histogram,
    seq: u64,
}

impl RemoteProjector {
    /// Dial `addr`, greet `shard`, and cache its identity.  Fails fast
    /// (after the bounded dial attempts) if the server is unreachable —
    /// a topology build should not hand out dead devices.
    pub fn connect(
        addr: &Addr,
        shard: u32,
        opts: NetOptions,
        metrics: &Registry,
    ) -> Result<RemoteProjector> {
        let mut rp = RemoteProjector {
            addr: addr.clone(),
            shard,
            opts,
            conn: None,
            modes: 0,
            requires_ternary: true,
            sim_seconds: 0.0,
            energy_joules: 0.0,
            frames_tx: metrics.counter(NET_FRAMES_TX),
            frames_rx: metrics.counter(NET_FRAMES_RX),
            bytes_tx: metrics.counter(NET_BYTES_TX),
            bytes_rx: metrics.counter(NET_BYTES_RX),
            reconnects: metrics.counter(NET_RECONNECTS),
            rtt: metrics.histogram(NET_RTT),
            seq: 0,
        };
        rp.ensure_conn(true)
            .with_context(|| format!("connecting to projector server {addr} shard {shard}"))?;
        Ok(rp)
    }

    /// The endpoint this client talks to.
    pub fn addr(&self) -> &Addr {
        &self.addr
    }

    /// The remote shard id.
    pub fn shard(&self) -> u32 {
        self.shard
    }

    /// Dial + greet with bounded exponential backoff.  `first` skips
    /// the reconnect counter (an initial connect is not a reconnect).
    fn ensure_conn(&mut self, first: bool) -> Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        if !first {
            self.reconnects.inc();
        }
        let tries = self.opts.reconnect_tries.max(1);
        let mut backoff = Duration::from_millis(self.opts.reconnect_base_ms);
        let mut last_err = None;
        for attempt in 0..tries {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff = (backoff * 2)
                    .min(Duration::from_millis(self.opts.reconnect_max_ms));
            }
            match NetStream::connect(
                &self.addr,
                Duration::from_millis(self.opts.connect_timeout_ms),
            ) {
                Ok(stream) => match self.hello(stream) {
                    Ok(()) => return Ok(()),
                    Err(e) => last_err = Some(e),
                },
                Err(e) => last_err = Some(e),
            }
        }
        bail!(
            "projector server {} unreachable after {tries} attempts: {}",
            self.addr,
            last_err.map_or_else(|| "no error recorded".into(), |e| e.to_string())
        )
    }

    fn hello(&mut self, mut stream: NetStream) -> Result<()> {
        stream.set_read_timeout(Some(Duration::from_millis(self.opts.request_timeout_ms)))?;
        let n = frame::send(&mut stream, &Msg::Hello { shard: self.shard })?;
        stream.flush()?;
        self.frames_tx.inc();
        self.bytes_tx.add(n as u64);
        let (reply, n) = frame::recv(&mut stream)?;
        self.frames_rx.inc();
        self.bytes_rx.add(n as u64);
        match reply {
            Msg::HelloOk {
                modes,
                requires_ternary,
                kind: _,
            } => {
                self.modes = modes as usize;
                self.requires_ternary = requires_ternary;
                self.conn = Some(stream);
                Ok(())
            }
            Msg::Error { message } => bail!("server rejected hello: {message}"),
            other => bail!("unexpected hello reply {other:?}"),
        }
    }

    /// Health-check round trip on the current connection.
    pub fn health(&mut self) -> Result<()> {
        self.ensure_conn(false)?;
        let stream = self.conn.as_mut().unwrap();
        let res = (|| -> Result<()> {
            frame::send(stream, &Msg::Health)?;
            stream.flush()?;
            match frame::recv(stream)?.0 {
                Msg::HealthOk => Ok(()),
                other => bail!("unexpected health reply {other:?}"),
            }
        })();
        if res.is_err() {
            self.conn = None;
        }
        res
    }
}

impl Projector for RemoteProjector {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        // Reconnect (bounded backoff) happens here, BETWEEN requests.
        self.ensure_conn(false)?;
        self.seq += 1;
        let seq = self.seq;
        let started = Instant::now();
        let stream = self.conn.as_mut().unwrap();
        let send_res = (|| -> Result<usize> {
            let token = trace::start();
            let n = frame::send(
                stream,
                &Msg::Project {
                    shard: self.shard,
                    frames: frames.clone(),
                },
            )?;
            stream.flush()?;
            trace::complete(STAGE_NET_SEND, seq, self.shard, token);
            Ok(n)
        })();
        let n = match send_res {
            Ok(n) => n,
            Err(e) => {
                // The frame may be half-written: the framing on this
                // connection is unusable, and the request must NOT be
                // resent (the server may already have projected it).
                self.conn = None;
                return Err(e.context("remote projection send failed"));
            }
        };
        self.frames_tx.inc();
        self.bytes_tx.add(n as u64);

        let token = trace::start();
        let recv_res = frame::recv(stream);
        let (reply, n) = match recv_res {
            Ok(ok) => ok,
            Err(e) => {
                // Timeout or dead transport with a request in flight:
                // complete it with an error (never silence, never a
                // retry) so the failover machinery sees the failure.
                self.conn = None;
                return Err(anyhow::Error::new(e).context(format!(
                    "remote projection reply from {} shard {} failed",
                    self.addr, self.shard
                )));
            }
        };
        trace::complete(STAGE_NET_RECV, seq, self.shard, token);
        self.frames_rx.inc();
        self.bytes_rx.add(n as u64);
        self.rtt.observe(started.elapsed().as_secs_f64());
        match reply {
            Msg::ProjectOk {
                p1,
                p2,
                sim_seconds,
                energy_joules,
            } => {
                self.sim_seconds = sim_seconds;
                self.energy_joules = energy_joules;
                Ok((p1, p2))
            }
            // A structured server-side error: the connection and its
            // framing are fine, keep it.
            Msg::Error { message } => bail!(
                "remote shard {} at {}: {message}",
                self.shard,
                self.addr
            ),
            other => {
                self.conn = None;
                bail!("unexpected projection reply {other:?}")
            }
        }
    }

    fn modes(&self) -> usize {
        self.modes
    }

    fn sim_seconds(&self) -> f64 {
        self.sim_seconds
    }

    fn energy_joules(&self) -> f64 {
        self.energy_joules
    }

    fn kind(&self) -> &'static str {
        "remote"
    }

    fn requires_ternary(&self) -> bool {
        self.requires_ternary
    }
}
