//! The projector server: shard devices behind a TCP/UDS listener.
//!
//! One [`ProjectorServer`] hosts a set of `(shard id, device)` pairs —
//! typically the local shards of one [`Topology`] — and speaks the
//! [`super::frame`] protocol.  Each accepted connection gets its own
//! handler thread with fully *blocking* reads (no server-side read
//! timeout: a half-received frame must never be abandoned mid-stream,
//! or the framing desyncs); handlers exit on client EOF.
//!
//! **Determinism:** each shard's device sits behind its own mutex, so
//! that shard's projections happen strictly in request order no matter
//! how many connections multiplex onto it — the per-shard noise-draw
//! order is the submission order, exactly as in-process.  A device
//! panic (e.g. a medium shape assert) is caught and returned as an
//! `Error` frame instead of killing the handler.
//!
//! [`Topology`]: crate::coordinator::topology::Topology

use std::io::Write;
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use super::frame::{self, Msg, WireError};
use super::{Addr, NetStream, NET_BYTES_RX, NET_BYTES_TX, NET_FRAMES_RX, NET_FRAMES_TX};
use crate::coordinator::projector::Projector;
use crate::metrics::Registry;

/// One hosted shard: its wire-visible id and the device behind it.
struct Hosted {
    shard: u32,
    device: Mutex<Box<dyn Projector + Send>>,
}

/// A running projector server (accept loop on a background thread).
pub struct ProjectorServer {
    local: Addr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    /// The bound UDS path, removed on shutdown.
    uds_path: Option<String>,
}

enum Listener {
    Tcp(TcpListener),
    Uds(UnixListener),
}

impl ProjectorServer {
    /// Bind `addr` and serve `devices` until [`shutdown`] or drop.
    /// `tcp:host:0` binds an ephemeral port; read the actual one back
    /// from [`local_addr`].  An existing socket file at a UDS path is
    /// replaced.
    ///
    /// [`shutdown`]: ProjectorServer::shutdown
    /// [`local_addr`]: ProjectorServer::local_addr
    pub fn bind(
        addr: &Addr,
        devices: Vec<(u32, Box<dyn Projector + Send>)>,
        metrics: Registry,
    ) -> Result<ProjectorServer> {
        anyhow::ensure!(!devices.is_empty(), "projector server needs >= 1 device");
        let (listener, local, uds_path) = match addr {
            Addr::Tcp(hp) => {
                let l = TcpListener::bind(hp.as_str())
                    .with_context(|| format!("binding tcp listener on {hp}"))?;
                let actual = l.local_addr()?;
                (Listener::Tcp(l), Addr::Tcp(actual.to_string()), None)
            }
            Addr::Uds(path) => {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding uds listener on {path}"))?;
                (Listener::Uds(l), Addr::Uds(path.clone()), Some(path.clone()))
            }
        };
        match &listener {
            Listener::Tcp(l) => l.set_nonblocking(true)?,
            Listener::Uds(l) => l.set_nonblocking(true)?,
        }
        let hosted: Arc<Vec<Hosted>> = Arc::new(
            devices
                .into_iter()
                .map(|(shard, device)| Hosted {
                    shard,
                    device: Mutex::new(device),
                })
                .collect(),
        );
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = stop.clone();
            thread::Builder::new()
                .name("litl-net-accept".into())
                .spawn(move || accept_loop(listener, hosted, metrics, stop))?
        };
        Ok(ProjectorServer {
            local,
            stop,
            accept: Some(accept),
            uds_path,
        })
    }

    /// The actually-bound address (ephemeral TCP ports resolved).
    pub fn local_addr(&self) -> &Addr {
        &self.local
    }

    /// Stop accepting and join the accept loop.  Handler threads for
    /// already-connected clients are detached; they exit when their
    /// client disconnects (in-flight requests still complete — the
    /// graceful half of a cutover; a *killed* server process is the
    /// abrupt half, and the client errors its in-flight frame).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(path) = self.uds_path.take() {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Drop for ProjectorServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: Listener,
    hosted: Arc<Vec<Hosted>>,
    metrics: Registry,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::SeqCst) {
        let conn = match &listener {
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                NetStream::Tcp(s)
            }),
            Listener::Uds(l) => l.accept().map(|(s, _)| NetStream::Uds(s)),
        };
        match conn {
            Ok(mut stream) => {
                // Handlers block in read; nonblocking was a listener
                // property only.
                match &stream {
                    NetStream::Tcp(s) => {
                        let _ = s.set_nonblocking(false);
                    }
                    NetStream::Uds(s) => {
                        let _ = s.set_nonblocking(false);
                    }
                }
                let hosted = hosted.clone();
                let metrics = metrics.clone();
                let spawned = thread::Builder::new()
                    .name("litl-net-conn".into())
                    .spawn(move || handle_conn(&mut stream, &hosted, &metrics));
                if spawned.is_err() {
                    log::warn!("projector server could not spawn a handler thread");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                log::warn!("projector server accept error: {e}");
                thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

fn handle_conn(stream: &mut NetStream, hosted: &[Hosted], metrics: &Registry) {
    let frames_rx = metrics.counter(NET_FRAMES_RX);
    let frames_tx = metrics.counter(NET_FRAMES_TX);
    let bytes_rx = metrics.counter(NET_BYTES_RX);
    let bytes_tx = metrics.counter(NET_BYTES_TX);
    loop {
        let msg = match frame::recv(stream) {
            Ok((msg, n)) => {
                frames_rx.inc();
                bytes_rx.add(n as u64);
                msg
            }
            Err(WireError::Closed) => return,
            Err(e) => {
                // Protocol violation or dead transport: tell the peer
                // why (best effort) and drop the connection — framing
                // cannot be trusted past this point.
                let _ = frame::send(
                    stream,
                    &Msg::Error {
                        message: format!("protocol error: {e}"),
                    },
                );
                return;
            }
        };
        let reply = match msg {
            Msg::Hello { shard } => match find(hosted, shard) {
                Some(h) => {
                    let dev = h.device.lock().unwrap_or_else(PoisonError::into_inner);
                    Msg::HelloOk {
                        modes: dev.modes() as u32,
                        requires_ternary: dev.requires_ternary(),
                        kind: dev.kind().to_string(),
                    }
                }
                None => not_hosted(shard, hosted),
            },
            Msg::Project { shard, frames } => match find(hosted, shard) {
                Some(h) => {
                    let mut dev =
                        h.device.lock().unwrap_or_else(PoisonError::into_inner);
                    // A device panic (shape assert deep in the medium)
                    // must not kill the handler thread: catch it and
                    // report it like any projection error.
                    let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
                        dev.project(&frames)
                    }));
                    match res {
                        Ok(Ok((p1, p2))) => Msg::ProjectOk {
                            p1,
                            p2,
                            sim_seconds: dev.sim_seconds(),
                            energy_joules: dev.energy_joules(),
                        },
                        Ok(Err(e)) => Msg::Error {
                            message: format!("projection failed: {e}"),
                        },
                        Err(_) => Msg::Error {
                            message: format!("projection panicked on shard {shard}"),
                        },
                    }
                }
                None => not_hosted(shard, hosted),
            },
            Msg::Health => Msg::HealthOk,
            other => Msg::Error {
                message: format!("unexpected client message {other:?}"),
            },
        };
        match frame::send(stream, &reply) {
            Ok(n) => {
                frames_tx.inc();
                bytes_tx.add(n as u64);
                let _ = stream.flush();
            }
            Err(_) => return,
        }
    }
}

fn find(hosted: &[Hosted], shard: u32) -> Option<&Hosted> {
    hosted.iter().find(|h| h.shard == shard)
}

fn not_hosted(shard: u32, hosted: &[Hosted]) -> Msg {
    let here: Vec<u32> = hosted.iter().map(|h| h.shard).collect();
    Msg::Error {
        message: format!("shard {shard} not hosted here (hosting {here:?})"),
    }
}
