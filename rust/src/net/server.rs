//! The projector server: shard devices behind a TCP/UDS listener.
//!
//! One [`ProjectorServer`] hosts a set of `(shard id, device)` pairs —
//! typically the local shards of one [`Topology`] — and speaks the
//! [`super::frame`] protocol.  Each accepted connection gets its own
//! handler thread with fully *blocking* reads (no server-side read
//! timeout: a half-received frame must never be abandoned mid-stream,
//! or the framing desyncs); handlers exit on client EOF.
//!
//! **Determinism:** each shard's device sits behind its own mutex, so
//! that shard's projections happen strictly in request order no matter
//! how many connections multiplex onto it — the per-shard noise-draw
//! order is the submission order, exactly as in-process.  A device
//! panic (e.g. a medium shape assert) is caught and returned as an
//! `Error` frame instead of killing the handler.
//!
//! **Session-resume journal (wire v2):** for every `(session, shard)`
//! pair that greeted with a nonzero session id, the server keeps the
//! sequence number of the last executed frame (the *cursor*) and a
//! copy of its reply.  The dedup rules make a redialed re-request safe:
//!
//! * `seq == cursor + 1` — a new frame: execute, advance the cursor,
//!   journal the reply.
//! * `seq == cursor` — the client never saw the reply (the connection
//!   died between execute and deliver): **replay the journaled reply**;
//!   the device is not touched, so its noise stream advanced exactly
//!   once for this frame.
//! * anything else — `Error { code: ERR_CURSOR }`: the server cannot
//!   prove the frame's fate (journal evicted, restarted, stale
//!   session), so the client must error into failover rather than risk
//!   a double draw.
//!
//! An application-level projection failure *removes* the journal entry:
//! the client never re-requests a failed frame (ERR_APP is fatal on its
//! side), and poisoning the session keeps a later out-of-step frame
//! from executing against an ambiguous cursor.  The journal is a
//! bounded LRU ([`ServerOptions::journal_cap`]); evictions are counted
//! and an evicted session resumes into a cursor mismatch — bounded
//! memory trades a failover, never correctness.
//!
//! [`Topology`]: crate::coordinator::topology::Topology

use std::io::Write;
use std::net::TcpListener;
use std::os::unix::net::{UnixListener, UnixStream};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::frame::{self, Msg, WireError, ERR_APP, ERR_CURSOR, ERR_PROTO, ERR_UNAVAILABLE};
use super::{
    Addr, FaultPlanCfg, NetStream, NET_BYTES_RX, NET_BYTES_TX, NET_FAULTS_INJECTED,
    NET_FRAMES_RX, NET_FRAMES_TX, NET_JOURNAL_EVICTIONS, NET_JOURNAL_REPLAYS,
    NET_JOURNAL_SESSIONS,
};
use crate::coordinator::projector::Projector;
use crate::metrics::{Counter, Gauge, Registry};

/// Server-side tuning: the session-resume journal bound and the
/// optional device-fault plan (chaos drills).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ServerOptions {
    /// Max journal entries (one per live `(session, shard)` pair);
    /// least-recently-used entries are evicted beyond this.  0 disables
    /// journaling entirely — every resume then fails with a typed
    /// cursor mismatch (the pre-v2 failure semantics).
    pub journal_cap: usize,
    /// Server-side deterministic fault plan: device error bursts and
    /// stall windows, keyed on the per-shard arrival counter.
    pub faults: Option<FaultPlanCfg>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            journal_cap: 256,
            faults: None,
        }
    }
}

/// One hosted shard: its wire-visible id, the device behind it, and the
/// arrival counter the fault plan keys on.
struct Hosted {
    shard: u32,
    device: Mutex<Box<dyn Projector + Send>>,
    arrivals: AtomicU64,
}

/// One `(session, shard)` replay-journal entry.
struct JournalEntry {
    session: u64,
    shard: u32,
    /// Seq of the last executed frame; `reply` is its journaled answer.
    cursor: u64,
    reply: Msg,
    /// LRU clock value of the last touch.
    tick: u64,
}

/// Bounded LRU of the last completed frame per `(session, shard)`.
/// Linear scans are fine: the cap is small (hundreds) and every entry
/// touch is already serialized by the mutex around this struct.
struct Journal {
    cap: usize,
    tick: u64,
    entries: Vec<JournalEntry>,
    evictions: Counter,
    sessions: Gauge,
}

impl Journal {
    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn find(&mut self, session: u64, shard: u32) -> Option<&mut JournalEntry> {
        self.entries
            .iter_mut()
            .find(|e| e.session == session && e.shard == shard)
    }

    /// Record `reply` as the journaled answer for `seq`, inserting or
    /// updating the entry and evicting LRU entries beyond the cap.
    fn record(&mut self, session: u64, shard: u32, seq: u64, reply: Msg) {
        let tick = self.touch();
        if let Some(e) = self.find(session, shard) {
            e.cursor = seq;
            e.reply = reply;
            e.tick = tick;
        } else {
            self.entries.push(JournalEntry {
                session,
                shard,
                cursor: seq,
                reply,
                tick,
            });
            while self.entries.len() > self.cap {
                let (lru, _) = self
                    .entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, e)| e.tick)
                    .map(|(i, e)| (i, e.tick))
                    .unwrap_or((0, 0));
                self.entries.swap_remove(lru);
                self.evictions.inc();
            }
        }
        self.sessions.set(self.entries.len() as f64);
    }

    fn remove(&mut self, session: u64, shard: u32) {
        self.entries
            .retain(|e| !(e.session == session && e.shard == shard));
        self.sessions.set(self.entries.len() as f64);
    }
}

/// A running projector server (accept loop on a background thread).
pub struct ProjectorServer {
    local: Addr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    /// Requests currently executing across all handler threads — the
    /// drain target for a graceful shutdown.
    busy: Arc<AtomicUsize>,
    /// The bound UDS path, removed on shutdown.
    uds_path: Option<String>,
}

enum Listener {
    Tcp(TcpListener),
    Uds(UnixListener),
}

/// Shared per-server state every handler thread sees.
struct Shared {
    hosted: Vec<Hosted>,
    journal: Mutex<Journal>,
    journal_cap: usize,
    faults: Option<FaultPlanCfg>,
    busy: Arc<AtomicUsize>,
}

impl ProjectorServer {
    /// Bind `addr` and serve `devices` with default [`ServerOptions`]
    /// until [`shutdown`] or drop.  `tcp:host:0` binds an ephemeral
    /// port; read the actual one back from [`local_addr`].
    ///
    /// [`shutdown`]: ProjectorServer::shutdown
    /// [`local_addr`]: ProjectorServer::local_addr
    pub fn bind(
        addr: &Addr,
        devices: Vec<(u32, Box<dyn Projector + Send>)>,
        metrics: Registry,
    ) -> Result<ProjectorServer> {
        Self::bind_with(addr, devices, metrics, ServerOptions::default())
    }

    /// [`bind`] with explicit [`ServerOptions`].  A UDS path holding a
    /// *dead* socket (bind leftover of a killed server) is unlinked and
    /// reused; a path with a live server behind it, or occupied by
    /// anything that is not a socket, is a typed error — never an
    /// unlink.
    ///
    /// [`bind`]: ProjectorServer::bind
    pub fn bind_with(
        addr: &Addr,
        devices: Vec<(u32, Box<dyn Projector + Send>)>,
        metrics: Registry,
        opts: ServerOptions,
    ) -> Result<ProjectorServer> {
        anyhow::ensure!(!devices.is_empty(), "projector server needs >= 1 device");
        let (listener, local, uds_path) = match addr {
            Addr::Tcp(hp) => {
                let l = TcpListener::bind(hp.as_str())
                    .with_context(|| format!("binding tcp listener on {hp}"))?;
                let actual = l.local_addr()?;
                (Listener::Tcp(l), Addr::Tcp(actual.to_string()), None)
            }
            Addr::Uds(path) => {
                reclaim_uds_path(path)?;
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding uds listener on {path}"))?;
                (Listener::Uds(l), Addr::Uds(path.clone()), Some(path.clone()))
            }
        };
        match &listener {
            Listener::Tcp(l) => l.set_nonblocking(true)?,
            Listener::Uds(l) => l.set_nonblocking(true)?,
        }
        let busy = Arc::new(AtomicUsize::new(0));
        let shared = Arc::new(Shared {
            hosted: devices
                .into_iter()
                .map(|(shard, device)| Hosted {
                    shard,
                    device: Mutex::new(device),
                    arrivals: AtomicU64::new(0),
                })
                .collect(),
            journal: Mutex::new(Journal {
                cap: opts.journal_cap.max(1),
                tick: 0,
                entries: Vec::new(),
                evictions: metrics.counter(NET_JOURNAL_EVICTIONS),
                sessions: metrics.gauge(NET_JOURNAL_SESSIONS),
            }),
            journal_cap: opts.journal_cap,
            faults: opts.faults.filter(|f| !f.is_noop()),
            busy: busy.clone(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = stop.clone();
            thread::Builder::new()
                .name("litl-net-accept".into())
                .spawn(move || accept_loop(listener, shared, metrics, stop))?
        };
        Ok(ProjectorServer {
            local,
            stop,
            accept: Some(accept),
            busy,
            uds_path,
        })
    }

    /// The actually-bound address (ephemeral TCP ports resolved).
    pub fn local_addr(&self) -> &Addr {
        &self.local
    }

    /// Requests currently executing (for drain loops and tests).
    pub fn in_flight(&self) -> usize {
        self.busy.load(Ordering::SeqCst)
    }

    /// Stop accepting and join the accept loop.  Handler threads for
    /// already-connected clients are detached; they exit when their
    /// client disconnects (in-flight requests still complete — the
    /// graceful half of a cutover; a *killed* server process is the
    /// abrupt half, and the client errors or resumes its in-flight
    /// frame).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(path) = self.uds_path.take() {
            let _ = std::fs::remove_file(path);
        }
    }

    /// Wait (bounded) for every in-flight request to complete.  Call
    /// after [`shutdown`] for a graceful exit: no new connections are
    /// accepted, and this returns `true` once the last executing
    /// projection has replied (idle-but-connected clients don't count —
    /// only requests actually on a device).  `false` means the timeout
    /// expired with work still running.
    ///
    /// [`shutdown`]: ProjectorServer::shutdown
    pub fn drain(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.busy.load(Ordering::SeqCst) > 0 {
            if t0.elapsed() > timeout {
                return false;
            }
            thread::sleep(Duration::from_millis(5));
        }
        true
    }
}

impl Drop for ProjectorServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Stale-socket handling for a UDS bind target: nothing there is fine;
/// a *dead* socket (its listener's process is gone, so connect gives
/// ECONNREFUSED) is unlinked; a live socket or a non-socket file is a
/// typed error.  This is what lets a crashed `litl serve` restart on
/// the same path without an operator `rm`, while never stealing a
/// path from a running server or clobbering an unrelated file.
fn reclaim_uds_path(path: &str) -> Result<()> {
    use std::os::unix::fs::FileTypeExt;
    let md = match std::fs::symlink_metadata(path) {
        Ok(md) => md,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("inspecting uds path {path}")),
    };
    anyhow::ensure!(
        md.file_type().is_socket(),
        "uds path {path} exists and is not a socket — refusing to unlink it"
    );
    match UnixStream::connect(path) {
        Ok(_) => anyhow::bail!(
            "uds path {path} has a live server behind it — refusing to bind over it"
        ),
        Err(e) if e.kind() == std::io::ErrorKind::ConnectionRefused => {
            std::fs::remove_file(path)
                .with_context(|| format!("unlinking stale uds socket {path}"))?;
            log::info!("reclaimed stale uds socket {path}");
            Ok(())
        }
        Err(e) => Err(e).with_context(|| {
            format!("probing uds path {path} (neither live nor provably dead)")
        }),
    }
}

fn accept_loop(
    listener: Listener,
    shared: Arc<Shared>,
    metrics: Registry,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::SeqCst) {
        let conn = match &listener {
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                NetStream::Tcp(s)
            }),
            Listener::Uds(l) => l.accept().map(|(s, _)| NetStream::Uds(s)),
        };
        match conn {
            Ok(mut stream) => {
                // Handlers block in read; nonblocking was a listener
                // property only.
                match &stream {
                    NetStream::Tcp(s) => {
                        let _ = s.set_nonblocking(false);
                    }
                    NetStream::Uds(s) => {
                        let _ = s.set_nonblocking(false);
                    }
                }
                let shared = shared.clone();
                let metrics = metrics.clone();
                let spawned = thread::Builder::new()
                    .name("litl-net-conn".into())
                    .spawn(move || handle_conn(&mut stream, &shared, &metrics));
                if spawned.is_err() {
                    log::warn!("projector server could not spawn a handler thread");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                log::warn!("projector server accept error: {e}");
                thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// RAII guard bumping the server's in-flight request count.
struct BusyGuard<'a>(&'a AtomicUsize);

impl<'a> BusyGuard<'a> {
    fn enter(busy: &'a AtomicUsize) -> Self {
        busy.fetch_add(1, Ordering::SeqCst);
        BusyGuard(busy)
    }
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn handle_conn(stream: &mut NetStream, shared: &Shared, metrics: &Registry) {
    let frames_rx = metrics.counter(NET_FRAMES_RX);
    let frames_tx = metrics.counter(NET_FRAMES_TX);
    let bytes_rx = metrics.counter(NET_BYTES_RX);
    let bytes_tx = metrics.counter(NET_BYTES_TX);
    let journal_replays = metrics.counter(NET_JOURNAL_REPLAYS);
    let faults_injected = metrics.counter(NET_FAULTS_INJECTED);
    // The session this connection greeted with (0 = journaling off for
    // this client, the pre-resume semantics).
    let mut session: u64 = 0;
    loop {
        let msg = match frame::recv(stream) {
            Ok((msg, n)) => {
                frames_rx.inc();
                bytes_rx.add(n as u64);
                msg
            }
            Err(WireError::Closed) => return,
            Err(e) => {
                // Protocol violation or dead transport: tell the peer
                // why (best effort) and drop the connection — framing
                // cannot be trusted past this point.  ERR_PROTO marks
                // the condition retryable-after-redial for a resuming
                // client (its frame was never parsed, so it never
                // executed).
                let _ = frame::send(
                    stream,
                    &Msg::Error {
                        code: ERR_PROTO,
                        message: format!("protocol error: {e}"),
                    },
                );
                return;
            }
        };
        let _busy = BusyGuard::enter(&shared.busy);
        let reply = match msg {
            Msg::Hello {
                shard,
                session: client_session,
            } => match find(&shared.hosted, shard) {
                Some(h) => {
                    session = client_session;
                    let dev = h.device.lock().unwrap_or_else(PoisonError::into_inner);
                    Msg::HelloOk {
                        modes: dev.modes() as u32,
                        requires_ternary: dev.requires_ternary(),
                        kind: dev.kind().to_string(),
                    }
                }
                None => not_hosted(shard, &shared.hosted),
            },
            Msg::Project { shard, seq, frames } => match find(&shared.hosted, shard) {
                Some(h) => project_reply(
                    h,
                    shared,
                    session,
                    seq,
                    &frames,
                    &journal_replays,
                    &faults_injected,
                ),
                None => not_hosted(shard, &shared.hosted),
            },
            Msg::Resume {
                session: resume_session,
                shard,
                cursor,
            } => {
                if resume_session != session || session == 0 {
                    Msg::Error {
                        code: ERR_PROTO,
                        message: format!(
                            "resume session {resume_session:#x} does not match this \
                             connection's hello session {session:#x}"
                        ),
                    }
                } else if find(&shared.hosted, shard).is_none() {
                    not_hosted(shard, &shared.hosted)
                } else {
                    resume_reply(shared, session, shard, cursor)
                }
            }
            Msg::Health => Msg::HealthOk,
            other => Msg::Error {
                code: ERR_PROTO,
                message: format!("unexpected client message {other:?}"),
            },
        };
        match frame::send(stream, &reply) {
            Ok(n) => {
                frames_tx.inc();
                bytes_tx.add(n as u64);
                let _ = stream.flush();
            }
            Err(_) => return,
        }
    }
}

/// One `Project` request against its hosted shard: fault injection,
/// journal dedup/replay, execution, journal record.  The journal lock
/// is never held across the projection itself — only the device mutex
/// serializes execution, exactly as before resume existed.
fn project_reply(
    h: &Hosted,
    shared: &Shared,
    session: u64,
    seq: u64,
    frames: &crate::tensor::Tensor,
    journal_replays: &Counter,
    faults_injected: &Counter,
) -> Msg {
    // Device-side fault plan, keyed on the arrival counter so a
    // resumed retry draws fresh (bursts end; retries converge).  An
    // injected error replies WITHOUT touching the device or journal —
    // the noise stream must not advance for a frame that "failed".
    if let Some(fp) = &shared.faults {
        let arrival = h.arrivals.fetch_add(1, Ordering::SeqCst);
        if let Some(d) = fp.dev_stall(h.shard, arrival) {
            faults_injected.inc();
            thread::sleep(d);
        }
        if fp.dev_err(h.shard, arrival) {
            faults_injected.inc();
            return Msg::Error {
                code: ERR_UNAVAILABLE,
                message: format!(
                    "injected device fault on shard {} (arrival {arrival})",
                    h.shard
                ),
            };
        }
    }
    let journaling = session != 0 && shared.journal_cap > 0;
    if journaling {
        enum Disposition {
            Replay(Msg),
            Execute,
            Mismatch(String),
        }
        let mut j = shared.journal.lock().unwrap_or_else(PoisonError::into_inner);
        let tick = j.touch();
        let disp = match j.find(session, h.shard) {
            // The client never saw this frame's reply: replay it.  The
            // device is untouched — its noise stream advanced exactly
            // once, at first execution.
            Some(e) if seq == e.cursor => {
                e.tick = tick;
                Disposition::Replay(e.reply.clone())
            }
            // In order: fall through to execute.
            Some(e) if seq == e.cursor + 1 => Disposition::Execute,
            None if seq == 1 => Disposition::Execute,
            // Out of step: the journal cannot prove this frame's fate.
            Some(e) => Disposition::Mismatch(format!("cursor {}", e.cursor)),
            None => Disposition::Mismatch("no journal entry".to_string()),
        };
        drop(j);
        match disp {
            Disposition::Replay(reply) => {
                journal_replays.inc();
                return reply;
            }
            Disposition::Execute => {}
            Disposition::Mismatch(have) => {
                return Msg::Error {
                    code: ERR_CURSOR,
                    message: format!(
                        "cursor mismatch on shard {} session {session:#x}: \
                         client sent seq {seq}, server has {have}",
                        h.shard
                    ),
                };
            }
        }
    }
    let reply = {
        let mut dev = h.device.lock().unwrap_or_else(PoisonError::into_inner);
        // A device panic (shape assert deep in the medium) must not
        // kill the handler thread: catch it and report it like any
        // projection error.
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| dev.project(frames)));
        match res {
            Ok(Ok((p1, p2))) => Msg::ProjectOk {
                p1,
                p2,
                sim_seconds: dev.sim_seconds(),
                energy_joules: dev.energy_joules(),
            },
            Ok(Err(e)) => Msg::Error {
                code: ERR_APP,
                message: format!("projection failed: {e}"),
            },
            Err(_) => Msg::Error {
                code: ERR_APP,
                message: format!("projection panicked on shard {}", h.shard),
            },
        }
    };
    if journaling {
        let mut j = shared.journal.lock().unwrap_or_else(PoisonError::into_inner);
        if matches!(reply, Msg::ProjectOk { .. }) {
            j.record(session, h.shard, seq, reply.clone());
        } else {
            // An app-level failure poisons the session: the client
            // treats ERR_APP as fatal (failover), and without a trusted
            // cursor any later frame on this session must mismatch
            // loudly instead of executing ambiguously.
            j.remove(session, h.shard);
        }
    }
    reply
}

/// One `Resume` request: answer with the journal cursor when it can
/// prove the in-flight frame's fate, a typed mismatch otherwise.
fn resume_reply(shared: &Shared, session: u64, shard: u32, cursor: u64) -> Msg {
    if shared.journal_cap == 0 {
        return Msg::Error {
            code: ERR_CURSOR,
            message: "session journal disabled on this server (journal_cap = 0)".into(),
        };
    }
    let mut j = shared.journal.lock().unwrap_or_else(PoisonError::into_inner);
    let tick = j.tick + 1;
    j.tick = tick;
    match j.find(session, shard) {
        // The server is at the client's cursor (nothing in flight
        // executed) or exactly one ahead (the in-flight frame executed
        // and its reply is replayable): both are provably safe.
        Some(e) if e.cursor == cursor || e.cursor == cursor + 1 => {
            e.tick = tick;
            Msg::ResumeOk { cursor: e.cursor }
        }
        Some(e) => Msg::Error {
            code: ERR_CURSOR,
            message: format!(
                "cursor mismatch on shard {shard} session {session:#x}: \
                 client resumed at {cursor}, server journal at {}",
                e.cursor
            ),
        },
        // A fresh session (nothing executed yet) legitimately has no
        // entry; anything else means the journal lost this session.
        None if cursor == 0 => Msg::ResumeOk { cursor: 0 },
        None => Msg::Error {
            code: ERR_CURSOR,
            message: format!(
                "no journal entry for shard {shard} session {session:#x} \
                 (evicted or server restarted); client resumed at {cursor}"
            ),
        },
    }
}

fn find(hosted: &[Hosted], shard: u32) -> Option<&Hosted> {
    hosted.iter().find(|h| h.shard == shard)
}

fn not_hosted(shard: u32, hosted: &[Hosted]) -> Msg {
    let here: Vec<u32> = hosted.iter().map(|h| h.shard).collect();
    Msg::Error {
        code: ERR_APP,
        message: format!("shard {shard} not hosted here (hosting {here:?})"),
    }
}
