//! Deterministic fault injection for the networked fleet.
//!
//! A [`FaultPlanCfg`] is a seeded, fully reproducible schedule of
//! transport and device faults: every decision is a pure function of
//! `(plan seed, fault site, shard, event counter)` through a
//! counter-addressed [`Pcg64`] stream, so the *same plan against the
//! same traffic produces the same faults* — independent of thread
//! interleaving, wall clock, or retry timing.  That is what makes the
//! chaos suite (`tests/chaos.rs`) a pin rather than a flake: a
//! fault-ridden run with session resume enabled must finish bitwise
//! identical to the fault-free run.
//!
//! Two injection planes share one plan:
//!
//! * **Client/wire** (consumed by [`super::client::RemoteProjector`]),
//!   keyed on the per-shard *send-attempt* counter: connection cuts
//!   (`cut_every` / `cut_ppm`), stalled sends (`stall_ppm` ×
//!   `stall_ms`), partial writes that truncate a frame mid-stream
//!   (`partial_ppm`), and single-bit payload corruption that exercises
//!   the CRC path end to end (`corrupt_ppm`).
//! * **Server/device** (consumed by [`super::server::ProjectorServer`]),
//!   keyed on the per-shard *arrival* counter: error bursts
//!   (`dev_err_ppm` × `dev_err_burst` consecutive arrivals) and stall
//!   windows (`dev_stall_ppm` × `dev_stall_ms`).  A device fault
//!   replies `ERR_UNAVAILABLE` *without executing the projection*, so
//!   the noise stream never advances for a faulted frame and a resumed
//!   retry still lands exactly once.
//!
//! Keying retries on the attempt/arrival counters (not the frame seq)
//! is deliberate: a retried frame draws a *fresh* decision, so bounded
//! retries converge through error bursts while the overall schedule
//! stays reproducible for the one-client-per-shard topologies the
//! trainer builds.
//!
//! The config is all-integer and `Copy + Eq + Hash` so it embeds
//! directly in [`super::NetOptions`] (and hence flows through the one
//! topology build path) without touching the topology's canonical
//! identity.  `None` everywhere means the hot paths skip injection with
//! a single `Option` test — zero cost when chaos is off.

use anyhow::{bail, Result};
use std::fmt;

use crate::util::rng::Pcg64;

/// Parts-per-million denominator for every probability knob.
pub const PPM: u64 = 1_000_000;

// Decision sites: each fault type draws from its own derived stream so
// the knobs are independent (raising `corrupt_ppm` never shifts which
// frames get cut).
const SITE_CUT: u64 = 0x11;
const SITE_PARTIAL: u64 = 0x22;
const SITE_CORRUPT: u64 = 0x33;
const SITE_CORRUPT_POS: u64 = 0x44;
const SITE_STALL: u64 = 0x55;
const SITE_DEV_ERR: u64 = 0x66;
const SITE_DEV_STALL: u64 = 0x77;

/// A seeded fault plan: the `--fault-plan` / `[net] fault_plan` spec,
/// parsed.  All probabilities are parts-per-million; all durations are
/// integer milliseconds; zero disables the knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FaultPlanCfg {
    /// Seed of the plan's own `Pcg64` streams (independent of every
    /// training seed — chaos never perturbs the math's draws).
    pub seed: u64,
    /// Cut the connection before every Nth send attempt (0 = off) —
    /// the deterministic "cut after the Nth frame" schedule.
    pub cut_every: u32,
    /// Probabilistic connection cut before a send attempt.
    pub cut_ppm: u32,
    /// Write only a frame prefix, then cut — the peer sees `Truncated`.
    pub partial_ppm: u32,
    /// Flip one bit of an encoded frame — the peer sees `BadCrc`.
    pub corrupt_ppm: u32,
    /// Stall this send attempt for `stall_ms` before writing.
    pub stall_ppm: u32,
    /// Stalled-send duration (ms).
    pub stall_ms: u32,
    /// Server-side: begin an error burst at this arrival.
    pub dev_err_ppm: u32,
    /// Consecutive arrivals each burst errors (>= 1 when triggered).
    pub dev_err_burst: u32,
    /// Server-side: stall the device for `dev_stall_ms` at this arrival.
    pub dev_stall_ppm: u32,
    /// Device stall-window duration (ms).
    pub dev_stall_ms: u32,
}

impl Default for FaultPlanCfg {
    fn default() -> Self {
        FaultPlanCfg {
            seed: 0,
            cut_every: 0,
            cut_ppm: 0,
            partial_ppm: 0,
            corrupt_ppm: 0,
            stall_ppm: 0,
            stall_ms: 0,
            dev_err_ppm: 0,
            dev_err_burst: 1,
            dev_stall_ppm: 0,
            dev_stall_ms: 0,
        }
    }
}

impl FaultPlanCfg {
    /// Parse the spec string: comma-separated `key=value` pairs, e.g.
    /// `seed=7,cut_every=5,corrupt_ppm=20000,dev_err_ppm=50000,
    /// dev_err_burst=2`.  Unknown keys and non-integer values are
    /// loud errors; every key is optional (defaults above).
    pub fn parse(spec: &str) -> Result<FaultPlanCfg> {
        let mut cfg = FaultPlanCfg::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, value)) = part.split_once('=') else {
                bail!("fault plan entry '{part}' is not key=value");
            };
            let (key, value) = (key.trim(), value.trim());
            let parse_u32 = |what: &str| -> Result<u32> {
                value
                    .parse::<u32>()
                    .map_err(|_| anyhow::anyhow!("fault plan {what} '{value}' is not a u32"))
            };
            match key {
                "seed" => {
                    cfg.seed = value
                        .parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("fault plan seed '{value}' is not a u64"))?
                }
                "cut_every" => cfg.cut_every = parse_u32(key)?,
                "cut_ppm" => cfg.cut_ppm = parse_u32(key)?,
                "partial_ppm" => cfg.partial_ppm = parse_u32(key)?,
                "corrupt_ppm" => cfg.corrupt_ppm = parse_u32(key)?,
                "stall_ppm" => cfg.stall_ppm = parse_u32(key)?,
                "stall_ms" => cfg.stall_ms = parse_u32(key)?,
                "dev_err_ppm" => cfg.dev_err_ppm = parse_u32(key)?,
                "dev_err_burst" => cfg.dev_err_burst = parse_u32(key)?,
                "dev_stall_ppm" => cfg.dev_stall_ppm = parse_u32(key)?,
                "dev_stall_ms" => cfg.dev_stall_ms = parse_u32(key)?,
                other => bail!(
                    "unknown fault plan key '{other}' (known: seed, cut_every, \
                     cut_ppm, partial_ppm, corrupt_ppm, stall_ppm, stall_ms, \
                     dev_err_ppm, dev_err_burst, dev_stall_ppm, dev_stall_ms)"
                ),
            }
        }
        for (ppm, name) in [
            (cfg.cut_ppm, "cut_ppm"),
            (cfg.partial_ppm, "partial_ppm"),
            (cfg.corrupt_ppm, "corrupt_ppm"),
            (cfg.stall_ppm, "stall_ppm"),
            (cfg.dev_err_ppm, "dev_err_ppm"),
            (cfg.dev_stall_ppm, "dev_stall_ppm"),
        ] {
            if ppm as u64 > PPM {
                bail!("fault plan {name}={ppm} exceeds {PPM} (parts-per-million)");
            }
        }
        if cfg.dev_err_burst == 0 {
            bail!("fault plan dev_err_burst must be >= 1");
        }
        Ok(cfg)
    }

    /// Parse from an environment variable (benches): `Ok(None)` when
    /// unset or empty, a loud error on a malformed spec.
    pub fn from_env(var: &str) -> Result<Option<FaultPlanCfg>> {
        match std::env::var(var) {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlanCfg::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    /// Canonical spec spelling: round-trips through [`parse`], emitting
    /// only non-default knobs (an all-default plan prints `seed=N`).
    ///
    /// [`parse`]: FaultPlanCfg::parse
    pub fn canonical(&self) -> String {
        let mut out = format!("seed={}", self.seed);
        let d = FaultPlanCfg::default();
        for (val, def, name) in [
            (self.cut_every, d.cut_every, "cut_every"),
            (self.cut_ppm, d.cut_ppm, "cut_ppm"),
            (self.partial_ppm, d.partial_ppm, "partial_ppm"),
            (self.corrupt_ppm, d.corrupt_ppm, "corrupt_ppm"),
            (self.stall_ppm, d.stall_ppm, "stall_ppm"),
            (self.stall_ms, d.stall_ms, "stall_ms"),
            (self.dev_err_ppm, d.dev_err_ppm, "dev_err_ppm"),
            (self.dev_err_burst, d.dev_err_burst, "dev_err_burst"),
            (self.dev_stall_ppm, d.dev_stall_ppm, "dev_stall_ppm"),
            (self.dev_stall_ms, d.dev_stall_ms, "dev_stall_ms"),
        ] {
            if val != def {
                out.push_str(&format!(",{name}={val}"));
            }
        }
        out
    }

    /// True when no knob can ever fire — callers may skip injection
    /// entirely (equivalent to no plan at all).
    pub fn is_noop(&self) -> bool {
        self.cut_every == 0
            && self.cut_ppm == 0
            && self.partial_ppm == 0
            && self.corrupt_ppm == 0
            && self.stall_ppm == 0
            && self.dev_err_ppm == 0
            && self.dev_stall_ppm == 0
    }

    // -- decision functions -------------------------------------------------
    //
    // Each is a pure function of (seed, site, shard, counter): the
    // counter-addressed draw makes decisions independent of evaluation
    // order, so concurrent shards and retried frames never perturb
    // each other's schedules.

    fn draw(&self, site: u64, shard: u32, n: u64) -> u64 {
        // One derived stream per (site, shard); `advance` addresses the
        // nth output directly (O(log n), no sequential walk).
        let mut rng = Pcg64::new(
            self.seed ^ site.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            0xC0FF_EE00 ^ (shard as u64),
        );
        rng.advance(n as u128);
        rng.next_u64()
    }

    fn hit(&self, site: u64, shard: u32, n: u64, ppm: u32) -> bool {
        ppm > 0 && self.draw(site, shard, n) % PPM < ppm as u64
    }

    /// Client: cut the connection before send attempt `n` (0-based)?
    pub fn cut(&self, shard: u32, n: u64) -> bool {
        (self.cut_every > 0 && (n + 1) % self.cut_every as u64 == 0)
            || self.hit(SITE_CUT, shard, n, self.cut_ppm)
    }

    /// Client: truncate this send attempt's frame mid-stream?
    pub fn partial(&self, shard: u32, n: u64) -> bool {
        self.hit(SITE_PARTIAL, shard, n, self.partial_ppm)
    }

    /// Client: corrupt one bit of this send attempt's frame?  Returns
    /// the bit index to flip (deterministic per attempt).
    pub fn corrupt(&self, shard: u32, n: u64, frame_bits: u64) -> Option<u64> {
        if frame_bits == 0 || !self.hit(SITE_CORRUPT, shard, n, self.corrupt_ppm) {
            return None;
        }
        Some(self.draw(SITE_CORRUPT_POS, shard, n) % frame_bits)
    }

    /// Client: stall duration before send attempt `n`, if any.
    pub fn stall(&self, shard: u32, n: u64) -> Option<std::time::Duration> {
        if self.stall_ms > 0 && self.hit(SITE_STALL, shard, n, self.stall_ppm) {
            Some(std::time::Duration::from_millis(self.stall_ms as u64))
        } else {
            None
        }
    }

    /// Server: does arrival `n` on `shard` fall inside an error burst?
    /// A hit at arrival `k` errors arrivals `k ..= k + burst - 1`, so a
    /// client retrying with fresh arrival numbers eventually passes.
    pub fn dev_err(&self, shard: u32, n: u64) -> bool {
        if self.dev_err_ppm == 0 {
            return false;
        }
        let burst = self.dev_err_burst.max(1) as u64;
        let lo = n.saturating_sub(burst - 1);
        (lo..=n).any(|k| self.hit(SITE_DEV_ERR, shard, k, self.dev_err_ppm))
    }

    /// Server: stall-window duration at arrival `n`, if any.
    pub fn dev_stall(&self, shard: u32, n: u64) -> Option<std::time::Duration> {
        if self.dev_stall_ms > 0 && self.hit(SITE_DEV_STALL, shard, n, self.dev_stall_ppm) {
            Some(std::time::Duration::from_millis(self.dev_stall_ms as u64))
        } else {
            None
        }
    }
}

impl fmt::Display for FaultPlanCfg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.canonical())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_round_trips() {
        let spec = "seed=7,cut_every=5,corrupt_ppm=20000,stall_ppm=1000,\
                    stall_ms=3,dev_err_ppm=50000,dev_err_burst=2";
        let cfg = FaultPlanCfg::parse(spec).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.cut_every, 5);
        assert_eq!(cfg.corrupt_ppm, 20_000);
        assert_eq!(cfg.dev_err_burst, 2);
        let back = FaultPlanCfg::parse(&cfg.canonical()).unwrap();
        assert_eq!(back, cfg);
        // Whitespace and trailing commas are tolerated.
        assert_eq!(
            FaultPlanCfg::parse(" seed=7 , cut_every=5 ,").unwrap().cut_every,
            5
        );
    }

    #[test]
    fn malformed_specs_are_loud() {
        assert!(FaultPlanCfg::parse("bogus_key=1").is_err());
        assert!(FaultPlanCfg::parse("seed").is_err());
        assert!(FaultPlanCfg::parse("cut_ppm=notanint").is_err());
        assert!(FaultPlanCfg::parse("cut_ppm=2000000").is_err(), "ppm > 1e6");
        assert!(FaultPlanCfg::parse("dev_err_burst=0").is_err());
    }

    #[test]
    fn decisions_are_deterministic_and_order_free() {
        let cfg = FaultPlanCfg::parse("seed=11,cut_ppm=300000,corrupt_ppm=300000").unwrap();
        // Same (shard, counter) always answers the same, in any order.
        let forward: Vec<bool> = (0..64).map(|n| cfg.cut(1, n)).collect();
        let backward: Vec<bool> = (0..64).rev().map(|n| cfg.cut(1, n)).rev().collect();
        assert_eq!(forward, backward);
        assert!(forward.iter().any(|&b| b), "30% over 64 draws must hit");
        assert!(!forward.iter().all(|&b| b), "and must not always hit");
        // Sites are independent: the cut schedule differs from corrupt.
        let corrupt: Vec<bool> =
            (0..64).map(|n| cfg.corrupt(1, n, 1024).is_some()).collect();
        assert_ne!(forward, corrupt);
        // Shards are independent streams.
        let other: Vec<bool> = (0..64).map(|n| cfg.cut(2, n)).collect();
        assert_ne!(forward, other);
    }

    #[test]
    fn cut_every_is_an_exact_schedule() {
        let cfg = FaultPlanCfg::parse("seed=1,cut_every=4").unwrap();
        for n in 0..32 {
            assert_eq!(cfg.cut(0, n), (n + 1) % 4 == 0, "attempt {n}");
        }
    }

    #[test]
    fn dev_err_bursts_cover_consecutive_arrivals() {
        let cfg = FaultPlanCfg::parse("seed=3,dev_err_ppm=60000,dev_err_burst=3").unwrap();
        // Find a triggering arrival, then the burst must span it.
        let trigger = (0..4096)
            .find(|&n| cfg.hit(super::SITE_DEV_ERR, 0, n, cfg.dev_err_ppm))
            .expect("6% over 4096 draws must trigger");
        for k in trigger..trigger + 3 {
            assert!(cfg.dev_err(0, k), "arrival {k} inside the burst");
        }
    }

    #[test]
    fn zero_plan_is_a_noop() {
        let cfg = FaultPlanCfg::parse("seed=9").unwrap();
        assert!(cfg.is_noop());
        for n in 0..128 {
            assert!(!cfg.cut(0, n));
            assert!(!cfg.partial(0, n));
            assert!(cfg.corrupt(0, n, 4096).is_none());
            assert!(cfg.stall(0, n).is_none());
            assert!(!cfg.dev_err(0, n));
            assert!(cfg.dev_stall(0, n).is_none());
        }
    }

    #[test]
    fn env_parsing_is_optional_but_strict() {
        std::env::remove_var("LITL_TEST_FAULT_PLAN");
        assert!(FaultPlanCfg::from_env("LITL_TEST_FAULT_PLAN").unwrap().is_none());
        std::env::set_var("LITL_TEST_FAULT_PLAN", "seed=5,cut_every=2");
        assert_eq!(
            FaultPlanCfg::from_env("LITL_TEST_FAULT_PLAN").unwrap().unwrap().cut_every,
            2
        );
        std::env::set_var("LITL_TEST_FAULT_PLAN", "nope");
        assert!(FaultPlanCfg::from_env("LITL_TEST_FAULT_PLAN").is_err());
        std::env::remove_var("LITL_TEST_FAULT_PLAN");
    }
}
