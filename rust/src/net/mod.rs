//! Networked projector servers: the wire protocol, the server that
//! hosts shard devices behind a listener, and the client that stands in
//! for them behind the [`crate::coordinator::projector::Projector`]
//! trait.
//!
//! The paper's co-processor is a *separate physical device* the trainer
//! talks to over a link; this module is that link.  A `litl serve`
//! process hosts one or more shards of a
//! [`crate::coordinator::topology::Topology`] behind a TCP or Unix-
//! domain-socket listener ([`server::ProjectorServer`]); a trainer (or
//! the sharded projection service) reaches them through
//! [`client::RemoteProjector`], declared per shard via the topology's
//! `remote:<addr>` endpoints — one descriptor, mixed local+remote
//! fleet, same single build path.
//!
//! **Standing contract:** a loopback remote shard is **bitwise
//! identical** to the same shard in-process, noisy optics included.
//! The wire codec ([`frame`]) moves f32 tensors as raw IEEE-754 bits,
//! the server serializes each shard's requests on its own device (so
//! the per-shard noise-draw order is the submission order, exactly as
//! in-process), and the client *never* silently retries an in-flight
//! projection — a resend would advance the device's noise stream and
//! diverge the bits.  Reconnection with bounded exponential backoff
//! happens only *between* requests; a request cut mid-flight completes
//! with an error so the serving layer's failover state machine trips
//! naturally on a dead server.  Pinned in `tests/net_parity.rs` and
//! enforced by the CI `net-smoke` job.
//!
//! **Observability:** both ends count `net_frames_{tx,rx}` /
//! `net_bytes_{tx,rx}`, the client counts `net_reconnects` and times
//! each round trip into the `net_rtt` histogram, all through the
//! ordinary [`crate::metrics::Registry`] (and hence the Prometheus
//! export), plus a `net_send`/`net_recv` trace span pair per request.

pub mod client;
pub mod frame;
pub mod server;

use std::fmt;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::time::Duration;

use anyhow::{bail, Result};

pub use client::RemoteProjector;
pub use frame::{Msg, WireError};
pub use server::ProjectorServer;

// Registry metric names (client + server share the vocabulary).
pub const NET_FRAMES_TX: &str = "net_frames_tx";
pub const NET_FRAMES_RX: &str = "net_frames_rx";
pub const NET_BYTES_TX: &str = "net_bytes_tx";
pub const NET_BYTES_RX: &str = "net_bytes_rx";
pub const NET_RECONNECTS: &str = "net_reconnects";
pub const NET_RTT: &str = "net_rtt";

/// A listener/dial address: TCP (`tcp:host:port`, or bare `host:port`)
/// or a Unix domain socket (`uds:/path/to.sock`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Addr {
    Tcp(String),
    Uds(String),
}

impl Addr {
    /// Parse the `tcp:`/`uds:` spelling (bare `host:port` means TCP).
    pub fn parse(s: &str) -> Result<Addr> {
        if let Some(rest) = s.strip_prefix("tcp:") {
            if rest.is_empty() {
                bail!("empty tcp address in '{s}'");
            }
            Ok(Addr::Tcp(rest.to_string()))
        } else if let Some(rest) = s.strip_prefix("uds:") {
            if rest.is_empty() {
                bail!("empty uds path in '{s}'");
            }
            Ok(Addr::Uds(rest.to_string()))
        } else if s.contains(':') {
            Ok(Addr::Tcp(s.to_string()))
        } else {
            bail!("address '{s}' is neither tcp:host:port nor uds:/path");
        }
    }

    /// Canonical spelling (round-trips through [`Addr::parse`]).
    pub fn canonical(&self) -> String {
        match self {
            Addr::Tcp(hp) => format!("tcp:{hp}"),
            Addr::Uds(p) => format!("uds:{p}"),
        }
    }
}

impl fmt::Display for Addr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.canonical())
    }
}

/// Client-side transport tuning.  Operational knobs only — they shape
/// *when* a connection attempt gives up, never *what* bits a successful
/// projection returns — so they are deliberately excluded from
/// [`crate::coordinator::topology::Topology::canonical`] identity.
///
/// All times are integer milliseconds so the containing types keep
/// their derived `Eq`/`Hash`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NetOptions {
    /// Per-attempt dial timeout (TCP; UDS connects are local and fast).
    pub connect_timeout_ms: u64,
    /// Read timeout while awaiting a reply; an expiry kills the
    /// connection and errors the in-flight frame.
    pub request_timeout_ms: u64,
    /// Dial attempts per (re)connection before giving up.
    pub reconnect_tries: u32,
    /// First backoff sleep between dial attempts …
    pub reconnect_base_ms: u64,
    /// … doubling up to this ceiling.
    pub reconnect_max_ms: u64,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            connect_timeout_ms: 1_000,
            request_timeout_ms: 30_000,
            reconnect_tries: 3,
            reconnect_base_ms: 50,
            reconnect_max_ms: 2_000,
        }
    }
}

/// One connected byte stream over either transport.
pub enum NetStream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl NetStream {
    /// Dial `addr` once (no retries — backoff lives in the client).
    pub fn connect(addr: &Addr, connect_timeout: Duration) -> Result<NetStream> {
        match addr {
            Addr::Tcp(hp) => {
                use std::net::ToSocketAddrs;
                let sa = hp
                    .to_socket_addrs()?
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("'{hp}' resolved to no address"))?;
                let s = TcpStream::connect_timeout(&sa, connect_timeout)?;
                s.set_nodelay(true)?;
                Ok(NetStream::Tcp(s))
            }
            Addr::Uds(path) => Ok(NetStream::Uds(UnixStream::connect(path)?)),
        }
    }

    /// Bound the blocking wait for a reply (`None` = wait forever).
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        match self {
            NetStream::Tcp(s) => s.set_read_timeout(d)?,
            NetStream::Uds(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            NetStream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            NetStream::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            NetStream::Uds(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parses_and_round_trips() {
        for (input, want) in [
            ("tcp:127.0.0.1:9000", Addr::Tcp("127.0.0.1:9000".into())),
            ("127.0.0.1:9000", Addr::Tcp("127.0.0.1:9000".into())),
            ("uds:/tmp/litl.sock", Addr::Uds("/tmp/litl.sock".into())),
        ] {
            let addr = Addr::parse(input).unwrap();
            assert_eq!(addr, want);
            assert_eq!(Addr::parse(&addr.canonical()).unwrap(), addr);
        }
        assert!(Addr::parse("not-an-address").is_err());
        assert!(Addr::parse("tcp:").is_err());
        assert!(Addr::parse("uds:").is_err());
    }
}
