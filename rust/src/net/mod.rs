//! Networked projector servers: the wire protocol, the server that
//! hosts shard devices behind a listener, and the client that stands in
//! for them behind the [`crate::coordinator::projector::Projector`]
//! trait.
//!
//! The paper's co-processor is a *separate physical device* the trainer
//! talks to over a link; this module is that link.  A `litl serve`
//! process hosts one or more shards of a
//! [`crate::coordinator::topology::Topology`] behind a TCP or Unix-
//! domain-socket listener ([`server::ProjectorServer`]); a trainer (or
//! the sharded projection service) reaches them through
//! [`client::RemoteProjector`], declared per shard via the topology's
//! `remote:<addr>` endpoints — one descriptor, mixed local+remote
//! fleet, same single build path.
//!
//! **Standing contract:** a loopback remote shard is **bitwise
//! identical** to the same shard in-process, noisy optics included.
//! The wire codec ([`frame`]) moves f32 tensors as raw IEEE-754 bits,
//! the server serializes each shard's requests on its own device (so
//! the per-shard noise-draw order is the submission order, exactly as
//! in-process), and the client *never* blindly retries an in-flight
//! projection — a resend the server had already executed would advance
//! the device's noise stream a second time and diverge the bits.
//!
//! Since the v2 wire protocol there are two ways to complete an
//! in-flight frame on a dying connection:
//!
//! * **Resume off** (`resume_tries == 0`, the default): the request
//!   completes with an error so the serving layer's failover state
//!   machine trips naturally on a dead server — exactly the pre-v2
//!   semantics.  Reconnection with bounded exponential backoff still
//!   happens only *between* requests.
//! * **Resume on**: the client redials, re-attaches its session with a
//!   `Resume`/`ResumeOk` cursor handshake, and re-requests the
//!   in-flight frame; the server's bounded replay journal guarantees
//!   the projection executes **exactly once** (a journaled reply is
//!   replayed, a never-executed frame runs now).  If the server cannot
//!   prove the frame's fate it answers a typed cursor mismatch and the
//!   client errors deterministically into failover — never a silent
//!   double draw, never a hang.
//!
//! Pinned in `tests/net_parity.rs` and `tests/chaos.rs` (the seeded
//! fault-injection soak: a fault-ridden run with resume on finishes
//! bitwise identical to the fault-free run) and enforced by the CI
//! `net-smoke` + `chaos-smoke` jobs.  [`faults`] provides the seeded,
//! fully reproducible [`FaultPlanCfg`] both the client and server
//! layers inject from.
//!
//! **Audit note (`unwrap`/`expect` in this module):** the only
//! remaining `unwrap()`s under `net/` are (a) slice→array conversions
//! in the payload decoder that follow an explicit bounds check (see
//! `frame::Dec`) and (b) lock poisoning recovery via
//! `unwrap_or_else(PoisonError::into_inner)`.  Everything reachable
//! from hostile input or I/O failure returns a typed
//! [`frame::WireError`] — exercised by the decoder property fuzz and
//! the chaos suite.
//!
//! **Observability:** both ends count `net_frames_{tx,rx}` /
//! `net_bytes_{tx,rx}`, the client counts `net_reconnects` and
//! `net_resumes` and times each round trip into the `net_rtt`
//! histogram, the server counts `net_journal_replays` /
//! `net_journal_evictions` and gauges `net_journal_sessions`, and both
//! ends count injected faults in `net_faults_injected` — all through
//! the ordinary [`crate::metrics::Registry`] (and hence the Prometheus
//! export), plus `net_send`/`net_recv` trace spans per request and a
//! `net_resume` span per resume handshake.

pub mod client;
pub mod faults;
pub mod frame;
pub mod server;

use std::fmt;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::time::Duration;

use anyhow::{bail, Result};

pub use client::RemoteProjector;
pub use faults::FaultPlanCfg;
pub use frame::{Msg, WireError};
pub use server::{ProjectorServer, ServerOptions};

// Registry metric names (client + server share the vocabulary).
pub const NET_FRAMES_TX: &str = "net_frames_tx";
pub const NET_FRAMES_RX: &str = "net_frames_rx";
pub const NET_BYTES_TX: &str = "net_bytes_tx";
pub const NET_BYTES_RX: &str = "net_bytes_rx";
pub const NET_RECONNECTS: &str = "net_reconnects";
pub const NET_RTT: &str = "net_rtt";
/// Client: completed session-resume handshakes (a redial that
/// re-attached its stream instead of tripping failover).
pub const NET_RESUMES: &str = "net_resumes";
/// Server: journaled replies replayed to a resumed client (the
/// projection itself ran exactly once, at first arrival).
pub const NET_JOURNAL_REPLAYS: &str = "net_journal_replays";
/// Server: journal entries evicted by the LRU cap — a later resume of
/// an evicted session is a cursor mismatch, i.e. a failover.
pub const NET_JOURNAL_EVICTIONS: &str = "net_journal_evictions";
/// Server: live journal entries (gauge).
pub const NET_JOURNAL_SESSIONS: &str = "net_journal_sessions";
/// Both ends: faults injected by the active [`FaultPlanCfg`] (cuts,
/// corruptions, stalls, device errors — chaos drills only).
pub const NET_FAULTS_INJECTED: &str = "net_faults_injected";

/// A listener/dial address: TCP (`tcp:host:port`, or bare `host:port`)
/// or a Unix domain socket (`uds:/path/to.sock`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Addr {
    Tcp(String),
    Uds(String),
}

impl Addr {
    /// Parse the `tcp:`/`uds:` spelling (bare `host:port` means TCP).
    pub fn parse(s: &str) -> Result<Addr> {
        if let Some(rest) = s.strip_prefix("tcp:") {
            if rest.is_empty() {
                bail!("empty tcp address in '{s}'");
            }
            Ok(Addr::Tcp(rest.to_string()))
        } else if let Some(rest) = s.strip_prefix("uds:") {
            if rest.is_empty() {
                bail!("empty uds path in '{s}'");
            }
            Ok(Addr::Uds(rest.to_string()))
        } else if s.contains(':') {
            Ok(Addr::Tcp(s.to_string()))
        } else {
            bail!("address '{s}' is neither tcp:host:port nor uds:/path");
        }
    }

    /// Canonical spelling (round-trips through [`Addr::parse`]).
    pub fn canonical(&self) -> String {
        match self {
            Addr::Tcp(hp) => format!("tcp:{hp}"),
            Addr::Uds(p) => format!("uds:{p}"),
        }
    }
}

impl fmt::Display for Addr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.canonical())
    }
}

/// Client-side transport tuning.  Operational knobs only — they shape
/// *when* a connection attempt gives up, never *what* bits a successful
/// projection returns — so they are deliberately excluded from
/// [`crate::coordinator::topology::Topology::canonical`] identity.
///
/// All times are integer milliseconds so the containing types keep
/// their derived `Eq`/`Hash`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NetOptions {
    /// Per-attempt dial timeout (TCP; UDS connects are local and fast).
    pub connect_timeout_ms: u64,
    /// Read timeout while awaiting a reply; an expiry kills the
    /// connection and errors the in-flight frame.
    pub request_timeout_ms: u64,
    /// Dial attempts per (re)connection before giving up.
    pub reconnect_tries: u32,
    /// First backoff sleep between dial attempts …
    pub reconnect_base_ms: u64,
    /// … doubling up to this ceiling.
    pub reconnect_max_ms: u64,
    /// Session-resume budget: how many times one projection may be
    /// re-requested across redials before the client gives up and
    /// errors into failover.  0 disables resume entirely (the pre-v2
    /// semantics: an in-flight frame on a dying connection errors and
    /// is never resent).  Resume never changes successful bits — the
    /// server's journal executes each frame exactly once — so this
    /// stays outside the topology's canonical identity like every
    /// other knob here.
    pub resume_tries: u32,
    /// Client-side deterministic fault plan (chaos drills; `None` =
    /// zero-cost no-op).  The same plan struct drives server-side
    /// device faults when passed to [`ServerOptions`].
    pub faults: Option<FaultPlanCfg>,
}

/// The resume budget `--net-resume on` selects: generous enough to
/// ride out an injected error burst, small enough that a genuinely
/// dead server still fails fast into failover.
pub const RESUME_TRIES_DEFAULT: u32 = 8;

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            connect_timeout_ms: 1_000,
            request_timeout_ms: 30_000,
            reconnect_tries: 3,
            reconnect_base_ms: 50,
            reconnect_max_ms: 2_000,
            resume_tries: 0,
            faults: None,
        }
    }
}

/// One connected byte stream over either transport.
pub enum NetStream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl NetStream {
    /// Dial `addr` once (no retries — backoff lives in the client).
    pub fn connect(addr: &Addr, connect_timeout: Duration) -> Result<NetStream> {
        match addr {
            Addr::Tcp(hp) => {
                use std::net::ToSocketAddrs;
                let sa = hp
                    .to_socket_addrs()?
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("'{hp}' resolved to no address"))?;
                let s = TcpStream::connect_timeout(&sa, connect_timeout)?;
                s.set_nodelay(true)?;
                Ok(NetStream::Tcp(s))
            }
            Addr::Uds(path) => Ok(NetStream::Uds(UnixStream::connect(path)?)),
        }
    }

    /// Bound the blocking wait for a reply (`None` = wait forever).
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        match self {
            NetStream::Tcp(s) => s.set_read_timeout(d)?,
            NetStream::Uds(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            NetStream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            NetStream::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            NetStream::Uds(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parses_and_round_trips() {
        for (input, want) in [
            ("tcp:127.0.0.1:9000", Addr::Tcp("127.0.0.1:9000".into())),
            ("127.0.0.1:9000", Addr::Tcp("127.0.0.1:9000".into())),
            ("uds:/tmp/litl.sock", Addr::Uds("/tmp/litl.sock".into())),
        ] {
            let addr = Addr::parse(input).unwrap();
            assert_eq!(addr, want);
            assert_eq!(Addr::parse(&addr.canonical()).unwrap(), addr);
        }
        assert!(Addr::parse("not-an-address").is_err());
        assert!(Addr::parse("tcp:").is_err());
        assert!(Addr::parse("uds:").is_err());
    }
}
