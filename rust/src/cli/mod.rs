//! Command-line interface (hand-rolled; clap is not in the offline
//! vendor set).
//!
//! Grammar: `litl <command> [--flag value]... [--bool-flag] [positional]`.
//! Commands are defined in `main.rs`; this module is the parser plus
//! help rendering.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: command, flags, positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.  Flags may be `--key value` or `--key=value`;
    /// a flag with no following value is boolean `"true"`.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                out.command = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if key.is_empty() {
                    bail!("empty flag name in '{arg}'");
                }
                let value = match inline {
                    Some(v) => v,
                    None => match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            it.next().unwrap().clone()
                        }
                        _ => "true".to_string(),
                    },
                };
                out.flags.entry(key).or_default().push(value);
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences of a repeatable flag (e.g. `--set k=v`).
    pub fn flag_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn flag_bool(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {s}: {e}")),
        }
    }

    /// Keys that were provided (for unknown-flag detection).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.flags.keys().map(|s| s.as_str())
    }

    /// Error on any flag not in `allowed`.
    pub fn ensure_known(&self, allowed: &[&str]) -> Result<()> {
        for k in self.keys() {
            if !allowed.contains(&k) {
                bail!("unknown flag --{k} (allowed: {})", allowed.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(|s| s.to_string()).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn command_flags_positionals() {
        // Value consumption is greedy: a bool flag followed by a
        // positional must use `--flag=true` or come last.
        let a = parse("train --epochs 3 --algo=optical out.csv --verbose");
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("epochs"), Some("3"));
        assert_eq!(a.flag("algo"), Some("optical"));
        assert!(a.flag_bool("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
        let b = parse("train --verbose=true out.csv");
        assert!(b.flag_bool("verbose"));
        assert_eq!(b.positional, vec!["out.csv"]);
    }

    #[test]
    fn repeated_flags_accumulate() {
        let a = parse("train --set a=1 --set b=2");
        assert_eq!(a.flag_all("set"), vec!["a=1", "b=2"]);
        assert_eq!(a.flag("set"), Some("b=2"));
    }

    #[test]
    fn typed_parse() {
        let a = parse("x --lr 0.01");
        assert_eq!(a.flag_parse::<f32>("lr").unwrap(), Some(0.01));
        assert_eq!(a.flag_parse::<u32>("missing").unwrap(), None);
        let b = parse("x --lr abc");
        assert!(b.flag_parse::<f32>("lr").is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("train --epochs 1 --nope 2");
        assert!(a.ensure_known(&["epochs"]).is_err());
        assert!(a.ensure_known(&["epochs", "nope"]).is_ok());
    }

    #[test]
    fn no_command() {
        let a = parse("--help");
        assert_eq!(a.command, "");
        assert!(a.flag_bool("help"));
    }
}
