//! Typed view of `artifacts/manifest.json`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::optics::OpuParams;
use crate::util::json::Json;

/// One lowered entry point's signature.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub entry: String,
    pub config: String,
    pub file: String,
    /// (name, shape) per input, in call order.
    pub inputs: Vec<(String, Vec<usize>)>,
    /// Output names, in tuple order.
    pub outputs: Vec<String>,
}

/// One (batch, hidden) build configuration.
#[derive(Clone, Debug)]
pub struct BuildConfig {
    pub name: String,
    pub batch: usize,
    pub hidden: usize,
    pub eval_batch: usize,
    pub modes: usize,
    pub layers: Vec<usize>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub err_dim: usize,
    pub opu: OpuParams,
    pub configs: Vec<BuildConfig>,
    pub artifacts: Vec<ArtifactSig>,
}

fn want<'j>(j: &'j Json, key: &str, ctx: &str) -> Result<&'j Json> {
    j.get(key)
        .with_context(|| format!("manifest: missing '{key}' in {ctx}"))
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let opu_j = want(&root, "opu", "root")?;
        let f = |key: &str| -> Result<f64> {
            want(opu_j, key, "opu")?
                .as_f64()
                .with_context(|| format!("opu.{key} not a number"))
        };
        let opu = OpuParams {
            oversample: f("oversample")? as usize,
            carrier: f("carrier")?,
            amp: f("amp")?,
            n_ph: f("n_ph")? as f32,
            read_sigma: f("read_sigma")? as f32,
            frame_rate_hz: f("frame_rate_hz")?,
            power_watts: f("power_watts")?,
            max_modes: f("max_modes")? as usize,
        };

        let configs = want(&root, "configs", "root")?
            .as_arr()
            .context("configs not an array")?
            .iter()
            .map(|c| -> Result<BuildConfig> {
                Ok(BuildConfig {
                    name: want(c, "name", "config")?.as_str().unwrap_or("").to_string(),
                    batch: want(c, "batch", "config")?.as_usize().unwrap_or(0),
                    hidden: want(c, "hidden", "config")?.as_usize().unwrap_or(0),
                    eval_batch: want(c, "eval_batch", "config")?.as_usize().unwrap_or(0),
                    modes: want(c, "modes", "config")?.as_usize().unwrap_or(0),
                    layers: want(c, "layers", "config")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let artifacts = want(&root, "artifacts", "root")?
            .as_arr()
            .context("artifacts not an array")?
            .iter()
            .map(|a| -> Result<ArtifactSig> {
                let inputs = want(a, "inputs", "artifact")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|i| {
                        let name = i
                            .get("name")
                            .and_then(|n| n.as_str())
                            .unwrap_or("")
                            .to_string();
                        let shape = i
                            .get("shape")
                            .and_then(|s| s.as_arr())
                            .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                            .unwrap_or_default();
                        (name, shape)
                    })
                    .collect();
                let outputs = want(a, "outputs", "artifact")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|o| o.get("name").and_then(|n| n.as_str()))
                    .map(|s| s.to_string())
                    .collect();
                Ok(ArtifactSig {
                    entry: want(a, "entry", "artifact")?.as_str().unwrap_or("").to_string(),
                    config: want(a, "config", "artifact")?.as_str().unwrap_or("").to_string(),
                    file: want(a, "file", "artifact")?.as_str().unwrap_or("").to_string(),
                    inputs,
                    outputs,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let m = Manifest {
            dir,
            err_dim: want(&root, "err_dim", "root")?.as_usize().unwrap_or(10),
            opu,
            configs,
            artifacts,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.configs.is_empty() {
            bail!("manifest has no build configs");
        }
        for a in &self.artifacts {
            if !self.dir.join(&a.file).exists() {
                bail!("artifact file missing: {} (run `make artifacts`)", a.file);
            }
        }
        Ok(())
    }

    pub fn config(&self, name: &str) -> Result<&BuildConfig> {
        self.configs
            .iter()
            .find(|c| c.name == name)
            .with_context(|| {
                format!(
                    "no build config '{name}' in manifest (have: {})",
                    self.configs
                        .iter()
                        .map(|c| c.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn artifact(&self, entry: &str, config: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .iter()
            .find(|a| a.entry == entry && a.config == config)
            .with_context(|| format!("no artifact '{entry}' for config '{config}'"))
    }

    pub fn artifact_path(&self, sig: &ArtifactSig) -> PathBuf {
        self.dir.join(&sig.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
 "version": 1,
 "err_dim": 10,
 "opu": {"oversample": 4, "carrier": 1.5707963, "amp": 16.0, "n_ph": 100.0,
         "read_sigma": 2.0, "adc_gain_err": 2.7, "frame_rate_hz": 1500.0,
         "power_watts": 30.0, "max_modes": 100000},
 "configs": [{"name": "tiny", "batch": 4, "hidden": 8, "eval_batch": 8,
              "modes": 8, "layers": [784, 8, 8, 10]}],
 "artifacts": [{"entry": "fwd_train", "config": "tiny", "file": "fwd.hlo.txt",
                "inputs": [{"name": "w1", "shape": [784, 8]}],
                "outputs": [{"name": "h1"}]}]
}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let mut f = std::fs::File::create(dir.join("fwd.hlo.txt")).unwrap();
        f.write_all(b"HloModule placeholder").unwrap();
    }

    #[test]
    fn loads_and_queries() {
        let dir = std::env::temp_dir().join("litl_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.err_dim, 10);
        assert_eq!(m.opu.frame_rate_hz, 1500.0);
        assert_eq!(m.config("tiny").unwrap().hidden, 8);
        let sig = m.artifact("fwd_train", "tiny").unwrap();
        assert_eq!(sig.inputs[0].1, vec![784, 8]);
        assert!(m.config("nope").is_err());
        assert!(m.artifact("fwd_train", "nope").is_err());
    }

    #[test]
    fn missing_file_is_detected() {
        let dir = std::env::temp_dir().join("litl_manifest_test2");
        write_fixture(&dir);
        std::fs::remove_file(dir.join("fwd.hlo.txt")).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("artifact file missing"), "{err}");
    }
}
