//! PJRT execution engine: compile-once, shape-checked calls.
//!
//! The [`Engine`] owns the PJRT CPU client and a cache of compiled
//! executables keyed by (entry, config).  A call takes host tensors,
//! verifies every shape against the manifest signature, uploads literals,
//! executes, and decomposes the result tuple back to host tensors.
//!
//! [`Model`] wraps the paper's state layout (6 params + 6+6 Adam moments)
//! and exposes the typed step/eval entry points the coordinator uses.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactSig, Manifest};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// PJRT client + compiled-executable cache over a manifest.
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    cache: HashMap<(String, String), PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU engine over `artifacts_dir`.
    pub fn new(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) one artifact.
    pub fn prepare(&mut self, entry: &str, config: &str) -> Result<()> {
        let key = (entry.to_string(), config.to_string());
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let sig = self.manifest.artifact(entry, config)?;
        let path = self.manifest.artifact_path(sig);
        let t0 = std::time::Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {entry}/{config}"))?;
        log::info!(
            "compiled {entry}/{config} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        self.cache.insert(key, exe);
        Ok(())
    }

    /// Execute an artifact on host tensors, shape-checked against the
    /// manifest; returns one host tensor per declared output.
    pub fn call(&mut self, entry: &str, config: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.prepare(entry, config)?;
        let sig = self.manifest.artifact(entry, config)?.clone();
        check_args(&sig, args)?;

        // NOTE: upstream xla 0.1.6's `execute` leaked one device copy of
        // every input per call (xla_rs.cc created the input buffers and
        // never freed them — MBs per training step at the paper config).
        // Fixed in our vendored copy (vendor/xla/xla_rs/xla_rs.cc, grep
        // "litl patch"); `rust/tests/e2e_train.rs::no_leak_across_steps`
        // guards the fix.
        let literals: Vec<Literal> = args
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;
        let exe = self
            .cache
            .get(&(entry.to_string(), config.to_string()))
            .expect("prepared above");
        let result = exe
            .execute::<Literal>(&literals)
            .with_context(|| format!("executing {entry}/{config}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple
            .to_tuple()
            .context("decomposing result tuple")?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "{entry}/{config}: got {} outputs, manifest says {}",
                parts.len(),
                sig.outputs.len()
            );
        }
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }

    /// Number of compiled executables held.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

fn check_args(sig: &ArtifactSig, args: &[&Tensor]) -> Result<()> {
    if args.len() != sig.inputs.len() {
        bail!(
            "{}: got {} args, signature has {}",
            sig.entry,
            args.len(),
            sig.inputs.len()
        );
    }
    for (i, ((name, shape), t)) in sig.inputs.iter().zip(args).enumerate() {
        if t.shape() != shape.as_slice() {
            bail!(
                "{} arg {i} ('{name}'): shape {:?}, signature wants {:?}",
                sig.entry,
                t.shape(),
                shape
            );
        }
    }
    Ok(())
}

/// Host tensor → PJRT literal (f32, row-major).
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let lit = Literal::vec1(t.data());
    if t.shape().is_empty() {
        // 0-d scalar: reshape to [].
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// PJRT literal → host tensor.
pub fn literal_to_tensor(l: &Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

/// The paper's model state: 6 parameter tensors + Adam moments, plus the
/// fixed projection matrices (derived from the optical medium), bound to
/// one build config of an [`Engine`].
pub struct Model {
    pub config: String,
    pub layers: Vec<usize>,
    pub batch: usize,
    pub eval_batch: usize,
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub t: f32,
}

impl Model {
    /// He-style init matching `python/compile/model.py::init_params`.
    pub fn init(engine: &Engine, config: &str, seed: u64) -> Result<Model> {
        let cfg = engine.manifest().config(config)?;
        let mut rng = Pcg64::new(seed, 0x1417);
        let mut params = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        for w in cfg.layers.windows(2) {
            let (d_in, d_out) = (w[0], w[1]);
            let scale = 1.0 / (d_in as f32).sqrt();
            params.push(Tensor::randn(&[d_in, d_out], &mut rng, scale));
            params.push(Tensor::zeros(&[d_out]));
            m.push(Tensor::zeros(&[d_in, d_out]));
            m.push(Tensor::zeros(&[d_out]));
            v.push(Tensor::zeros(&[d_in, d_out]));
            v.push(Tensor::zeros(&[d_out]));
        }
        Ok(Model {
            config: cfg.name.clone(),
            layers: cfg.layers.clone(),
            batch: cfg.batch,
            eval_batch: cfg.eval_batch,
            params,
            m,
            v,
            t: 0.0,
        })
    }

    /// Total parameter count (the paper's ~1.87M at hidden=1024).
    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Full state vector in artifact order: params ++ m ++ v.
    pub fn state_refs(&self) -> Vec<&Tensor> {
        self.params.iter().chain(&self.m).chain(&self.v).collect()
    }

    /// Replace state from artifact outputs (params' ++ m' ++ v').
    pub fn update_state(&mut self, mut outs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        if outs.len() < 18 {
            bail!("state update needs >= 18 outputs, got {}", outs.len());
        }
        let rest = outs.split_off(18);
        let mut it = outs.into_iter();
        for slot in self
            .params
            .iter_mut()
            .chain(self.m.iter_mut())
            .chain(self.v.iter_mut())
        {
            *slot = it.next().unwrap();
        }
        Ok(rest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar(3.5);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back.shape(), &[] as &[usize]);
        assert_eq!(back.data(), &[3.5]);
    }

    #[test]
    fn check_args_catches_mismatches() {
        let sig = ArtifactSig {
            entry: "e".into(),
            config: "c".into(),
            file: "f".into(),
            inputs: vec![("x".into(), vec![2, 3])],
            outputs: vec!["y".into()],
        };
        let good = Tensor::zeros(&[2, 3]);
        let bad = Tensor::zeros(&[3, 2]);
        assert!(check_args(&sig, &[&good]).is_ok());
        assert!(check_args(&sig, &[&bad]).is_err());
        assert!(check_args(&sig, &[]).is_err());
    }
}
