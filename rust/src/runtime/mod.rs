//! PJRT runtime: load AOT artifacts, execute them from the hot path.
//!
//! `python/compile/aot.py` lowers the JAX/Pallas stack once to HLO text
//! (+ `manifest.json`); this module is everything rust needs to run it:
//!
//! * [`manifest`] — typed view of the manifest (artifact signatures,
//!   build configs, OPU physical constants).
//! * [`engine`] — PJRT CPU client + compiled-executable cache + shape
//!   checked `call` (and the [`engine::Model`] convenience wrapper for
//!   the paper's parameter/optimizer-state layout).
//!
//! Python never runs here: the interchange is HLO *text* (xla_extension
//! 0.5.1 rejects jax ≥ 0.5 serialized protos — see aot.py docstring).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Model};
pub use manifest::{ArtifactSig, BuildConfig, Manifest};
