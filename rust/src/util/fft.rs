//! Iterative radix-2 complex FFT.
//!
//! Used by the rust-native off-axis holography demodulator
//! ([`crate::optics::holography::demod_fft`]) — the textbook Fourier
//! side-band pipeline that cross-validates the exact quadrature
//! demodulator on the hot path.  Sizes are powers of two (the camera line
//! is `4 × modes` pixels with `modes` a power of two in every config).

use std::f64::consts::PI;

/// A complex number as an (re, im) pair of f64.
pub type C64 = (f64, f64);

#[inline]
fn c_mul(a: C64, b: C64) -> C64 {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

/// In-place decimation-in-time radix-2 FFT.  `data.len()` must be a power
/// of two.  `inverse` applies the conjugate transform *without* the 1/N
/// normalization (callers normalize — see [`ifft`]).
pub fn fft_in_place(data: &mut [C64], inverse: bool) {
    let n = data.len();
    assert!(n.is_power_of_two(), "fft size {n} not a power of two");
    if n <= 1 {
        return;
    }

    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            data.swap(i, j);
        }
    }

    // Butterflies.
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = (1.0, 0.0);
            for k in 0..len / 2 {
                let u = data[i + k];
                let v = c_mul(data[i + k + len / 2], w);
                data[i + k] = (u.0 + v.0, u.1 + v.1);
                data[i + k + len / 2] = (u.0 - v.0, u.1 - v.1);
                w = c_mul(w, wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Forward FFT of a complex vector.
pub fn fft(input: &[C64]) -> Vec<C64> {
    let mut data = input.to_vec();
    fft_in_place(&mut data, false);
    data
}

/// Inverse FFT (normalized by 1/N).
pub fn ifft(input: &[C64]) -> Vec<C64> {
    let mut data = input.to_vec();
    fft_in_place(&mut data, true);
    let inv_n = 1.0 / data.len() as f64;
    for x in data.iter_mut() {
        x.0 *= inv_n;
        x.1 *= inv_n;
    }
    data
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn assert_close(a: &[C64], b: &[C64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x.0 - y.0).abs() < tol && (x.1 - y.1).abs() < tol,
                "{x:?} vs {y:?}"
            );
        }
    }

    #[test]
    fn impulse_is_flat() {
        let mut input = vec![(0.0, 0.0); 8];
        input[0] = (1.0, 0.0);
        let out = fft(&input);
        assert_close(&out, &vec![(1.0, 0.0); 8], 1e-12);
    }

    #[test]
    fn pure_tone_is_single_bin() {
        let n = 64;
        let k = 5;
        let input: Vec<C64> = (0..n)
            .map(|p| {
                let ph = 2.0 * PI * k as f64 * p as f64 / n as f64;
                (ph.cos(), ph.sin())
            })
            .collect();
        let out = fft(&input);
        for (bin, v) in out.iter().enumerate() {
            let mag = (v.0 * v.0 + v.1 * v.1).sqrt();
            if bin == k {
                assert!((mag - n as f64).abs() < 1e-9);
            } else {
                assert!(mag < 1e-9, "leakage at bin {bin}: {mag}");
            }
        }
    }

    #[test]
    fn roundtrip_identity() {
        let mut rng = Pcg64::seeded(11);
        for log_n in [0, 1, 4, 10] {
            let n = 1usize << log_n;
            let input: Vec<C64> = (0..n)
                .map(|_| (rng.next_normal(), rng.next_normal()))
                .collect();
            let back = ifft(&fft(&input));
            assert_close(&back, &input, 1e-9);
        }
    }

    #[test]
    fn linearity() {
        let mut rng = Pcg64::seeded(12);
        let n = 32;
        let a: Vec<C64> = (0..n).map(|_| (rng.next_normal(), 0.0)).collect();
        let b: Vec<C64> = (0..n).map(|_| (rng.next_normal(), 0.0)).collect();
        let sum: Vec<C64> = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x.0 + y.0, x.1 + y.1))
            .collect();
        let fa = fft(&a);
        let fb = fft(&b);
        let fsum = fft(&sum);
        let expect: Vec<C64> = fa
            .iter()
            .zip(&fb)
            .map(|(x, y)| (x.0 + y.0, x.1 + y.1))
            .collect();
        assert_close(&fsum, &expect, 1e-9);
    }

    #[test]
    fn parseval() {
        let mut rng = Pcg64::seeded(13);
        let n = 256;
        let input: Vec<C64> = (0..n)
            .map(|_| (rng.next_normal(), rng.next_normal()))
            .collect();
        let out = fft(&input);
        let e_time: f64 = input.iter().map(|x| x.0 * x.0 + x.1 * x.1).sum();
        let e_freq: f64 =
            out.iter().map(|x| x.0 * x.0 + x.1 * x.1).sum::<f64>() / n as f64;
        assert!((e_time - e_freq).abs() < 1e-6 * e_time);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        let mut data = vec![(0.0, 0.0); 12];
        fft_in_place(&mut data, false);
    }
}
