//! Minimal JSON parser and writer.
//!
//! Parses `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and serializes metrics/experiment records.  Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (not needed for
//! our machine-generated inputs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(usize, char),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(at) => write!(f, "unexpected end of input at byte {at}"),
            JsonError::Unexpected(at, c) => {
                write!(f, "unexpected character '{c}' at byte {at}")
            }
            JsonError::BadNumber(at) => write!(f, "invalid number at byte {at}"),
            JsonError::BadEscape(at) => write!(f, "invalid escape at byte {at}"),
            JsonError::Trailing(at) => write!(f, "trailing garbage at byte {at}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(JsonError::Trailing(pos));
        }
        Ok(value)
    }

    // -- typed accessors (ergonomics for manifest reading) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out);
        out
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(JsonError::Eof(*pos)),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, JsonError> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(JsonError::Unexpected(*pos, b[*pos] as char))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or(JsonError::BadNumber(start))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(JsonError::Eof(*pos)),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                let esc = *b.get(*pos).ok_or(JsonError::Eof(*pos))?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            return Err(JsonError::Eof(*pos));
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|_| JsonError::BadEscape(*pos))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError::BadEscape(*pos))?;
                        *pos += 4;
                        out.push(
                            char::from_u32(cp).ok_or(JsonError::BadEscape(*pos))?,
                        );
                    }
                    _ => return Err(JsonError::BadEscape(*pos)),
                }
            }
            Some(&c) => {
                // Consume one UTF-8 scalar (manifest strings are ASCII,
                // but be correct anyway).
                let len = match c {
                    0x00..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                let s = std::str::from_utf8(&b[*pos..*pos + len])
                    .map_err(|_| JsonError::Unexpected(*pos, c as char))?;
                out.push_str(s);
                *pos += len;
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            Some(&c) => return Err(JsonError::Unexpected(*pos, c as char)),
            None => return Err(JsonError::Eof(*pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            return Err(JsonError::Unexpected(
                *pos,
                b.get(*pos).map(|&c| c as char).unwrap_or('\0'),
            ));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(JsonError::Unexpected(
                *pos,
                b.get(*pos).map(|&c| c as char).unwrap_or('\0'),
            ));
        }
        *pos += 1;
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            Some(&c) => return Err(JsonError::Unexpected(*pos, c as char)),
            None => return Err(JsonError::Eof(*pos)),
        }
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builder for writing records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let text = r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}} "#;
        let v = Json::parse(text).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().get("e"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,"x"],"flag":false,"n":null,"s":"a\"b"}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".to_string())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(
            Json::parse("\"héllo\"").unwrap(),
            Json::Str("héllo".to_string())
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
 "version": 1,
 "opu": {"carrier": 1.5707963267948966, "amp": 16.0},
 "artifacts": [{"entry": "fwd_train", "inputs": [{"name": "w1", "shape": [784, 1024]}]}]
}"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("entry").unwrap().as_str(), Some("fwd_train"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(784));
    }
}
