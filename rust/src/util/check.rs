//! Mini property-testing framework (no proptest offline).
//!
//! Provides seeded generators and a `forall` runner with simple halving
//! shrinking for numeric/vector inputs.  Coordinator invariants (frame
//! packing, batching, routing, checkpoint round-trips) are expressed as
//! properties over these generators — see the `#[cfg(test)]` blocks
//! across `coordinator/` and `rust/tests/`.

use crate::util::rng::Pcg64;

/// Number of random cases per property (override with LITL_CHECK_CASES).
pub fn default_cases() -> usize {
    std::env::var("LITL_CHECK_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// A generator of values of type `T` from a PRNG.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg64) -> T;

    /// Candidate smaller versions of a failing input (default: none).
    fn shrink(&self, _value: &T) -> Vec<T> {
        Vec::new()
    }
}

/// Run `prop` on `cases` random inputs; on failure, greedily shrink and
/// panic with the smallest failing input found.
pub fn forall<T: std::fmt::Debug + Clone, G: Gen<T>>(
    name: &str,
    gen: &G,
    prop: impl Fn(&T) -> bool,
) {
    let cases = default_cases();
    let mut rng = Pcg64::new(0x11f1, name.len() as u64);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink greedily.
        let mut smallest = input.clone();
        let mut budget = 200;
        'outer: while budget > 0 {
            for cand in gen.shrink(&smallest) {
                budget -= 1;
                if !prop(&cand) {
                    smallest = cand;
                    continue 'outer;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed on case {case}\n  original: {input:?}\n  shrunk:   {smallest:?}"
        );
    }
}

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen<usize> for UsizeIn {
    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.next_below((self.1 - self.0 + 1) as u64) as usize
    }

    fn shrink(&self, value: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *value > self.0 {
            out.push(self.0);
            out.push(self.0 + (*value - self.0) / 2);
            out.push(value - 1);
        }
        out.dedup();
        out
    }
}

/// f32 vector of a length drawn from `len`, values normal * scale.
pub struct VecF32 {
    pub len: UsizeIn,
    pub scale: f32,
}

impl Gen<Vec<f32>> for VecF32 {
    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let n = self.len.generate(rng);
        (0..n).map(|_| rng.next_normal_f32() * self.scale).collect()
    }

    fn shrink(&self, value: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if value.len() > self.len.0 {
            out.push(value[..value.len() / 2.max(self.len.0)].to_vec());
            out.push(value[..value.len() - 1].to_vec());
        }
        // Zeroing values often shrinks counterexamples.
        if value.iter().any(|&x| x != 0.0) {
            out.push(value.iter().map(|_| 0.0).collect());
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairG<A, B>(pub A, pub B);

impl<T1: Clone, T2: Clone, A: Gen<T1>, B: Gen<T2>> Gen<(T1, T2)> for PairG<A, B> {
    fn generate(&self, rng: &mut Pcg64) -> (T1, T2) {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, value: &(T1, T2)) -> Vec<(T1, T2)> {
        let mut out: Vec<(T1, T2)> = self
            .0
            .shrink(&value.0)
            .into_iter()
            .map(|a| (a, value.1.clone()))
            .collect();
        out.extend(
            self.1
                .shrink(&value.1)
                .into_iter()
                .map(|b| (value.0.clone(), b)),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("usize in range", &UsizeIn(3, 17), |&n| (3..=17).contains(&n));
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics() {
        forall("always false", &UsizeIn(0, 100), |_| false);
    }

    #[test]
    fn shrinking_reaches_small_case() {
        // Property fails for n >= 10; shrinker should find something
        // close to 10, certainly < 50.
        let result = std::panic::catch_unwind(|| {
            forall("ge ten", &UsizeIn(0, 1000), |&n| n < 10);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        let shrunk: usize = msg
            .split("shrunk:")
            .nth(1)
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!(shrunk < 50, "shrunk to {shrunk}; msg: {msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        forall(
            "vec len",
            &VecF32 {
                len: UsizeIn(1, 9),
                scale: 2.0,
            },
            |v| (1..=9).contains(&v.len()),
        );
    }
}
