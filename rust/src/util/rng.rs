//! PCG64 pseudo-random number generator + sampling helpers.
//!
//! The coordinator owns every random draw in the system (medium sampling,
//! camera noise, data shuffling, weight init) so that a run is exactly
//! reproducible from its seed.  PCG-XSL-RR 128/64 (O'Neill 2014) gives a
//! fast, well-distributed generator in ~20 lines with no dependencies.
//!
//! Since PR 6 the Box–Muller transcendentals (`ln`, `sin_cos`) are the
//! crate-owned kernels of [`crate::util::mathk`] rather than host-libm
//! calls, in **both** the scalar walk ([`Pcg64::next_normal`], hence
//! the [`Pcg64::fill_normal_scalar`] oracle) and the lane kernel — so
//! the pinned scalar==lane bitwise contract holds by construction, the
//! lane loops vectorize (no opaque libm calls in the hot path), and
//! normal draws became platform-independent: the same seed gives the
//! same transmission-matrix bits on every host, not just every host
//! sharing a libm build.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second Box–Muller output (perf: the camera-noise path
    /// draws millions of normals per step; pairing halves the ln/sqrt).
    normal_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

/// Box–Muller pairs per batch of the lane kernel (see
/// [`Pcg64::fill_normal`]): uniforms land in fixed-width stack arrays
/// and each transcendental (`ln`, `sqrt`, `sin_cos`) runs as its own
/// tight loop over a lane.  Since PR 6 the `ln`/`sin_cos` bodies are
/// the inlinable polynomial kernels of [`crate::util::mathk`] — pure
/// `+ − × ÷` arithmetic with no opaque libm calls — so the compiler
/// can vectorize the *whole* loop, not just the glue around a call.
/// 16 pairs = 32 normals = a few hundred bytes of stack scratch.
pub const NORMAL_LANE: usize = 16;

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value; `stream` decorrelates
    /// generators sharing a seed (e.g. per-worker noise streams).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            normal_spare: None,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Jump the generator forward by `delta` [`Pcg64::next_u64`] steps in
    /// O(log delta) (Brown's LCG jump-ahead: compose `state ← M·state + inc`
    /// with itself by repeated squaring).  This is what makes the
    /// transmission medium *counter-addressable*: a streamed tile can seek
    /// to any column of a row stream without generating the prefix.
    ///
    /// Any cached Box–Muller spare is discarded — after a jump the pairing
    /// restarts on the draw the jump landed on.
    pub fn advance(&mut self, mut delta: u128) {
        let mut acc_mult: u128 = 1;
        let mut acc_plus: u128 = 0;
        let mut cur_mult = PCG_MULT;
        let mut cur_plus = self.inc;
        while delta > 0 {
            if delta & 1 == 1 {
                acc_mult = acc_mult.wrapping_mul(cur_mult);
                acc_plus = acc_plus.wrapping_mul(cur_mult).wrapping_add(cur_plus);
            }
            cur_plus = cur_mult.wrapping_add(1).wrapping_mul(cur_plus);
            cur_mult = cur_mult.wrapping_mul(cur_mult);
            delta >>= 1;
        }
        self.state = acc_mult.wrapping_mul(self.state).wrapping_add(acc_plus);
        self.normal_spare = None;
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire's unbiased method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let wide = (x as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via paired Box–Muller: each (u, v) draw yields two
    /// independent normals (cos and sin quadratures); the spare is cached
    /// (perf iteration #3 — the camera model draws 2 normals per pixel).
    #[inline]
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.normal_spare.take() {
            return z;
        }
        // Avoid u == 0 (log of zero).
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.next_f64();
        let r = (-2.0 * crate::util::mathk::ln_kern(u)).sqrt();
        let (sin, cos) = crate::util::mathk::sin_cos_kern(2.0 * std::f64::consts::PI * v);
        self.normal_spare = Some(r * sin);
        r * cos
    }

    #[inline]
    pub fn next_normal_f32(&mut self) -> f32 {
        self.next_normal() as f32
    }

    /// One batch of the lane kernel: `2 * NORMAL_LANE` standard normals
    /// in draw order (cos, sin, cos, sin, …), **bitwise identical** to
    /// `2 * NORMAL_LANE` successive [`Pcg64::next_normal`] calls from
    /// the same state.  Caller guarantees no spare is cached.
    ///
    /// The uniforms are drawn interleaved (u, v, u, v, …) exactly as the
    /// scalar walk draws them; each transcendental then runs over the
    /// whole lane in its own loop.  Per-pair f64 intermediate rounding
    /// is preserved because every output element's op sequence
    /// (`ln`, `* -2.0`, `sqrt`, `sin_cos`, `*`) is element-independent —
    /// batching changes the loop shape, never a rounding step.  The
    /// scalar path's zero-uniform rejection (p = 2⁻⁵³ per pair) is
    /// preserved by falling back: if any `u` in the lane is rejectable,
    /// the LCG state rewinds and the lane replays through the scalar
    /// walk, rejection loop and all.
    fn normal_lane(&mut self, z: &mut [f64; 2 * NORMAL_LANE]) {
        debug_assert!(self.normal_spare.is_none());
        let saved_state = self.state;
        let mut u = [0.0f64; NORMAL_LANE];
        let mut v = [0.0f64; NORMAL_LANE];
        let mut ok = true;
        for k in 0..NORMAL_LANE {
            u[k] = self.next_f64();
            v[k] = self.next_f64();
            ok &= u[k] > 1e-300;
        }
        if !ok {
            // A rejectable uniform shifts the pair alignment for
            // everything after it: replay the whole lane scalar.
            self.state = saved_state;
            for k in 0..NORMAL_LANE {
                z[2 * k] = self.next_normal();
                z[2 * k + 1] = self.normal_spare.take().expect("pair spare");
            }
            return;
        }
        let mut r = [0.0f64; NORMAL_LANE];
        for (rk, uk) in r.iter_mut().zip(u.iter()) {
            *rk = -2.0 * crate::util::mathk::ln_kern(*uk);
        }
        for rk in r.iter_mut() {
            *rk = rk.sqrt();
        }
        let mut s = [0.0f64; NORMAL_LANE];
        let mut c = [0.0f64; NORMAL_LANE];
        for ((sk, ck), vk) in s.iter_mut().zip(c.iter_mut()).zip(v.iter()) {
            let (si, co) =
                crate::util::mathk::sin_cos_kern(2.0 * std::f64::consts::PI * *vk);
            *sk = si;
            *ck = co;
        }
        for (k, pair) in z.chunks_exact_mut(2).enumerate() {
            pair[0] = r[k] * c[k];
            pair[1] = r[k] * s[k];
        }
    }

    /// Fill a slice with standard-normal f32s via the batched lane
    /// kernel — bitwise identical to [`Pcg64::fill_normal_scalar`] (the
    /// old per-call walk) for every state, including a cached spare on
    /// entry and the spare carried out of an odd-length fill.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        let mut i = 0usize;
        if i < out.len() {
            if let Some(z) = self.normal_spare.take() {
                out[i] = z as f32;
                i += 1;
            }
        }
        let mut z = [0.0f64; 2 * NORMAL_LANE];
        while out.len() - i >= 2 * NORMAL_LANE {
            self.normal_lane(&mut z);
            for (dst, &zz) in out[i..i + 2 * NORMAL_LANE].iter_mut().zip(z.iter()) {
                *dst = zz as f32;
            }
            i += 2 * NORMAL_LANE;
        }
        while i < out.len() {
            out[i] = self.next_normal_f32();
            i += 1;
        }
    }

    /// The pre-batching reference walk: one [`Pcg64::next_normal_f32`]
    /// per element.  Kept as the bitwise oracle for the lane kernel
    /// (pinned in tests) and the baseline the `e6_genkernel` bench
    /// record compares against.
    pub fn fill_normal_scalar(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.next_normal_f32();
        }
    }

    /// Fill two equal-length slices with scaled normals in interleaved
    /// draw order — `re[0], im[0], re[1], im[1], …` — via the lane
    /// kernel: the quadrature-pair primitive behind
    /// `TransmissionMatrix::stream_row_window_into` and
    /// `TransmissionMatrix::sample`.  Bitwise identical to the scalar
    /// walk `re[k] = next_normal_f32() * scale; im[k] = …` for every
    /// entry state (a cached spare shifts the phase by one; the scatter
    /// tracks the logical draw index, so alignment is preserved).
    pub fn fill_normal_quadrature(&mut self, scale: f32, re: &mut [f32], im: &mut [f32]) {
        debug_assert_eq!(re.len(), im.len());
        let total = 2 * re.len();
        let mut w = 0usize;
        if w < total {
            if let Some(z) = self.normal_spare.take() {
                re[0] = (z as f32) * scale;
                w = 1;
            }
        }
        let mut z = [0.0f64; 2 * NORMAL_LANE];
        while total - w >= 2 * NORMAL_LANE {
            self.normal_lane(&mut z);
            for (j, &zz) in z.iter().enumerate() {
                let idx = w + j;
                let val = (zz as f32) * scale;
                if idx % 2 == 0 {
                    re[idx / 2] = val;
                } else {
                    im[idx / 2] = val;
                }
            }
            w += 2 * NORMAL_LANE;
        }
        while w < total {
            let val = self.next_normal_f32() * scale;
            if w % 2 == 0 {
                re[w / 2] = val;
            } else {
                im[w / 2] = val;
            }
            w += 1;
        }
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (stream split).
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::new(seed, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_decorrelate() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seeded(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn advance_matches_sequential_draws() {
        for delta in [0usize, 1, 2, 3, 17, 1000, 4096] {
            let mut seq = Pcg64::new(11, 7);
            for _ in 0..delta {
                seq.next_u64();
            }
            let mut jump = Pcg64::new(11, 7);
            jump.advance(delta as u128);
            for _ in 0..16 {
                assert_eq!(seq.next_u64(), jump.next_u64(), "delta {delta}");
            }
        }
    }

    #[test]
    fn advance_discards_normal_spare() {
        // A cached spare belongs to the pre-jump position; advance(0)
        // must still re-pair from the current raw draw.
        let mut a = Pcg64::new(3, 9);
        let _ = a.next_normal(); // caches the sin spare
        a.advance(0);
        let mut b = Pcg64::new(3, 9);
        b.advance(2); // one Box–Muller pair consumed 2 draws
        assert_eq!(a.next_normal().to_bits(), b.next_normal().to_bits());
    }

    #[test]
    fn advance_composes() {
        let mut a = Pcg64::new(5, 1);
        a.advance(1000);
        a.advance(24);
        let mut b = Pcg64::new(5, 1);
        b.advance(1024);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn batched_fill_is_bitwise_the_scalar_walk() {
        // Lengths straddling every lane boundary, including 0 and odd
        // tails; consecutive calls so the spare carries across fills.
        for seed in [0u64, 1, 42, 0xDEAD_BEEF, u64::MAX] {
            let mut scalar = Pcg64::new(seed, 9);
            let mut batched = Pcg64::new(seed, 9);
            for len in [
                0usize,
                1,
                2,
                3,
                2 * NORMAL_LANE - 1,
                2 * NORMAL_LANE,
                2 * NORMAL_LANE + 1,
                5 * NORMAL_LANE + 3,
                257,
            ] {
                let mut a = vec![0.0f32; len];
                let mut b = vec![0.0f32; len];
                scalar.fill_normal_scalar(&mut a);
                batched.fill_normal(&mut b);
                for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "seed {seed} len {len} elem {i}"
                    );
                }
            }
            // Both generators end in the same state (spare included).
            assert_eq!(
                scalar.next_normal().to_bits(),
                batched.next_normal().to_bits(),
                "post-fill state, seed {seed}"
            );
        }
    }

    #[test]
    fn odd_length_fills_carry_the_spare_across_calls() {
        // An odd fill leaves the sin quadrature cached; the next fill
        // must start from it — in both kernels, identically.
        let mut scalar = Pcg64::new(77, 3);
        let mut batched = Pcg64::new(77, 3);
        for len in [33usize, 1, 2 * NORMAL_LANE + 1, 7] {
            let mut a = vec![0.0f32; len];
            let mut b = vec![0.0f32; len];
            scalar.fill_normal_scalar(&mut a);
            batched.fill_normal(&mut b);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "len {len}"
            );
        }
    }

    #[test]
    fn batched_fill_starts_from_a_cached_spare() {
        let mut scalar = Pcg64::new(5, 11);
        let mut batched = Pcg64::new(5, 11);
        assert_eq!(
            scalar.next_normal().to_bits(),
            batched.next_normal().to_bits()
        );
        // Both now hold the sin spare; fills must begin with it.
        let mut a = vec![0.0f32; 2 * NORMAL_LANE + 2];
        let mut b = vec![0.0f32; 2 * NORMAL_LANE + 2];
        scalar.fill_normal_scalar(&mut a);
        batched.fill_normal(&mut b);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn quadrature_fill_is_bitwise_the_interleaved_walk() {
        let scale = 0.25f32;
        for seed in [3u64, 19, 0x5eed] {
            for pairs in [1usize, 2, NORMAL_LANE - 1, NORMAL_LANE, 40, 97] {
                let mut scalar = Pcg64::new(seed, 4);
                let mut batched = Pcg64::new(seed, 4);
                let (mut ra, mut ia) = (vec![0.0f32; pairs], vec![0.0f32; pairs]);
                for k in 0..pairs {
                    ra[k] = scalar.next_normal_f32() * scale;
                    ia[k] = scalar.next_normal_f32() * scale;
                }
                let (mut rb, mut ib) = (vec![0.0f32; pairs], vec![0.0f32; pairs]);
                batched.fill_normal_quadrature(scale, &mut rb, &mut ib);
                for k in 0..pairs {
                    assert_eq!(ra[k].to_bits(), rb[k].to_bits(), "re {seed}/{pairs}/{k}");
                    assert_eq!(ia[k].to_bits(), ib[k].to_bits(), "im {seed}/{pairs}/{k}");
                }
            }
        }
    }

    #[test]
    fn quadrature_fill_after_advance_seek_at_odd_offsets() {
        // The streamed tile path: seek to pair `col0` via advance (2 raw
        // draws per pair), then fill — the batched kernel must reproduce
        // the scalar walk at every offset parity.
        let scale = std::f32::consts::FRAC_1_SQRT_2;
        for col0 in [0u128, 1, 3, 17, 4095, 4096, 4097] {
            let mut scalar = Pcg64::new(13 ^ 0x5eed, 8);
            scalar.advance(2 * col0);
            let mut batched = Pcg64::new(13 ^ 0x5eed, 8);
            batched.advance(2 * col0);
            let pairs = 2 * NORMAL_LANE + 5;
            let (mut ra, mut ia) = (vec![0.0f32; pairs], vec![0.0f32; pairs]);
            for k in 0..pairs {
                ra[k] = scalar.next_normal_f32() * scale;
                ia[k] = scalar.next_normal_f32() * scale;
            }
            let (mut rb, mut ib) = (vec![0.0f32; pairs], vec![0.0f32; pairs]);
            batched.fill_normal_quadrature(scale, &mut rb, &mut ib);
            assert_eq!(
                ra.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                rb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "re col0 {col0}"
            );
            assert_eq!(
                ia.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                ib.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "im col0 {col0}"
            );
        }
    }

    #[test]
    fn quadrature_fill_with_spare_shifts_phase_like_the_scalar_walk() {
        // A cached spare makes re[0] the spare and shifts every later
        // output by one draw — the scatter must track the phase.
        let scale = 0.5f32;
        let mut scalar = Pcg64::new(31, 2);
        let mut batched = Pcg64::new(31, 2);
        assert_eq!(
            scalar.next_normal().to_bits(),
            batched.next_normal().to_bits()
        );
        let pairs = 3 * NORMAL_LANE;
        let (mut ra, mut ia) = (vec![0.0f32; pairs], vec![0.0f32; pairs]);
        for k in 0..pairs {
            ra[k] = scalar.next_normal_f32() * scale;
            ia[k] = scalar.next_normal_f32() * scale;
        }
        let (mut rb, mut ib) = (vec![0.0f32; pairs], vec![0.0f32; pairs]);
        batched.fill_normal_quadrature(scale, &mut rb, &mut ib);
        assert_eq!(
            ra.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            rb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            ia.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            ib.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn kernel_normals_are_bitwise_scalar_across_a_seed_sweep() {
        // PR-6 edge-case suite: the owned transcendental kernels must
        // keep the lane bitwise-pinned to the scalar oracle across a
        // much wider (seed, stream, offset) sweep than the fixed-seed
        // tests above — every lane draws 16 fresh (u, v) pairs, so the
        // sweep samples the kernels' reduction paths (including
        // near-quadrant-boundary phases, which take the rare Cody–Waite
        // refinement) at production density.  Uniforms are k·2⁻⁵³ with
        // k ≥ 1: subnormal inputs are excluded by construction, so the
        // scan needs no subnormal family.
        let mut meta = Pcg64::seeded(0xED6E);
        for trial in 0..100 {
            let seed = meta.next_u64();
            let stream = meta.next_u64();
            let off = meta.next_below(1 << 20) as u128;
            let mut scalar = Pcg64::new(seed, stream);
            let mut batched = Pcg64::new(seed, stream);
            scalar.advance(2 * off);
            batched.advance(2 * off);
            let mut a = vec![0.0f32; 4 * NORMAL_LANE + 3];
            let mut b = vec![0.0f32; 4 * NORMAL_LANE + 3];
            scalar.fill_normal_scalar(&mut a);
            batched.fill_normal(&mut b);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "trial {trial} elem {i}");
            }
        }
    }

    #[test]
    fn split_children_independent() {
        let mut parent = Pcg64::seeded(7);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
