//! PCG64 pseudo-random number generator + sampling helpers.
//!
//! The coordinator owns every random draw in the system (medium sampling,
//! camera noise, data shuffling, weight init) so that a run is exactly
//! reproducible from its seed.  PCG-XSL-RR 128/64 (O'Neill 2014) gives a
//! fast, well-distributed generator in ~20 lines with no dependencies.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second Box–Muller output (perf: the camera-noise path
    /// draws millions of normals per step; pairing halves the ln/sqrt).
    normal_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value; `stream` decorrelates
    /// generators sharing a seed (e.g. per-worker noise streams).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            normal_spare: None,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Jump the generator forward by `delta` [`Pcg64::next_u64`] steps in
    /// O(log delta) (Brown's LCG jump-ahead: compose `state ← M·state + inc`
    /// with itself by repeated squaring).  This is what makes the
    /// transmission medium *counter-addressable*: a streamed tile can seek
    /// to any column of a row stream without generating the prefix.
    ///
    /// Any cached Box–Muller spare is discarded — after a jump the pairing
    /// restarts on the draw the jump landed on.
    pub fn advance(&mut self, mut delta: u128) {
        let mut acc_mult: u128 = 1;
        let mut acc_plus: u128 = 0;
        let mut cur_mult = PCG_MULT;
        let mut cur_plus = self.inc;
        while delta > 0 {
            if delta & 1 == 1 {
                acc_mult = acc_mult.wrapping_mul(cur_mult);
                acc_plus = acc_plus.wrapping_mul(cur_mult).wrapping_add(cur_plus);
            }
            cur_plus = cur_mult.wrapping_add(1).wrapping_mul(cur_plus);
            cur_mult = cur_mult.wrapping_mul(cur_mult);
            delta >>= 1;
        }
        self.state = acc_mult.wrapping_mul(self.state).wrapping_add(acc_plus);
        self.normal_spare = None;
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire's unbiased method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let wide = (x as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via paired Box–Muller: each (u, v) draw yields two
    /// independent normals (cos and sin quadratures); the spare is cached
    /// (perf iteration #3 — the camera model draws 2 normals per pixel).
    #[inline]
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.normal_spare.take() {
            return z;
        }
        // Avoid u == 0 (log of zero).
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (sin, cos) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.normal_spare = Some(r * sin);
        r * cos
    }

    #[inline]
    pub fn next_normal_f32(&mut self) -> f32 {
        self.next_normal() as f32
    }

    /// Fill a slice with standard-normal f32s.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.next_normal_f32();
        }
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (stream split).
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::new(seed, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_decorrelate() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seeded(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn advance_matches_sequential_draws() {
        for delta in [0usize, 1, 2, 3, 17, 1000, 4096] {
            let mut seq = Pcg64::new(11, 7);
            for _ in 0..delta {
                seq.next_u64();
            }
            let mut jump = Pcg64::new(11, 7);
            jump.advance(delta as u128);
            for _ in 0..16 {
                assert_eq!(seq.next_u64(), jump.next_u64(), "delta {delta}");
            }
        }
    }

    #[test]
    fn advance_discards_normal_spare() {
        // A cached spare belongs to the pre-jump position; advance(0)
        // must still re-pair from the current raw draw.
        let mut a = Pcg64::new(3, 9);
        let _ = a.next_normal(); // caches the sin spare
        a.advance(0);
        let mut b = Pcg64::new(3, 9);
        b.advance(2); // one Box–Muller pair consumed 2 draws
        assert_eq!(a.next_normal().to_bits(), b.next_normal().to_bits());
    }

    #[test]
    fn advance_composes() {
        let mut a = Pcg64::new(5, 1);
        a.advance(1000);
        a.advance(24);
        let mut b = Pcg64::new(5, 1);
        b.advance(1024);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn split_children_independent() {
        let mut parent = Pcg64::seeded(7);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
