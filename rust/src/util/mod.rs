//! Small, dependency-free substrates used across the crate.
//!
//! The offline build environment vendors only `xla`, `anyhow`,
//! `flate2` and `log` (as in-tree stubs under `rust/vendor/`), so the
//! usual ecosystem crates (`rand`, `serde_json`, `rustfft`, criterion's
//! stats, ...) are reimplemented here at the scale this project needs:
//!
//! * [`rng`] — PCG64 PRNG with normal/shuffle helpers (seeded,
//!   reproducible across hosts; mirrors the python side where shared).
//! * [`mathk`] — crate-owned `ln`/`sin_cos` kernels for the Box–Muller
//!   hot path (platform-independent bits, vectorizable lane loops;
//!   design pre-validated in `python/compile/kernels/boxmuller.py`).
//! * [`fft`] — iterative radix-2 complex FFT (off-axis holography demod).
//! * [`json`] — minimal JSON parser/writer (artifact manifest, metrics).
//! * [`stats`] — Welford accumulators, percentiles, linear regression.
//! * [`logging`] — env-filtered logger for the `log` facade.
//! * [`check`] — mini property-testing framework (generators + shrinking).

pub mod check;
pub mod fft;
pub mod json;
pub mod logging;
pub mod mathk;
pub mod rng;
pub mod stats;

/// Contiguous balanced partition: split `total` items into `parts`
/// widths that differ by at most one, earlier parts taking the
/// remainder.  THE shard-range arithmetic — the dense medium split, the
/// streamed-window split and the service/farm batch-row split all call
/// this one function, which is what makes dense↔streamed farms carve
/// identical shard ranges (a bitwise-parity requirement, pinned in
/// `rust/tests/stream_parity.rs`) and the scheduler agree with the farm.
pub fn balanced_widths(total: usize, parts: usize) -> Vec<usize> {
    debug_assert!(parts >= 1, "need at least one part");
    let base = total / parts;
    let extra = total % parts;
    (0..parts).map(|i| base + usize::from(i < extra)).collect()
}

/// Contiguous *weighted* partition: split `total` items proportionally to
/// `weights` (largest-remainder rounding, ties to earlier parts).  This
/// is the heterogeneous-farm generalization of [`balanced_widths`], and
/// equal weights reduce to it **exactly** — same widths, bit for bit —
/// which is what keeps equal-weight topologies on the legacy schedule
/// (pinned in `rust/tests/topology.rs`).
///
/// Weights must be positive: a zero-weight shard would silently starve,
/// so `Topology::validate` rejects it before the arithmetic ever runs.
pub fn weighted_widths(total: usize, weights: &[u32]) -> Vec<usize> {
    debug_assert!(!weights.is_empty(), "need at least one part");
    debug_assert!(weights.iter().all(|&w| w > 0), "weights must be positive");
    let sum_w: u64 = weights.iter().map(|&w| w as u64).sum();
    // Floor quotas first; hand the leftover items to the largest
    // fractional remainders (earlier index wins ties).  For equal
    // weights every remainder ties, so the leftover lands on the first
    // `total % parts` parts — exactly `balanced_widths`.
    let mut widths: Vec<usize> = Vec::with_capacity(weights.len());
    let mut rems: Vec<(u64, usize)> = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let num = total as u64 * w as u64;
        widths.push((num / sum_w) as usize);
        rems.push((num % sum_w, i));
        assigned += *widths.last().unwrap();
    }
    let mut leftover = total - assigned;
    // Sort by descending remainder, ascending index for ties.
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, i) in rems.iter() {
        if leftover == 0 {
            break;
        }
        widths[i] += 1;
        leftover -= 1;
    }
    debug_assert_eq!(widths.iter().sum::<usize>(), total);
    widths
}

#[cfg(test)]
mod tests {
    use super::{balanced_widths, weighted_widths};

    #[test]
    fn weighted_widths_equal_weights_are_exactly_the_balanced_split() {
        // The bitwise-parity cornerstone: equal weights must reproduce
        // balanced_widths for every (total, parts, weight) — not just
        // sum to the same total.
        for total in 0..120usize {
            for parts in 1..8usize {
                for w in [1u32, 2, 7] {
                    assert_eq!(
                        weighted_widths(total, &vec![w; parts]),
                        balanced_widths(total, parts),
                        "{total}/{parts} @ {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn weighted_widths_are_proportional_and_exact() {
        assert_eq!(weighted_widths(16, &[3, 1]), vec![12, 4]);
        assert_eq!(weighted_widths(40, &[3, 1]), vec![30, 10]);
        assert_eq!(weighted_widths(16, &[2, 2, 1]), vec![7, 6, 3]);
        assert_eq!(weighted_widths(8, &[3, 1]), vec![6, 2]);
        // Leftovers go to the largest remainders, earlier index first;
        // the sum is always exact.
        for (total, ws) in [
            (10usize, vec![1u32, 2, 3]),
            (7, vec![5, 1, 1]),
            (0, vec![4, 2]),
            (3, vec![9, 9, 9, 9]),
        ] {
            let out = weighted_widths(total, &ws);
            assert_eq!(out.len(), ws.len());
            assert_eq!(out.iter().sum::<usize>(), total, "{total} over {ws:?}");
        }
    }

    #[test]
    fn balanced_widths_cover_and_balance() {
        for (total, parts) in [(37usize, 5usize), (10, 4), (3, 7), (0, 3), (8, 1)] {
            let w = balanced_widths(total, parts);
            assert_eq!(w.len(), parts);
            assert_eq!(w.iter().sum::<usize>(), total);
            let (min, max) = (w.iter().min().unwrap(), w.iter().max().unwrap());
            assert!(max - min <= 1, "{total}/{parts}: {w:?}");
            // Earlier parts take the remainder.
            assert!(w.windows(2).all(|p| p[0] >= p[1]));
        }
    }
}
