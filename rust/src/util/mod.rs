//! Small, dependency-free substrates used across the crate.
//!
//! The offline build environment vendors only `xla`, `anyhow`,
//! `flate2` and `log` (as in-tree stubs under `rust/vendor/`), so the
//! usual ecosystem crates (`rand`, `serde_json`, `rustfft`, criterion's
//! stats, ...) are reimplemented here at the scale this project needs:
//!
//! * [`rng`] — PCG64 PRNG with normal/shuffle helpers (seeded,
//!   reproducible across hosts; mirrors the python side where shared).
//! * [`fft`] — iterative radix-2 complex FFT (off-axis holography demod).
//! * [`json`] — minimal JSON parser/writer (artifact manifest, metrics).
//! * [`stats`] — Welford accumulators, percentiles, linear regression.
//! * [`logging`] — env-filtered logger for the `log` facade.
//! * [`check`] — mini property-testing framework (generators + shrinking).

pub mod check;
pub mod fft;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;

/// Contiguous balanced partition: split `total` items into `parts`
/// widths that differ by at most one, earlier parts taking the
/// remainder.  THE shard-range arithmetic — the dense medium split, the
/// streamed-window split and the service/farm batch-row split all call
/// this one function, which is what makes dense↔streamed farms carve
/// identical shard ranges (a bitwise-parity requirement, pinned in
/// `rust/tests/stream_parity.rs`) and the scheduler agree with the farm.
pub fn balanced_widths(total: usize, parts: usize) -> Vec<usize> {
    debug_assert!(parts >= 1, "need at least one part");
    let base = total / parts;
    let extra = total % parts;
    (0..parts).map(|i| base + usize::from(i < extra)).collect()
}

#[cfg(test)]
mod tests {
    use super::balanced_widths;

    #[test]
    fn balanced_widths_cover_and_balance() {
        for (total, parts) in [(37usize, 5usize), (10, 4), (3, 7), (0, 3), (8, 1)] {
            let w = balanced_widths(total, parts);
            assert_eq!(w.len(), parts);
            assert_eq!(w.iter().sum::<usize>(), total);
            let (min, max) = (w.iter().min().unwrap(), w.iter().max().unwrap());
            assert!(max - min <= 1, "{total}/{parts}: {w:?}");
            // Earlier parts take the remainder.
            assert!(w.windows(2).all(|p| p[0] >= p[1]));
        }
    }
}
