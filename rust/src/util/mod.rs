//! Small, dependency-free substrates used across the crate.
//!
//! The offline build environment vendors only `xla`, `anyhow`,
//! `flate2` and `log` (as in-tree stubs under `rust/vendor/`), so the
//! usual ecosystem crates (`rand`, `serde_json`, `rustfft`, criterion's
//! stats, ...) are reimplemented here at the scale this project needs:
//!
//! * [`rng`] — PCG64 PRNG with normal/shuffle helpers (seeded,
//!   reproducible across hosts; mirrors the python side where shared).
//! * [`fft`] — iterative radix-2 complex FFT (off-axis holography demod).
//! * [`json`] — minimal JSON parser/writer (artifact manifest, metrics).
//! * [`stats`] — Welford accumulators, percentiles, linear regression.
//! * [`logging`] — env-filtered logger for the `log` facade.
//! * [`check`] — mini property-testing framework (generators + shrinking).

pub mod check;
pub mod fft;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
