//! Crate-owned transcendental kernels for the Box–Muller hot path:
//! `ln` on the uniform domain and `sin_cos` on `[0, 2π]`.
//!
//! PR 5's lane kernel batched the Box–Muller arithmetic but still
//! called the host libm once per element for `ln` and `sin_cos` —
//! opaque calls the compiler can neither inline nor vectorize, and the
//! profile's largest remaining serial fraction.  These kernels replace
//! them with the classic fdlibm/musl reduction + polynomial designs
//! (freely redistributable, Sun Microsystems), written so that every
//! step is a single IEEE-754 `+ − × ÷`/`sqrt`/bit-cast — **no
//! `mul_add`** (without the `fma` target feature it lowers to a libm
//! call) and no tables — which the compiler can unroll and vectorize
//! across [`rng::NORMAL_LANE`]-wide loops.
//!
//! **Contract.** Deterministic and platform-independent: the same
//! input bits give the same output bits on every host, because no step
//! depends on the build's libm.  This *strengthens* PR 5's determinism
//! story — transmission-matrix bits used to be pinned per-libm-build;
//! now they are pinned per-algorithm.  The crate therefore never
//! asserts kernel == libm *bitwise* (platform libms differ between
//! builds; that contract would be unverifiable), but accuracy is held
//! to ≤ 2 ulp of the host libm in tests, and the scalar/lane walks are
//! pinned bitwise against each other — both route through these same
//! functions, so oracle parity holds by construction.
//!
//! **Domain.** Both kernels assume the Box–Muller input domain and are
//! not general replacements: `ln` takes positive *normal* doubles
//! (uniforms are `k·2⁻⁵³`, `k ≥ 1` — subnormals excluded by
//! construction), `sin_cos` takes `x = 2π·v ∈ [0, 2π]`.
//!
//! **Pre-validation.** The authoring environment has no Rust
//! toolchain, so the design was proven first in a bit-exact Python
//! port (`python/compile/kernels/boxmuller.py`, constants given as
//! IEEE bit patterns in both sources so they can be diffed by eye):
//! ≤ 1 ulp worst case over 400k+ random samples plus dense
//! quadrant-boundary scans, and lane == scalar bitwise throughout
//! (`python/tests/test_boxmuller.py`).
//!
//! [`rng::NORMAL_LANE`]: crate::util::rng::NORMAL_LANE

// fdlibm e_log.c coefficients.
const LN2_HI: f64 = f64::from_bits(0x3FE62E42FEE00000);
const LN2_LO: f64 = f64::from_bits(0x3DEA39EF35793C76);
const LG1: f64 = f64::from_bits(0x3FE5555555555593);
const LG2: f64 = f64::from_bits(0x3FD999999997FA04);
const LG3: f64 = f64::from_bits(0x3FD2492494229359);
const LG4: f64 = f64::from_bits(0x3FCC71C51D8E78AF);
const LG5: f64 = f64::from_bits(0x3FC7466496CB03DE);
const LG6: f64 = f64::from_bits(0x3FC39A09D078C69F);
const LG7: f64 = f64::from_bits(0x3FC2F112DF3E5244);

/// Natural log of a positive *normal* f64 (the Box–Muller uniform
/// domain: no zeros, subnormals, infinities or NaNs — callers uphold
/// this; the uniform `k·2⁻⁵³, k ≥ 1` does by construction).
///
/// fdlibm `e_log` reduction `x = 2ᵏ·(1+f)` with `1+f ∈ [√2/2, √2)`,
/// `s = f/(2+f)`, split even/odd polynomial in `s²` — assembled
/// through the single general formula
/// `dk·ln2_hi − ((hfsq − (s·(hfsq+R) + dk·ln2_lo)) − f)`.  fdlibm
/// special-cases `k == 0` as `f − (hfsq − s·(hfsq+R))`, but that is
/// bit-equal to the general formula at `dk = 0` (IEEE negation
/// symmetry: `round(0 − (A − f)) = −round(A − f) = round(f − A)`), so
/// one branch-free expression serves the whole lane.
#[inline]
pub fn ln_kern(x: f64) -> f64 {
    let bits = x.to_bits();
    let mut hx = (bits >> 32) as u32;
    let lx = bits as u32;
    hx = hx.wrapping_add(0x3FF00000 - 0x3FE6A09E);
    let k = ((hx >> 20) as i32) - 0x3FF;
    hx = (hx & 0x000FFFFF) + 0x3FE6A09E;
    let m = f64::from_bits(((hx as u64) << 32) | lx as u64); // 1+f ∈ [√2/2, √2)
    let f = m - 1.0;
    let s = f / (2.0 + f);
    let dk = k as f64;
    let z = s * s;
    let w = z * z;
    let t1 = w * (LG2 + w * (LG4 + w * LG6));
    let t2 = z * (LG1 + w * (LG3 + w * (LG5 + w * LG7)));
    let r = t2 + t1;
    let hfsq = 0.5 * f * f;
    dk * LN2_HI - ((hfsq - (s * (hfsq + r) + dk * LN2_LO)) - f)
}

// fdlibm __rem_pio2 medium-path constants: π/2 split into 33-bit
// chunks so an integer multiple n ≤ 4 times any chunk stays exact.
const INVPIO2: f64 = f64::from_bits(0x3FE45F306DC9C883);
const PIO2_1: f64 = f64::from_bits(0x3FF921FB54400000);
const PIO2_1T: f64 = f64::from_bits(0x3DD0B4611A626331);
const PIO2_2: f64 = f64::from_bits(0x3DD0B4611A600000);
const PIO2_2T: f64 = f64::from_bits(0x3BA3198A2E037073);
const PIO2_3: f64 = f64::from_bits(0x3BA3198A2E000000);
const PIO2_3T: f64 = f64::from_bits(0x397B839A252049C1);

// musl __sin.c / __cos.c core polynomial coefficients.
const S1: f64 = f64::from_bits(0xBFC5555555555549);
const S2: f64 = f64::from_bits(0x3F8111111110F8A6);
const S3: f64 = f64::from_bits(0xBF2A01A019C161D5);
const S4: f64 = f64::from_bits(0x3EC71DE357B1FE7D);
const S5: f64 = f64::from_bits(0xBE5AE5E68A2B9CEB);
const S6: f64 = f64::from_bits(0x3DE5D93A5ACFD57C);

const C1: f64 = f64::from_bits(0x3FA555555555554C);
const C2: f64 = f64::from_bits(0xBF56C16C16C15177);
const C3: f64 = f64::from_bits(0x3EFA01A019CB1590);
const C4: f64 = f64::from_bits(0xBE927E4F809C52AD);
const C5: f64 = f64::from_bits(0x3E21EE9EBDB4B1C4);
const C6: f64 = f64::from_bits(0xBDA8FAE9BE8838D4);

/// musl `__sin`, tail path (`iy = 1`) unconditionally: `|x| ≤ π/4 +
/// ulp`, `y` the low word of the reduced argument.
#[inline]
fn sin_core(x: f64, y: f64) -> f64 {
    let z = x * x;
    let w = z * z;
    let r = S2 + z * (S3 + z * S4) + z * w * (S5 + z * S6);
    let v = z * x;
    x - ((z * (0.5 * y - v * r) - y) - v * S1)
}

/// musl `__cos` (already branch-free): `|x| ≤ π/4 + ulp`.
#[inline]
fn cos_core(x: f64, y: f64) -> f64 {
    let z = x * x;
    let w = z * z;
    let r = z * (C1 + z * (C2 + z * C3)) + w * w * (C4 + z * (C5 + z * C6));
    let hz = 0.5 * z;
    let w = 1.0 - hz;
    w + (((1.0 - w) - hz) + (z * r - x * y))
}

/// `(sin x, cos x)` for `x ∈ [0, 2π]` — the Box–Muller phase domain
/// (`x = 2π·v`, `v ∈ [0, 1)`).
///
/// Quadrant reduction: `n = round(x·2/π) ∈ {0..4}` via truncation of
/// `x·(2/π) + 0.5` (x is non-negative); the residual `y = x − n·π/2`
/// is carried as a head/tail pair through Cody–Waite subtraction, with
/// fdlibm's cancellation-depth check adding the 2nd/3rd `π/2` term
/// pairs when `x` lands close to a quadrant boundary — so `cos` near
/// its zero crossing keeps ~1 ulp accuracy instead of losing the tail
/// to an 85-bit reduction.  The refinement branches are data-dependent
/// but deterministic (pure functions of the input bits) and rare
/// (~2⁻¹⁶ of the domain); the polynomial cores stay branch-free.
#[inline]
pub fn sin_cos_kern(x: f64) -> (f64, f64) {
    let n = (x * INVPIO2 + 0.5) as i32;
    let fn_ = n as f64;
    let mut r = x - fn_ * PIO2_1; // fn·PIO2_1 exact: 33-bit × 3-bit
    let mut w = fn_ * PIO2_1T; // 1st round good to 85 bits
    let mut y0 = r - w;
    let ex = ((x.to_bits() >> 52) & 0x7FF) as i32;
    let ey = ((y0.to_bits() >> 52) & 0x7FF) as i32;
    if ex - ey > 16 {
        let t = r;
        w = fn_ * PIO2_2;
        r = t - w;
        w = fn_ * PIO2_2T - ((t - r) - w);
        y0 = r - w; // 2nd round good to 118 bits
        let ey = ((y0.to_bits() >> 52) & 0x7FF) as i32;
        if ex - ey > 49 {
            let t = r;
            w = fn_ * PIO2_3;
            r = t - w;
            w = fn_ * PIO2_3T - ((t - r) - w);
            y0 = r - w; // 3rd round: 151 bits, covers every double
        }
    }
    let y1 = (r - y0) - w;
    let s = sin_core(y0, y1);
    let c = cos_core(y0, y1);
    match n & 3 {
        0 => (s, c),
        1 => (c, -s),
        2 => (-s, -c),
        _ => (-c, s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Distance in representable doubles (same-sign finite operands).
    fn ulp_diff(a: f64, b: f64) -> u64 {
        let map = |x: f64| {
            let bits = x.to_bits();
            if bits >> 63 == 1 {
                (1u64 << 63).wrapping_sub(bits & !(1 << 63))
            } else {
                bits.wrapping_add(1 << 63)
            }
        };
        map(a).abs_diff(map(b))
    }

    #[test]
    fn ln_kern_is_within_2_ulp_of_libm_on_the_uniform_domain() {
        let mut rng = crate::util::rng::Pcg64::seeded(0xE6);
        let mut cases: Vec<f64> = (0..2000)
            .map(|_| {
                let k = (rng.next_u64() >> 11).max(1);
                k as f64 * (1.0 / (1u64 << 53) as f64)
            })
            .collect();
        // Edges: extreme uniforms, powers of two (f == 0), the √2/2
        // reduction boundary from both sides.
        cases.extend([
            f64::from_bits(0x3CA0000000000000), // 2⁻⁵³, smallest uniform
            1.0 - f64::EPSILON / 2.0,           // largest uniform
            0.5,
            0.25,
        ]);
        let sqrt_half = std::f64::consts::FRAC_1_SQRT_2;
        for bump in -4i64..=4 {
            cases.push(f64::from_bits((sqrt_half.to_bits() as i64 + bump) as u64));
        }
        for u in cases {
            let d = ulp_diff(ln_kern(u), u.ln());
            assert!(d <= 2, "ln({u:e}): {d} ulp from libm");
        }
    }

    #[test]
    fn sin_cos_kern_is_within_2_ulp_of_libm_including_quadrant_boundaries() {
        let two_pi = 2.0 * std::f64::consts::PI;
        let mut rng = crate::util::rng::Pcg64::seeded(0x51);
        let mut cases: Vec<f64> = (0..2000).map(|_| rng.next_f64()).collect();
        // v near j/4 puts x = 2πv near the quadrant boundaries jπ/2,
        // where the reduction must refine or cos loses its zero
        // crossing — the exhaustive-edge-case family.
        for j in 0..=4u32 {
            let base = j as f64 / 4.0;
            let mut lo = base;
            let mut hi = base;
            for _ in 0..64 {
                lo = next_toward(lo, -1.0);
                hi = next_toward(hi, 2.0);
                if lo >= 0.0 {
                    cases.push(lo);
                }
                if hi < 1.0 {
                    cases.push(hi);
                }
            }
        }
        cases.extend([0.0, f64::from_bits(0x3CA0000000000000), 1.0 - f64::EPSILON / 2.0]);
        for v in cases {
            let x = two_pi * v;
            let (s, c) = sin_cos_kern(x);
            let ds = ulp_diff(s, x.sin());
            let dc = ulp_diff(c, x.cos());
            assert!(ds <= 2 && dc <= 2, "sin_cos(2π·{v:e}): {ds}/{dc} ulp");
            assert!((s * s + c * c - 1.0).abs() < 1e-15, "unit phasor at {v:e}");
        }
    }

    /// `f64::next_after` is unstable; one-ulp step toward `dir`.
    fn next_toward(x: f64, dir: f64) -> f64 {
        if x == dir {
            return x;
        }
        let bits = x.to_bits() as i64;
        let up = (x < dir) == (x >= 0.0);
        let stepped = if x == 0.0 {
            if x < dir {
                1u64
            } else {
                1u64 | (1 << 63)
            }
        } else if up {
            (bits + 1) as u64
        } else {
            (bits - 1) as u64
        };
        f64::from_bits(stepped)
    }

    #[test]
    fn extreme_uniform_radius_is_finite_and_accurate() {
        // The smallest admissible uniform drives the largest Box–Muller
        // radius the kernel ever sees: r = √(−2 ln 2⁻⁵³) ≈ 8.57.
        let u = f64::from_bits(0x3CA0000000000000);
        let r_kern = (-2.0 * ln_kern(u)).sqrt();
        let r_libm = (-2.0 * u.ln()).sqrt();
        assert!(r_kern.is_finite());
        assert!(ulp_diff(r_kern, r_libm) <= 2);
    }
}
