//! Statistics helpers: Welford accumulator, percentiles, histograms.
//!
//! Backbone of the metrics registry and the benchmark harness (we have no
//! criterion offline — `crate::bench` reimplements the robust-timing
//! parts on top of these).

/// Streaming mean/variance (Welford's algorithm) with min/max.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n as f64;
        self.m2 += other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample (linear interpolation, like numpy's default).
/// `q` in [0, 100].  Sorts a copy — fine at metrics scale.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Ordinary least squares fit `y = a + b·x`; returns `(a, b, r²)`.
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { sxy * sxy / (sxx * syy) };
    (a, b, r2)
}

/// Pearson correlation coefficient.
pub fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
    let (_, _, r2) = linreg(xs, ys);
    let (_, b, _) = linreg(xs, ys);
    r2.sqrt() * b.signum()
}

/// Cosine similarity of two vectors (the DFA alignment metric).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set = 32/7
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.var() - all.var()).abs() < 1e-10);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linreg_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-10);
        assert!((b - 2.0).abs() < 1e-10);
        assert!((r2 - 1.0).abs() < 1e-10);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-9);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-9);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-9);
    }
}
