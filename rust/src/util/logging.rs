//! Tiny logger for the `log` facade (env-filtered, stderr).
//!
//! `LITL_LOG=debug litl train ...` — levels: error, warn, info, debug,
//! trace.  Defaults to `info`.

use std::io::Write;
use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INIT: Once = Once::new();

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:9.3}s {lvl} {}] {}",
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the global logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        let filter = match std::env::var("LITL_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            Ok("off") => LevelFilter::Off,
            _ => LevelFilter::Info,
        };
        let logger = Box::new(StderrLogger {
            start: Instant::now(),
        });
        let _ = log::set_boxed_logger(logger);
        log::set_max_level(filter);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
