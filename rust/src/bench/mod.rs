//! Micro-benchmark harness (offline stand-in for criterion).
//!
//! `cargo bench` targets (`rust/benches/e*.rs`, `harness = false`) use
//! [`Bench`] for robust timing: warmup, fixed-duration measurement,
//! outlier-resistant statistics, and aligned table output that mirrors
//! the paper's tables/figures (one bench per experiment id — DESIGN.md
//! §4).

use std::time::{Duration, Instant};

use crate::util::stats::{percentile, Welford};

/// Result of one timed case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s
    }
}

/// Benchmark runner with warmup and a time budget per case.
pub struct Bench {
    warmup: Duration,
    budget: Duration,
    min_iters: u64,
    pub results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick mode for CI/tests (tiny budgets).
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(100),
            min_iters: 3,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, warmup: Duration, budget: Duration) -> Self {
        self.warmup = warmup;
        self.budget = budget;
        self
    }

    /// Time `f` repeatedly; returns and records the measurement.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> Measurement {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples = Vec::new();
        let mut w = Welford::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget || samples.len() < self.min_iters as usize {
            let it = Instant::now();
            f();
            let dt = it.elapsed().as_secs_f64();
            samples.push(dt);
            w.push(dt);
            if samples.len() > 100_000 {
                break;
            }
        }
        let m = Measurement {
            name: name.to_string(),
            iters: w.count(),
            mean_s: w.mean(),
            std_s: w.std(),
            p50_s: percentile(&samples, 50.0),
            min_s: w.min(),
        };
        self.results.push(m.clone());
        m
    }

    /// Print an aligned results table.
    pub fn table(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            "case", "iters", "mean", "p50", "min"
        );
        for m in &self.results {
            println!(
                "{:<44} {:>10} {:>12} {:>12} {:>12}",
                m.name,
                m.iters,
                fmt_s(m.mean_s),
                fmt_s(m.p50_s),
                fmt_s(m.min_s)
            );
        }
    }
}

/// Human-format a duration in seconds.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Human-format a rate.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} /s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::quick();
        let m = b.run("sleep50us", || {
            std::thread::sleep(Duration::from_micros(50));
        });
        assert!(m.iters >= 3);
        assert!(m.mean_s >= 45e-6, "mean: {}", m.mean_s);
        assert!(m.min_s <= m.mean_s + 1e-9);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_s(2.5), "2.500 s");
        assert_eq!(fmt_s(0.0025), "2.500 ms");
        assert!(fmt_s(2.5e-6).contains("µs"));
        assert!(fmt_rate(1.5e3).contains("k/s"));
    }
}
