//! Model-state checkpointing (own binary format; no serde offline).
//!
//! Layout (little-endian):
//! ```text
//! magic   "LITLCKPT"            8 bytes
//! version u32                   = 1
//! step    f32  (Adam t)
//! count   u32  (tensor count)
//! per tensor: ndim u32, dims u32×ndim, data f32×numel
//! crc32   u32 over everything above (flate2's crc)
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"LITLCKPT";
const VERSION: u32 = 1;

/// Serialize tensors + step counter to a writer.
pub fn write_to(w: &mut impl Write, tensors: &[&Tensor], step: f32) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&step.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        buf.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let mut hasher = flate2::Crc::new();
    hasher.update(&buf);
    buf.extend_from_slice(&hasher.sum().to_le_bytes());
    w.write_all(&buf)?;
    Ok(())
}

/// Deserialize tensors + step counter from a reader.
pub fn read_from(r: &mut impl Read) -> Result<(Vec<Tensor>, f32)> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    if buf.len() < 8 + 4 + 4 + 4 + 4 {
        bail!("checkpoint truncated ({} bytes)", buf.len());
    }
    let (body, crc_bytes) = buf.split_at(buf.len() - 4);
    let want_crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let mut hasher = flate2::Crc::new();
    hasher.update(body);
    if hasher.sum() != want_crc {
        bail!("checkpoint CRC mismatch (corrupt file)");
    }

    let mut at = 0usize;
    let take = |at: &mut usize, n: usize| -> Result<&[u8]> {
        if *at + n > body.len() {
            bail!("checkpoint truncated at byte {at}");
        }
        let s = &body[*at..*at + n];
        *at += n;
        Ok(s)
    };
    if take(&mut at, 8)? != MAGIC {
        bail!("not a litl checkpoint (bad magic)");
    }
    let version = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap());
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let step = f32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap());
    let count = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
    if count > 10_000 {
        bail!("implausible tensor count {count}");
    }
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let ndim = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
        if ndim > 8 {
            bail!("implausible rank {ndim}");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize);
        }
        let numel: usize = dims.iter().product();
        let raw = take(&mut at, numel * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        tensors.push(Tensor::from_vec(&dims, data));
    }
    if at != body.len() {
        bail!("trailing bytes in checkpoint");
    }
    Ok((tensors, step))
}

/// Save to a file (atomic via temp + rename).
pub fn save(path: impl AsRef<Path>, tensors: &[&Tensor], step: f32) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        write_to(&mut f, tensors, step)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<(Vec<Tensor>, f32)> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    read_from(&mut f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let a = Tensor::randn(&[3, 4], &mut rng, 1.0);
        let b = Tensor::randn(&[7], &mut rng, 2.0);
        let c = Tensor::scalar(5.5);
        let path = std::env::temp_dir().join("litl_ckpt_test.bin");
        save(&path, &[&a, &b, &c], 42.0).unwrap();
        let (tensors, step) = load(&path).unwrap();
        assert_eq!(step, 42.0);
        assert_eq!(tensors.len(), 3);
        assert_eq!(tensors[0], a);
        assert_eq!(tensors[1], b);
        assert_eq!(tensors[2], c);
    }

    #[test]
    fn corruption_is_detected() {
        let t = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let path = std::env::temp_dir().join("litl_ckpt_corrupt.bin");
        save(&path, &[&t], 1.0).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_is_detected() {
        let t = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let path = std::env::temp_dir().join("litl_ckpt_trunc.bin");
        save(&path, &[&t], 1.0).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 6]).unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("litl_ckpt_garbage.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }
}
