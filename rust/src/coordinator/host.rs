//! Pure-rust reference trainers (no XLA in the loop).
//!
//! Three roles:
//! 1. **Oracle** — the math of `python/compile/model.py` re-derived
//!    independently; cross-checked against the artifacts in
//!    `rust/tests/e2e_train.rs`.
//! 2. **CPU baseline** — the "silicon" comparator for E2/E3 benches.
//! 3. **Async-DFA demonstrator** — the paper's §I motivation is that DFA
//!    breaks backprop's backward lock-step: once `B·e` is back from the
//!    OPU, every layer's update is independent.  [`AsyncDfaTrainer`]
//!    actually runs the per-layer updates on a worker pool.

use std::sync::{Arc, Mutex, PoisonError};

use anyhow::Result;

use crate::exec::pool::ThreadPool;
use crate::tensor::{
    add_row_inplace, col_sum, gate_tanh, matmul, matmul_nt, matmul_tn, softmax,
    tanh_inplace, ternarize, Tensor,
};
use crate::util::rng::Pcg64;

use super::optim::Adam;
use super::projector::Projector;

/// Forward-pass intermediates.
pub struct Fwd {
    pub h1: Tensor,
    pub h2: Tensor,
    pub probs: Tensor,
}

/// The paper's MLP on the host: 784 → H → H → 10, tanh.
#[derive(Clone)]
pub struct HostMlp {
    pub layers: Vec<usize>,
    /// w1, b1, w2, b2, w3, b3 (weights `[fan_in, fan_out]`).
    pub params: Vec<Tensor>,
}

impl HostMlp {
    /// He-style init; matches `Model::init` given the same seed.
    pub fn init(seed: u64, layers: &[usize]) -> Self {
        let mut rng = Pcg64::new(seed, 0x1417);
        let mut params = Vec::new();
        for w in layers.windows(2) {
            let scale = 1.0 / (w[0] as f32).sqrt();
            params.push(Tensor::randn(&[w[0], w[1]], &mut rng, scale));
            params.push(Tensor::zeros(&[w[1]]));
        }
        HostMlp {
            layers: layers.to_vec(),
            params,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Fwd {
        let mut a1 = matmul(x, &self.params[0]);
        add_row_inplace(&mut a1, self.params[1].data());
        tanh_inplace(&mut a1);
        let h1 = a1;
        let mut a2 = matmul(&h1, &self.params[2]);
        add_row_inplace(&mut a2, self.params[3].data());
        tanh_inplace(&mut a2);
        let h2 = a2;
        let mut logits = matmul(&h2, &self.params[4]);
        add_row_inplace(&mut logits, self.params[5].data());
        let probs = softmax(&logits);
        Fwd { h1, h2, probs }
    }

    /// Mean CE loss and per-sample error `e = probs - y`.
    pub fn loss_err(probs: &Tensor, yoh: &Tensor) -> (f32, Tensor) {
        let b = probs.rows();
        let mut e = probs.clone();
        let mut loss = 0.0f64;
        for (ev, &yv) in e.data_mut().iter_mut().zip(yoh.data()) {
            if yv > 0.5 {
                loss -= (ev.max(1e-12) as f64).ln();
            }
            *ev -= yv;
        }
        ((loss / b as f64) as f32, e)
    }

    /// Manual backprop gradients (Eq. 2) in param order.
    pub fn bp_grads(&self, x: &Tensor, yoh: &Tensor) -> (Vec<Tensor>, f32) {
        let fwd = self.forward(x);
        let (loss, e) = Self::loss_err(&fwd.probs, yoh);
        let b = x.rows() as f32;
        let mut d3 = e;
        scale(&mut d3, 1.0 / b);
        let dw3 = matmul_tn_from(&fwd.h2, &d3);
        let db3 = col_sum(&d3);
        let d2 = gate_tanh(&matmul_nt(&d3, &self.params[4]), &fwd.h2);
        let dw2 = matmul_tn_from(&fwd.h1, &d2);
        let db2 = col_sum(&d2);
        let d1 = gate_tanh(&matmul_nt(&d2, &self.params[2]), &fwd.h1);
        let dw1 = matmul_tn_from(x, &d1);
        let db1 = col_sum(&d1);
        (
            vec![
                dw1,
                Tensor::from_vec(&[self.layers[1]], db1),
                dw2,
                Tensor::from_vec(&[self.layers[2]], db2),
                dw3,
                Tensor::from_vec(&[self.layers[3]], db3),
            ],
            loss,
        )
    }

    /// DFA gradients (Eq. 3) given projected errors `p1, p2` ([B, H]).
    pub fn dfa_grads(
        &self,
        x: &Tensor,
        fwd: &Fwd,
        e: &Tensor,
        p1: &Tensor,
        p2: &Tensor,
    ) -> Vec<Tensor> {
        let b = x.rows() as f32;
        let inv_b = 1.0 / b;
        let mut g1 = gate_tanh(p1, &fwd.h1);
        scale(&mut g1, inv_b);
        let mut g2 = gate_tanh(p2, &fwd.h2);
        scale(&mut g2, inv_b);
        let mut d3 = e.clone();
        scale(&mut d3, inv_b);
        vec![
            matmul_tn_from(x, &g1),
            Tensor::from_vec(&[self.layers[1]], col_sum(&g1)),
            matmul_tn_from(&fwd.h1, &g2),
            Tensor::from_vec(&[self.layers[2]], col_sum(&g2)),
            matmul_tn_from(&fwd.h2, &d3),
            Tensor::from_vec(&[self.layers[3]], col_sum(&d3)),
        ]
    }

    /// Top-1 accuracy on a batch.
    pub fn accuracy(&self, x: &Tensor, yoh: &Tensor) -> f32 {
        let fwd = self.forward(x);
        let classes = yoh.cols();
        let mut correct = 0usize;
        for r in 0..x.rows() {
            let row = fwd.probs.row(r);
            let pred = argmax(row);
            let truth = argmax(&yoh.data()[r * classes..(r + 1) * classes]);
            if pred == truth {
                correct += 1;
            }
        }
        correct as f32 / x.rows() as f32
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn scale(t: &mut Tensor, s: f32) {
    crate::tensor::scale_inplace(t, s);
}

/// `aᵀ @ b` without materializing the transpose.
fn matmul_tn_from(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_tn(a, b)
}

/// Which feedback the host trainer uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HostAlgo {
    Bp,
    DfaFloat,
    DfaTernary { theta: f32 },
}

/// Synchronous host trainer over an arbitrary projector.
pub struct HostTrainer {
    pub mlp: HostMlp,
    pub opt: Adam,
    pub algo: HostAlgo,
    projector: Box<dyn Projector>,
}

impl HostTrainer {
    pub fn new(
        seed: u64,
        layers: &[usize],
        lr: f32,
        algo: HostAlgo,
        projector: Box<dyn Projector>,
    ) -> Self {
        let mlp = HostMlp::init(seed, layers);
        let opt = Adam::new(&mlp.params, lr);
        HostTrainer {
            mlp,
            opt,
            algo,
            projector,
        }
    }

    /// One training step; returns the batch loss.
    pub fn step(&mut self, x: &Tensor, yoh: &Tensor) -> Result<f32> {
        match self.algo {
            HostAlgo::Bp => {
                let (grads, loss) = self.mlp.bp_grads(x, yoh);
                self.opt.step(&mut self.mlp.params, &grads);
                Ok(loss)
            }
            HostAlgo::DfaFloat | HostAlgo::DfaTernary { .. } => {
                let fwd = self.mlp.forward(x);
                let (loss, e) = HostMlp::loss_err(&fwd.probs, yoh);
                let feedback = match self.algo {
                    HostAlgo::DfaTernary { theta } => ternarize(&e, theta),
                    _ => e.clone(),
                };
                if self.projector.requires_ternary()
                    && !matches!(self.algo, HostAlgo::DfaTernary { .. })
                {
                    anyhow::bail!(
                        "projector '{}' needs ternary frames; use DfaTernary",
                        self.projector.kind()
                    );
                }
                let (p1, p2) = self.projector.project(&feedback)?;
                let grads = self.mlp.dfa_grads(x, &fwd, &e, &p1, &p2);
                self.opt.step(&mut self.mlp.params, &grads);
                Ok(loss)
            }
        }
    }

    pub fn projector(&self) -> &dyn Projector {
        self.projector.as_ref()
    }

    /// Save model + optimizer state in the coordinator checkpoint format
    /// (params, then Adam `m`, then `v`; step = `opt.t`).  A trainer
    /// restored with [`HostTrainer::load_state`] continues bitwise where
    /// this one stopped — the host-side half of `--resume`.
    pub fn save_state(&self, path: &str) -> Result<()> {
        let tensors: Vec<&Tensor> = self
            .mlp
            .params
            .iter()
            .chain(self.opt.m.iter())
            .chain(self.opt.v.iter())
            .collect();
        super::checkpoint::save(path, &tensors, self.opt.t)
    }

    /// Restore state written by [`HostTrainer::save_state`] into a
    /// trainer of the same architecture.
    pub fn load_state(&mut self, path: &str) -> Result<()> {
        let (tensors, t) = super::checkpoint::load(path)?;
        let want = 3 * self.mlp.params.len();
        anyhow::ensure!(
            tensors.len() == want,
            "checkpoint has {} tensors, expected {want}",
            tensors.len()
        );
        let mut it = tensors.into_iter();
        for slot in self
            .mlp
            .params
            .iter_mut()
            .chain(self.opt.m.iter_mut())
            .chain(self.opt.v.iter_mut())
        {
            let t = it.next().unwrap();
            anyhow::ensure!(
                t.shape() == slot.shape(),
                "checkpoint shape {:?} vs model {:?}",
                t.shape(),
                slot.shape()
            );
            *slot = t;
        }
        self.opt.t = t;
        Ok(())
    }
}

/// Per-layer state for the asynchronous DFA engine.
struct Layer {
    w: Tensor,
    b: Tensor,
    opt: Adam,
}

/// Asynchronous DFA: each layer's (gradient + Adam) update runs as an
/// independent pool job — the structural freedom DFA buys over BP.
///
/// Numerically identical to the synchronous trainer (property-tested):
/// updates within a step are data-independent, so running them in
/// parallel changes nothing but wall-clock.
pub struct AsyncDfaTrainer {
    pub layers: Vec<usize>,
    layer_state: Vec<Arc<Mutex<Layer>>>,
    pool: ThreadPool,
    theta: f32,
    projector: Box<dyn Projector>,
}

impl AsyncDfaTrainer {
    pub fn new(
        seed: u64,
        layers: &[usize],
        lr: f32,
        theta: f32,
        projector: Box<dyn Projector>,
        workers: usize,
    ) -> Self {
        let mlp = HostMlp::init(seed, layers);
        let mut layer_state = Vec::new();
        for i in 0..layers.len() - 1 {
            let w = mlp.params[2 * i].clone();
            let b = mlp.params[2 * i + 1].clone();
            let opt = Adam::new(&[w.clone(), b.clone()], lr);
            layer_state.push(Arc::new(Mutex::new(Layer { w, b, opt })));
        }
        AsyncDfaTrainer {
            layers: layers.to_vec(),
            layer_state,
            pool: ThreadPool::new(workers.max(1), 16),
            theta,
            projector,
        }
    }

    /// Snapshot the parameters into a `HostMlp` (for eval / comparison).
    pub fn snapshot(&self) -> HostMlp {
        let mut params = Vec::new();
        for l in &self.layer_state {
            let l = l.lock().unwrap_or_else(PoisonError::into_inner);
            params.push(l.w.clone());
            params.push(l.b.clone());
        }
        HostMlp {
            layers: self.layers.clone(),
            params,
        }
    }

    /// One step: forward (sequential), project (device), then all three
    /// layer updates dispatched concurrently.
    pub fn step(&mut self, x: &Tensor, yoh: &Tensor) -> Result<f32> {
        let mlp = self.snapshot();
        let fwd = mlp.forward(x);
        let (loss, e) = HostMlp::loss_err(&fwd.probs, yoh);
        let feedback = ternarize(&e, self.theta);
        let (p1, p2) = self.projector.project(&feedback)?;
        let inv_b = 1.0 / x.rows() as f32;

        // Per-layer jobs: (hprev, signal, gate_h or None for the head).
        let jobs: Vec<(Arc<Mutex<Layer>>, Tensor, Tensor, Option<Tensor>)> = vec![
            (
                self.layer_state[0].clone(),
                x.clone(),
                p1,
                Some(fwd.h1.clone()),
            ),
            (
                self.layer_state[1].clone(),
                fwd.h1.clone(),
                p2,
                Some(fwd.h2.clone()),
            ),
            (self.layer_state[2].clone(), fwd.h2.clone(), e, None),
        ];
        for (state, hprev, signal, gate) in jobs {
            self.pool.submit(move || {
                let mut g = match gate {
                    Some(h) => gate_tanh(&signal, &h),
                    None => signal,
                };
                crate::tensor::scale_inplace(&mut g, inv_b);
                let dw = matmul_tn(&hprev, &g);
                let db = Tensor::from_vec(&[g.cols()], col_sum(&g));
                let mut layer = state.lock().unwrap_or_else(PoisonError::into_inner);
                let mut wb = vec![layer.w.clone(), layer.b.clone()];
                layer.opt.step(&mut wb, &[dw, db]);
                layer.b = wb.pop().unwrap();
                layer.w = wb.pop().unwrap();
            });
        }
        self.pool.join();
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::projector::DigitalProjector;
    use crate::optics::medium::TransmissionMatrix;

    const LAYERS: &[usize] = &[20, 16, 16, 10];

    fn task_batch(seed: u64, b: usize) -> (Tensor, Tensor) {
        // Fixed random linear task (same construction as python tests).
        let mut proto_rng = Pcg64::new(1234, 0);
        let proto = Tensor::randn(&[10, 20], &mut proto_rng, 1.0);
        let mut rng = Pcg64::seeded(seed);
        let x = Tensor::randn(&[b, 20], &mut rng, 1.0);
        let scores = matmul(&x, &transpose(&proto));
        let mut yoh = Tensor::zeros(&[b, 10]);
        for r in 0..b {
            let c = argmax(scores.row(r));
            *yoh.at_mut(r, c) = 1.0;
        }
        (x, yoh)
    }

    fn transpose(t: &Tensor) -> Tensor {
        let (m, n) = (t.rows(), t.cols());
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                *out.at_mut(j, i) = t.at(i, j);
            }
        }
        out
    }

    fn digital() -> Box<dyn Projector> {
        Box::new(DigitalProjector::new(TransmissionMatrix::sample(
            99, 10, 16,
        )))
    }

    #[test]
    fn bp_learns_the_task() {
        let mut tr = HostTrainer::new(0, LAYERS, 0.01, HostAlgo::Bp, digital());
        let mut first = 0.0;
        let mut last = 0.0;
        for t in 0..80 {
            let (x, y) = task_batch(100 + t, 64);
            let loss = tr.step(&x, &y).unwrap();
            if t == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < 0.5 * first, "first={first} last={last}");
    }

    #[test]
    fn dfa_float_learns() {
        let mut tr = HostTrainer::new(0, LAYERS, 0.01, HostAlgo::DfaFloat, digital());
        let mut losses = Vec::new();
        for t in 0..80 {
            let (x, y) = task_batch(200 + t, 64);
            losses.push(tr.step(&x, &y).unwrap());
        }
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[75..].iter().sum::<f32>() / 5.0;
        assert!(tail < 0.7 * head, "head={head} tail={tail}");
    }

    #[test]
    fn dfa_ternary_learns() {
        // Ternary feedback is the slowest starter (most wrong-class
        // errors quantize to zero early) — use a longer horizon.
        let mut tr = HostTrainer::new(
            0,
            LAYERS,
            0.01,
            HostAlgo::DfaTernary { theta: 0.1 },
            digital(),
        );
        let mut losses = Vec::new();
        for t in 0..160 {
            let (x, y) = task_batch(300 + t, 64);
            losses.push(tr.step(&x, &y).unwrap());
        }
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[155..].iter().sum::<f32>() / 5.0;
        assert!(tail < 0.8 * head, "head={head} tail={tail}");
    }

    #[test]
    fn bp_grads_match_finite_differences() {
        let mlp = HostMlp::init(3, &[6, 5, 5, 4]);
        let mut rng = Pcg64::seeded(4);
        let x = Tensor::randn(&[3, 6], &mut rng, 1.0);
        let mut yoh = Tensor::zeros(&[3, 4]);
        for r in 0..3 {
            *yoh.at_mut(r, r % 4) = 1.0;
        }
        let (grads, _) = mlp.bp_grads(&x, &yoh);
        // Check a few random weight entries per tensor by central diff.
        let eps = 1e-3f32;
        for (pi, gi) in [(0usize, 0usize), (2, 2), (4, 4)] {
            let mut m = mlp.clone();
            for check in 0..4 {
                let idx = (check * 7 + 3) % m.params[pi].numel();
                let orig = m.params[pi].data()[idx];
                m.params[pi].data_mut()[idx] = orig + eps;
                let (lp, _) = HostMlp::loss_err(&m.forward(&x).probs, &yoh);
                m.params[pi].data_mut()[idx] = orig - eps;
                let (lm, _) = HostMlp::loss_err(&m.forward(&x).probs, &yoh);
                m.params[pi].data_mut()[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[gi].data()[idx];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                    "param {pi} idx {idx}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn async_dfa_equals_sync_dfa() {
        let mut sync_tr = HostTrainer::new(
            5,
            LAYERS,
            0.01,
            HostAlgo::DfaTernary { theta: 0.1 },
            digital(),
        );
        let mut async_tr = AsyncDfaTrainer::new(5, LAYERS, 0.01, 0.1, digital(), 3);
        for t in 0..10 {
            let (x, y) = task_batch(400 + t, 32);
            let l1 = sync_tr.step(&x, &y).unwrap();
            let l2 = async_tr.step(&x, &y).unwrap();
            assert!((l1 - l2).abs() < 1e-5, "step {t}: {l1} vs {l2}");
        }
        let snap = async_tr.snapshot();
        for (a, b) in snap.params.iter().zip(&sync_tr.mlp.params) {
            assert!(a.max_abs_diff(b) < 1e-5);
        }
    }

    #[test]
    fn float_error_rejected_by_ternary_device() {
        let medium = TransmissionMatrix::sample(99, 10, 16);
        let optical = Box::new(super::super::projector::NativeOpticalProjector::new(
            crate::optics::OpuParams::default(),
            medium,
            1,
        ));
        let mut tr = HostTrainer::new(0, LAYERS, 0.01, HostAlgo::DfaFloat, optical);
        let (x, y) = task_batch(1, 8);
        assert!(tr.step(&x, &y).is_err());
    }
}
