//! The projection service: shared projection devices, many clients.
//!
//! Two service shapes live here:
//!
//! * [`ProjectionService`] — the classic *device-agnostic* path: one
//!   dispatcher thread drains the request queue and packs pending
//!   requests into *shared device batches* (dynamic batching, the same
//!   motif as vLLM's router at a different timescale: here the deadline
//!   is the next camera frame).  The device may be a
//!   [`ProjectorFarm`](super::farm::ProjectorFarm), but the service
//!   neither knows nor exploits that: every batch is one opaque device
//!   call.
//! * [`ShardedProjectionService`] — the *shard-aware* path: a frame-slot
//!   scheduler assigns client submissions to concrete
//!   **(shard, frame-slot)** pairs.  Each farm shard gets its own
//!   bounded request lane ([`Lanes`]) and a dedicated worker thread that
//!   owns the shard device, so concurrent clients actually occupy the
//!   farm's devices concurrently instead of serializing behind one
//!   dispatcher.  Small requests coalesce into shared frame sequences;
//!   large ones are carved along the [`Partition`] axis — every shard
//!   images its mode slice of every frame (`modes`), or each shard takes
//!   a contiguous row range of the batch (`batch`).
//!
//! **Determinism contract** (pinned in `rust/tests/service_schedule.rs`):
//! the scheduler is a single thread, so for a fixed submission order the
//! frame packing, the (shard, slot) assignment and each shard's job
//! sequence — hence its noise-stream draws — are all deterministic, and
//! at `shards = 1` the scheduled result is bitwise identical to the
//! device-agnostic path (same greedy packing, same device, and the
//! single-part gather is a pure copy).  For digital shards the scheduled
//! result is bitwise equal to the single-device reference at *any* shard
//! count under either partition; noiseless optics agree to fp/ADC
//! tolerance.
//!
//! Invariants (property-tested below and in `rust/tests/`):
//! * every submitted frame is projected exactly once (no loss, no dup),
//!   including frames still queued when `shutdown` is called — shutdown
//!   drains the central queue into the lanes and the lanes into the
//!   devices before joining the workers;
//! * rows within a request keep their order;
//! * replies are routed to the submitting client only;
//! * a *coalesced* frame sequence never exceeds the configured capacity
//!   (`max_batch`); a single request larger than `max_batch` is never
//!   split — it passes through as its own oversized sequence, identical
//!   in both services;
//! * per-shard slot accounts explain the client-observed totals (modes:
//!   every shard is charged every frame; batch: charges sum to the
//!   submitted rows).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::Partition;
use crate::exec::oneshot;
use crate::exec::queue::{BoundedQueue, Lanes};
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::sim::clock::SimClock;
use crate::tensor::Tensor;

use crate::util::weighted_widths;

use super::farm::{concat_mode_parts, concat_row_parts, ProjectorFarm};
use super::projector::Projector;

/// Metric name for shard-worker device failures in the sharded service.
pub const SHARD_ERRORS: &str = "service_shard_errors";

/// One projection request: a few frames from one client.
struct Request {
    frames: Tensor,
    reply: oneshot::Sender<Result<(Tensor, Tensor), String>>,
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Max frames packed into one device call (SLM sequence depth).
    pub max_batch: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 128,
            queue_depth: 256,
        }
    }
}

/// Handle for submitting projection requests.
#[derive(Clone)]
pub struct ProjectionClient {
    queue: BoundedQueue<Request>,
    d_in: usize,
}

impl ProjectionClient {
    /// Submit frames `[B, d_in]`; returns a future for `(P1, P2)`.
    /// Requests are coalesced up to the service's `max_batch`; a single
    /// request *larger* than `max_batch` is never split — it is
    /// scheduled as its own oversized frame sequence (pinned by
    /// `prop_service_preserves_payloads` in `rust/tests/props.rs`).
    pub fn submit(
        &self,
        frames: Tensor,
    ) -> Result<oneshot::Reply<Result<(Tensor, Tensor), String>>> {
        anyhow::ensure!(
            frames.shape().len() == 2 && frames.cols() == self.d_in,
            "projection frames must be [b, {}], got {:?}",
            self.d_in,
            frames.shape()
        );
        anyhow::ensure!(frames.rows() > 0, "empty projection request");
        let (tx, rx) = oneshot::channel();
        self.queue
            .push(Request { frames, reply: tx })
            .map_err(|_| anyhow::anyhow!("projection service is shut down"))?;
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn project(&self, frames: Tensor) -> Result<(Tensor, Tensor)> {
        let reply = self.submit(frames)?;
        match reply.wait() {
            Some(Ok(pair)) => Ok(pair),
            Some(Err(e)) => anyhow::bail!("device error: {e}"),
            None => anyhow::bail!("projection service dropped the request"),
        }
    }
}

/// [`Projector`] adapter over a [`ProjectionClient`]: lets a trainer
/// (host or XLA) drive its error projections through a *running
/// projection service* — N trainers sharing one device fleet, the
/// Perspectives ensemble scenario.  Frame accounting mirrors the
/// optical frame clock (`rows / frame_rate`); the service's own
/// per-shard counters carry the authoritative slot/energy attribution.
pub struct ClientProjector {
    client: ProjectionClient,
    modes: usize,
    frame_rate_hz: f64,
    power_watts: f64,
    frames: u64,
    requires_ternary: bool,
}

impl ClientProjector {
    /// Adapter over `client` for a fleet exposing `modes` output modes.
    /// Defaults: the paper's 1.5 kHz / 30 W device rates, ternary
    /// frames required (the safe assumption when any shard is optical).
    pub fn new(client: ProjectionClient, modes: usize) -> ClientProjector {
        ClientProjector {
            client,
            modes,
            frame_rate_hz: 1500.0,
            power_watts: 30.0,
            frames: 0,
            requires_ternary: true,
        }
    }

    /// Override the frame clock / power used for this handle's local
    /// `sim_seconds`/`energy_joules` view.
    pub fn with_rates(mut self, frame_rate_hz: f64, power_watts: f64) -> ClientProjector {
        self.frame_rate_hz = frame_rate_hz;
        self.power_watts = power_watts;
        self
    }

    /// Accept float frames (an all-digital fleet has no SLM to please).
    pub fn allow_float(mut self) -> ClientProjector {
        self.requires_ternary = false;
        self
    }
}

impl Projector for ClientProjector {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        let out = self.client.project(frames.clone())?;
        self.frames += frames.rows() as u64;
        Ok(out)
    }

    fn modes(&self) -> usize {
        self.modes
    }

    fn sim_seconds(&self) -> f64 {
        self.frames as f64 / self.frame_rate_hz
    }

    fn energy_joules(&self) -> f64 {
        self.sim_seconds() * self.power_watts
    }

    fn kind(&self) -> &'static str {
        "service-client"
    }

    fn requires_ternary(&self) -> bool {
        self.requires_ternary
    }
}

/// The running service (owns the dispatcher thread and the device).
pub struct ProjectionService {
    queue: BoundedQueue<Request>,
    dispatcher: Option<JoinHandle<()>>,
    d_in: usize,
}

impl ProjectionService {
    /// Start a service over a device.  `d_in` is the frame width.
    pub fn start(
        mut device: Box<dyn Projector + Send>,
        d_in: usize,
        cfg: ServiceConfig,
        metrics: Registry,
    ) -> ProjectionService {
        let queue: BoundedQueue<Request> = BoundedQueue::new(cfg.queue_depth);
        let q2 = queue.clone();
        let frames_ctr = metrics.counter("service_frames");
        let batches_ctr = metrics.counter("service_batches");
        let occupancy = metrics.histogram("service_batch_occupancy");
        let dispatcher = std::thread::Builder::new()
            .name("litl-projection-service".into())
            .spawn(move || {
                pack_loop(&q2, cfg.max_batch, |batch, total| {
                    frames_ctr.add(total as u64);
                    batches_ctr.inc();
                    Self::run_batch(&mut *device, batch, &occupancy);
                    true
                });
            })
            .expect("spawn dispatcher");
        ProjectionService {
            queue,
            dispatcher: Some(dispatcher),
            d_in,
        }
    }

    fn run_batch(
        device: &mut dyn Projector,
        batch: Vec<Request>,
        occupancy: &crate::metrics::Histogram,
    ) {
        let rows: usize = batch.iter().map(|r| r.frames.rows()).sum();
        occupancy.observe(rows as f64);
        let d_in = batch[0].frames.cols();
        let packed = pack_requests(&batch, rows, d_in);
        match device.project(&packed) {
            Ok((p1, p2)) => {
                let modes = device.modes();
                send_replies(batch, &p1, &p2, modes);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in batch {
                    req.reply.send(Err(msg.clone()));
                }
            }
        }
    }

    /// Create a client handle.
    pub fn client(&self) -> ProjectionClient {
        ProjectionClient {
            queue: self.queue.clone(),
            d_in: self.d_in,
        }
    }

    /// Stop accepting requests and join the dispatcher.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ProjectionService {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// Greedy dynamic batching, shared verbatim by the device-agnostic
/// dispatcher and the frame-slot scheduler — the `shards=1`
/// bitwise-parity contract requires the two to pack identically.
/// Blocks for one request, opportunistically coalesces pending ones up
/// to `max_batch` rows (a request that does not fit flushes the current
/// sequence and starts the next; re-queueing would reorder), and calls
/// `flush` for every packed sequence.  Returns when the queue is closed
/// AND drained; `flush` returning false aborts early (shutdown raced a
/// schedule).
fn pack_loop(
    queue: &BoundedQueue<Request>,
    max_batch: usize,
    mut flush: impl FnMut(Vec<Request>, usize) -> bool,
) {
    while let Some(first) = queue.pop() {
        let mut batch: Vec<Request> = vec![first];
        let mut total: usize = batch[0].frames.rows();
        while total < max_batch {
            match queue.try_pop() {
                Some(req) if total + req.frames.rows() <= max_batch => {
                    total += req.frames.rows();
                    batch.push(req);
                }
                Some(req) => {
                    if !flush(batch, total) {
                        return;
                    }
                    batch = vec![req];
                    total = batch[0].frames.rows();
                }
                None => break,
            }
        }
        if !flush(batch, total) {
            return;
        }
    }
}

/// Copy a batch of requests into one contiguous `[total, d_in]` frame
/// sequence, submission order preserved — shared by the dispatcher and
/// the frame-slot scheduler for the same reason as [`pack_loop`].
fn pack_requests(batch: &[Request], total: usize, d_in: usize) -> Tensor {
    let mut packed = Tensor::zeros(&[total, d_in]);
    let mut at = 0usize;
    for req in batch {
        let n = req.frames.rows() * d_in;
        packed.data_mut()[at * d_in..at * d_in + n]
            .copy_from_slice(req.frames.data());
        at += req.frames.rows();
    }
    packed
}

/// Slice a packed frame sequence's projections back out to the
/// submitting clients, preserving request row order.
fn send_replies(batch: Vec<Request>, p1: &Tensor, p2: &Tensor, modes: usize) {
    let mut row = 0usize;
    for req in batch {
        let b = req.frames.rows();
        let take = |src: &Tensor| {
            Tensor::from_vec(
                &[b, modes],
                src.data()[row * modes..(row + b) * modes].to_vec(),
            )
        };
        req.reply.send(Ok((take(p1), take(p2))));
        row += b;
    }
}

/// Scheduling configuration for the shard-aware service.
#[derive(Clone, Copy, Debug)]
pub struct ShardServiceConfig {
    /// Max frames (rows) coalesced into one scheduled frame sequence.
    pub max_batch: usize,
    /// Central submit-queue capacity (client backpressure bound).
    pub queue_depth: usize,
    /// Per-shard lane capacity (scheduler → worker backpressure bound).
    pub lane_depth: usize,
    /// How scheduled frames map onto shards.
    pub partition: Partition,
    /// Frame rate used for scheduler-side per-slot time attribution.
    pub frame_rate_hz: f64,
}

impl Default for ShardServiceConfig {
    fn default() -> Self {
        ShardServiceConfig {
            max_batch: 128,
            queue_depth: 256,
            lane_depth: 8,
            partition: Partition::Modes,
            frame_rate_hz: 1500.0,
        }
    }
}

/// One shard's share of a scheduled frame sequence.  `frames` is shared
/// (`Arc`) because the mode partition sends the *same* packed sequence
/// to every shard — no per-shard deep copies on the scheduler thread.
struct ShardJob {
    frames: Arc<Tensor>,
    /// Index into the frame's part list (== gather position).
    part: usize,
    assembly: Arc<FrameAssembly>,
}

/// Gather state for one scheduled frame sequence: the worker that
/// completes the last pending part assembles the full quadratures and
/// routes the replies.  Assembly order is by part index — fixed at
/// scheduling time — so results do not depend on which shard finishes
/// first.
struct FrameAssembly {
    requests: Mutex<Vec<Request>>,
    #[allow(clippy::type_complexity)]
    parts: Mutex<Vec<Option<Result<(Tensor, Tensor), String>>>>,
    pending: AtomicUsize,
    partition: Partition,
    rows_total: usize,
    modes_total: usize,
    /// Per-part mode counts (modes partition) or row counts (batch).
    part_dims: Vec<usize>,
}

fn complete_part(
    assembly: &Arc<FrameAssembly>,
    part: usize,
    result: Result<(Tensor, Tensor), String>,
) {
    assembly.parts.lock().unwrap()[part] = Some(result);
    if assembly.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        finish_frame(assembly);
    }
}

fn finish_frame(assembly: &FrameAssembly) {
    let parts_raw = std::mem::take(&mut *assembly.parts.lock().unwrap());
    let requests = std::mem::take(&mut *assembly.requests.lock().unwrap());
    let mut parts: Vec<(Tensor, Tensor)> = Vec::with_capacity(parts_raw.len());
    let mut errors: Vec<String> = Vec::new();
    for (i, p) in parts_raw.into_iter().enumerate() {
        match p {
            Some(Ok(pair)) => parts.push(pair),
            Some(Err(e)) => errors.push(format!("shard part {i}: {e}")),
            None => errors.push(format!("shard part {i}: no result")),
        }
    }
    if !errors.is_empty() {
        let msg = errors.join("; ");
        for req in requests {
            req.reply.send(Err(msg.clone()));
        }
        return;
    }
    let (p1, p2) = concat_parts(&parts, assembly);
    send_replies(requests, &p1, &p2, assembly.modes_total);
}

/// Concatenate per-shard quadratures back into the full frame result:
/// along columns for the mode partition, along rows for batch (the same
/// gather the farm uses — one implementation, one contract).
fn concat_parts(
    parts: &[(Tensor, Tensor)],
    assembly: &FrameAssembly,
) -> (Tensor, Tensor) {
    match assembly.partition {
        Partition::Modes => {
            concat_mode_parts(parts, &assembly.part_dims, assembly.rows_total)
        }
        Partition::Batch => {
            concat_row_parts(parts, &assembly.part_dims, assembly.modes_total)
        }
    }
}

/// One shard's worker: owns the device, drains its lane in FIFO order.
/// A panicking device fails the frame (all clients in it see the error)
/// but the worker — and the lane — stay alive, mirroring the farm's
/// panic containment.
struct ShardWorker {
    shard: usize,
    device: Box<dyn Projector + Send>,
    lanes: Lanes<ShardJob>,
    max_batch: usize,
    frames: Counter,
    calls: Counter,
    errors: Counter,
    util: Gauge,
    lane_depth: Gauge,
}

impl ShardWorker {
    fn run(mut self) {
        while let Some(job) = self.lanes.pop(self.shard) {
            self.lane_depth.set(self.lanes.len(self.shard) as f64);
            let rows = job.frames.rows();
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || self.device.project(&job.frames),
            ))
            .unwrap_or_else(|_| Err(anyhow::anyhow!("shard device panicked")))
            .map_err(|e| format!("{e:#}"));
            self.calls.inc();
            match &result {
                Ok(_) => self.frames.add(rows as u64),
                Err(_) => self.errors.inc(),
            }
            // Occupancy utilization: rows actually projected per unit of
            // offered frame-sequence capacity on this shard (clamped to
            // 1.0 — an oversized pass-through request can exceed one
            // sequence's nominal capacity).
            let done = self.frames.get() as f64;
            let offered = (self.calls.get() * self.max_batch as u64) as f64;
            self.util.set(done / offered.max(done).max(1.0));
            complete_part(&job.assembly, job.part, result);
        }
    }
}

/// The frame-slot scheduler: a single thread, so frame packing and
/// (shard, slot) assignment are a pure function of submission order.
struct FrameScheduler {
    cfg: ShardServiceConfig,
    d_in: usize,
    modes_total: usize,
    shard_modes: Vec<usize>,
    /// Relative service weights, shard order: the batch partition
    /// splits a frame's rows proportionally to these
    /// ([`weighted_widths`]); all-equal weights reproduce the
    /// historical even split bit for bit.
    weights: Vec<u32>,
    lanes: Lanes<ShardJob>,
    frames_ctr: Counter,
    batches_ctr: Counter,
    occupancy: Histogram,
    queue_depth: Gauge,
    shard_slots: Vec<Counter>,
    slot_clocks: Vec<SimClock>,
    slot_gauges: Vec<Gauge>,
}

impl FrameScheduler {
    fn run(self, queue: BoundedQueue<Request>) {
        // `pack_loop` is the same greedy coalescing the device-agnostic
        // dispatcher runs — that shared implementation is what makes
        // `shards=1` bitwise-reproduce the classic path.  `pop` drains
        // the queue after close, so everything submitted before
        // shutdown still gets scheduled.
        pack_loop(&queue, self.cfg.max_batch, |batch, total| {
            self.queue_depth.set(queue.len() as f64);
            self.schedule_frame(batch, total).is_ok()
        });
    }

    /// Pack `batch` into one frame sequence, carve it into per-shard
    /// jobs along the partition axis, and enqueue each job on its
    /// shard's lane, charging that shard's slot account at scheduling
    /// time.  `Err` means the lanes closed under us (shutdown raced a
    /// schedule) — the unsent parts' requests get dropped senders, which
    /// clients observe as a dropped request.
    fn schedule_frame(&self, batch: Vec<Request>, total: usize) -> Result<(), ()> {
        self.frames_ctr.add(total as u64);
        self.batches_ctr.inc();
        self.occupancy.observe(total as f64);
        let packed = pack_requests(&batch, total, self.d_in);
        let shards = self.shard_modes.len();
        // (frames, shard) in part order — the gather order.
        let mut jobs: Vec<(Arc<Tensor>, usize)> = Vec::with_capacity(shards);
        let mut part_dims: Vec<usize> = Vec::with_capacity(shards);
        match self.cfg.partition {
            Partition::Modes => {
                // Every shard images every frame: same slot range on
                // each device, coalesced requests share the slots (and
                // the one packed tensor — Arc, not a copy per shard).
                let shared = Arc::new(packed);
                for (shard, &mc) in self.shard_modes.iter().enumerate() {
                    jobs.push((shared.clone(), shard));
                    part_dims.push(mc);
                }
            }
            Partition::Batch => {
                // Contiguous weighted row ranges (the farm's split —
                // equal weights are the historical balanced ranges);
                // shards whose range is empty sit this frame out.
                let mut row0 = 0usize;
                for (shard, &c) in weighted_widths(total, &self.weights).iter().enumerate()
                {
                    if c == 0 {
                        continue;
                    }
                    jobs.push((
                        Arc::new(Tensor::from_vec(
                            &[c, self.d_in],
                            packed.data()[row0 * self.d_in..(row0 + c) * self.d_in]
                                .to_vec(),
                        )),
                        shard,
                    ));
                    part_dims.push(c);
                    row0 += c;
                }
            }
        }
        let n_parts = jobs.len();
        let mut part_slots: Vec<Option<Result<(Tensor, Tensor), String>>> =
            Vec::with_capacity(n_parts);
        part_slots.resize_with(n_parts, || None);
        let assembly = Arc::new(FrameAssembly {
            requests: Mutex::new(batch),
            parts: Mutex::new(part_slots),
            pending: AtomicUsize::new(n_parts),
            partition: self.cfg.partition,
            rows_total: total,
            modes_total: self.modes_total,
            part_dims,
        });
        for (part, (frames, shard)) in jobs.into_iter().enumerate() {
            // The slot range is reserved on the shard's frame sequence
            // at scheduling time, whether or not the device later errors
            // (a failed exposure still occupied the camera).
            let slots = frames.rows() as u64;
            self.shard_slots[shard].add(slots);
            self.slot_clocks[shard].advance_slots(slots, self.cfg.frame_rate_hz);
            self.slot_gauges[shard].set(self.slot_clocks[shard].now_secs());
            let job = ShardJob {
                frames,
                part,
                assembly: assembly.clone(),
            };
            if self.lanes.push(shard, job).is_err() {
                return Err(());
            }
        }
        Ok(())
    }
}

/// The running shard-aware service: scheduler + one worker per shard.
pub struct ShardedProjectionService {
    queue: BoundedQueue<Request>,
    lanes: Lanes<ShardJob>,
    scheduler: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    slot_clocks: Vec<SimClock>,
    d_in: usize,
}

impl ShardedProjectionService {
    /// Start a service over equal-weight shard devices (shard `i` ↔
    /// lane `i`; order is the gather order).  `d_in` is the frame
    /// width.
    pub fn start(
        shards: Vec<Box<dyn Projector + Send>>,
        d_in: usize,
        cfg: ShardServiceConfig,
        metrics: Registry,
    ) -> Result<ShardedProjectionService> {
        let weights = vec![1u32; shards.len()];
        Self::start_weighted(shards, weights, d_in, cfg, metrics)
    }

    /// [`ShardedProjectionService::start`] with per-shard service
    /// weights: under the batch partition the frame-slot scheduler
    /// splits each frame's rows proportionally to `weights` — the
    /// heterogeneous-fleet schedule where a `@3` device takes 3× the
    /// rows of a `@1` one.  Equal weights reproduce [`start`]'s
    /// schedule bit for bit.  Topologies route through here
    /// ([`Topology::build_service`]).
    ///
    /// [`start`]: ShardedProjectionService::start
    /// [`Topology::build_service`]: super::topology::Topology::build_service
    pub fn start_weighted(
        shards: Vec<Box<dyn Projector + Send>>,
        weights: Vec<u32>,
        d_in: usize,
        cfg: ShardServiceConfig,
        metrics: Registry,
    ) -> Result<ShardedProjectionService> {
        anyhow::ensure!(!shards.is_empty(), "service needs at least one shard");
        anyhow::ensure!(
            weights.len() == shards.len(),
            "{} weights for {} shards",
            weights.len(),
            shards.len()
        );
        anyhow::ensure!(
            weights.iter().all(|&w| w >= 1),
            "zero-weight shard in {weights:?} (weights must be >= 1)"
        );
        anyhow::ensure!(
            cfg.max_batch > 0 && cfg.queue_depth > 0 && cfg.lane_depth > 0,
            "service capacities must be positive: {cfg:?}"
        );
        anyhow::ensure!(
            cfg.frame_rate_hz > 0.0,
            "frame_rate_hz must be positive: {cfg:?}"
        );
        let shard_modes: Vec<usize> = shards.iter().map(|s| s.modes()).collect();
        let modes_total = match cfg.partition {
            Partition::Modes => shard_modes.iter().sum(),
            Partition::Batch => {
                anyhow::ensure!(
                    shard_modes.iter().all(|&m| m == shard_modes[0]),
                    "batch-partition shards must expose identical mode \
                     counts, got {shard_modes:?}"
                );
                shard_modes[0]
            }
        };
        let n = shards.len();
        let queue: BoundedQueue<Request> = BoundedQueue::new(cfg.queue_depth);
        let lanes: Lanes<ShardJob> = Lanes::new(n, cfg.lane_depth);
        let slot_clocks: Vec<SimClock> = (0..n).map(|_| SimClock::new()).collect();
        let mut workers = Vec::with_capacity(n);
        for (i, device) in shards.into_iter().enumerate() {
            let worker = ShardWorker {
                shard: i,
                device,
                lanes: lanes.clone(),
                max_batch: cfg.max_batch,
                frames: metrics.counter(&format!("service_shard{i}_frames")),
                calls: metrics.counter(&format!("service_shard{i}_calls")),
                errors: metrics.counter(SHARD_ERRORS),
                util: metrics.gauge(&format!("service_shard{i}_util")),
                lane_depth: metrics.gauge(&format!("service_shard{i}_lane_depth")),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("litl-shard-worker-{i}"))
                    .spawn(move || worker.run())
                    .expect("spawn shard worker"),
            );
        }
        let scheduler = FrameScheduler {
            cfg,
            d_in,
            modes_total,
            shard_modes,
            weights,
            lanes: lanes.clone(),
            frames_ctr: metrics.counter("service_frames"),
            batches_ctr: metrics.counter("service_batches"),
            occupancy: metrics.histogram("service_batch_occupancy"),
            queue_depth: metrics.gauge("service_queue_depth"),
            shard_slots: (0..n)
                .map(|i| metrics.counter(&format!("service_shard{i}_slots")))
                .collect(),
            slot_clocks: slot_clocks.clone(),
            slot_gauges: (0..n)
                .map(|i| metrics.gauge(&format!("service_shard{i}_slot_s")))
                .collect(),
        };
        let q2 = queue.clone();
        let sched_handle = std::thread::Builder::new()
            .name("litl-shard-scheduler".into())
            .spawn(move || scheduler.run(q2))
            .expect("spawn frame scheduler");
        Ok(ShardedProjectionService {
            queue,
            lanes,
            scheduler: Some(sched_handle),
            workers,
            slot_clocks,
            d_in,
        })
    }

    /// Start over a [`ProjectorFarm`], taking ownership of its shard
    /// devices *and its service weights* (so a weighted topology's farm
    /// keeps its row split behind the service).  The farm's partition
    /// must match the scheduler's — a mode-sliced farm cannot serve
    /// batch row ranges.
    pub fn over_farm(
        farm: ProjectorFarm,
        d_in: usize,
        cfg: ShardServiceConfig,
        metrics: Registry,
    ) -> Result<ShardedProjectionService> {
        anyhow::ensure!(
            farm.partition() == cfg.partition,
            "farm partition {:?} != service partition {:?}",
            farm.partition(),
            cfg.partition
        );
        let weights = farm.weights().to_vec();
        Self::start_weighted(farm.into_shards(), weights, d_in, cfg, metrics)
    }

    /// Create a client handle (same submit/project API as the
    /// device-agnostic service).
    pub fn client(&self) -> ProjectionClient {
        ProjectionClient {
            queue: self.queue.clone(),
            d_in: self.d_in,
        }
    }

    /// Per-shard scheduled-slot seconds — the scheduler's timing
    /// attribution (`slots / frame_rate`), independent of each device's
    /// own clock.
    pub fn shard_slot_seconds(&self) -> Vec<f64> {
        self.slot_clocks.iter().map(|c| c.now_secs()).collect()
    }

    fn shutdown_inner(&mut self) {
        // Ordered drain: stop intake, let the scheduler drain the
        // central queue into the lanes, then close the lanes and let
        // each worker drain its lane.  No in-flight work is abandoned.
        self.queue.close();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        self.lanes.close_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Stop accepting requests, drain everything in flight, join all
    /// threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }
}

impl Drop for ShardedProjectionService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::projector::DigitalProjector;
    use crate::coordinator::topology::{DeviceKind, Topology};
    use crate::optics::medium::TransmissionMatrix;
    use crate::optics::stream::Medium;
    use crate::optics::OpuParams;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    fn digital_devices(
        medium: &TransmissionMatrix,
        shards: usize,
        partition: Partition,
    ) -> Vec<Box<dyn Projector + Send>> {
        Topology::homogeneous(DeviceKind::Digital, shards)
            .with_partition(partition)
            .build_devices(OpuParams::default(), &Medium::Dense(medium.clone()), 0)
            .unwrap()
    }

    fn service(modes: usize, max_batch: usize) -> (ProjectionService, TransmissionMatrix) {
        let medium = TransmissionMatrix::sample(11, 10, modes);
        let dev = Box::new(DigitalProjector::new(medium.clone()));
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig {
                max_batch,
                queue_depth: 64,
            },
            Registry::new(),
        );
        (svc, medium)
    }

    fn tern(rows: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * 10)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, 10], data)
    }

    #[test]
    fn single_request_roundtrip() {
        let (svc, medium) = service(16, 32);
        let client = svc.client();
        let e = tern(4, 1);
        let (p1, _) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re));
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_get_their_own_answers() {
        let (svc, medium) = service(8, 16);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let client = svc.client();
                let medium = medium.clone();
                std::thread::spawn(move || {
                    for j in 0..5 {
                        let e = tern(3, 100 + i * 10 + j);
                        let (p1, p2) = client.project(e.clone()).unwrap();
                        assert_eq!(p1, matmul(&e, &medium.b_re), "client {i} req {j}");
                        assert_eq!(p2, matmul(&e, &medium.b_im));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn oversized_request_is_rejected_politely() {
        let (svc, _) = service(8, 16);
        let client = svc.client();
        let bad = Tensor::zeros(&[2, 7]); // wrong width
        assert!(client.submit(bad).is_err());
        let empty = Tensor::zeros(&[0, 10]);
        assert!(client.submit(empty).is_err());
        svc.shutdown();
    }

    #[test]
    fn sharded_oversized_request_passes_through_like_the_classic_path() {
        // A request larger than max_batch is never split: both services
        // schedule it as its own oversized frame sequence (the classic
        // path's behavior is pinned at tier 1 by
        // prop_service_preserves_payloads in rust/tests/props.rs).
        for partition in [Partition::Modes, Partition::Batch] {
            let (svc, medium, _) = sharded(partition, 2, 8, 16);
            let client = svc.client();
            let e = tern(17, 11); // 17 rows > max_batch 16
            let (p1, p2) = client.project(e.clone()).unwrap();
            assert_eq!(p1, matmul(&e, &medium.b_re), "{partition:?}");
            assert_eq!(p2, matmul(&e, &medium.b_im), "{partition:?}");
            svc.shutdown();
        }
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let (svc, _) = service(8, 16);
        let client = svc.client();
        svc.shutdown();
        assert!(client.project(tern(1, 0)).is_err());
    }

    #[test]
    fn device_error_propagates_to_all_in_batch() {
        // Non-ternary frames through an optical device error out.
        let medium = TransmissionMatrix::sample(11, 10, 8);
        let dev = Box::new(super::super::projector::NativeOpticalProjector::new(
            crate::optics::OpuParams::default(),
            medium,
            1,
        ));
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig::default(),
            Registry::new(),
        );
        let client = svc.client();
        let mut bad = tern(2, 3);
        bad.data_mut()[0] = 0.5;
        let err = client.project(bad).unwrap_err().to_string();
        assert!(err.contains("device error"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn sharded_farm_behind_the_service_matches_single_device() {
        // The farm is just another device to the service: dynamic
        // batching in front, mode sharding behind, payloads intact.
        let medium = TransmissionMatrix::sample(11, 10, 24);
        let farm = Box::new(
            Topology::homogeneous(DeviceKind::Digital, 4)
                .build_farm(
                    OpuParams::default(),
                    &Medium::Dense(medium.clone()),
                    0,
                    Registry::new(),
                )
                .unwrap(),
        );
        let svc = ProjectionService::start(
            farm,
            10,
            ServiceConfig {
                max_batch: 32,
                queue_depth: 64,
            },
            Registry::new(),
        );
        let client = svc.client();
        let replies: Vec<_> = (0..6)
            .map(|i| {
                let e = tern(3, 50 + i);
                (e.clone(), client.submit(e).unwrap())
            })
            .collect();
        for (e, reply) in replies {
            let (p1, p2) = reply.wait().unwrap().unwrap();
            assert_eq!(p1, matmul(&e, &medium.b_re));
            assert_eq!(p2, matmul(&e, &medium.b_im));
        }
        svc.shutdown();
    }

    fn sharded(
        partition: Partition,
        shards: usize,
        modes: usize,
        max_batch: usize,
    ) -> (ShardedProjectionService, TransmissionMatrix, Registry) {
        let medium = TransmissionMatrix::sample(19, 10, modes);
        let devices = digital_devices(&medium, shards, partition);
        let reg = Registry::new();
        let svc = ShardedProjectionService::start(
            devices,
            10,
            ShardServiceConfig {
                max_batch,
                queue_depth: 64,
                lane_depth: 4,
                partition,
                frame_rate_hz: 1500.0,
            },
            reg.clone(),
        )
        .unwrap();
        (svc, medium, reg)
    }

    #[test]
    fn sharded_roundtrip_under_both_partitions() {
        for partition in [Partition::Modes, Partition::Batch] {
            let (svc, medium, _) = sharded(partition, 4, 24, 32);
            let client = svc.client();
            let replies: Vec<_> = (0..6)
                .map(|i| {
                    let e = tern(3, 60 + i);
                    (e.clone(), client.submit(e).unwrap())
                })
                .collect();
            for (e, r) in replies {
                let (p1, p2) = r.wait().unwrap().unwrap();
                assert_eq!(p1, matmul(&e, &medium.b_re), "{partition:?}");
                assert_eq!(p2, matmul(&e, &medium.b_im), "{partition:?}");
            }
            svc.shutdown();
        }
    }

    #[test]
    fn batch_partition_slots_sum_to_client_rows() {
        let (svc, _, reg) = sharded(Partition::Batch, 4, 16, 64);
        let client = svc.client();
        let replies: Vec<_> = (0..5)
            .map(|i| client.submit(tern(4, 70 + i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        let slot_s = svc.shard_slot_seconds();
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_frames"], 20.0);
        let slot_sum: f64 = (0..4)
            .map(|i| snap[&format!("service_shard{i}_slots")])
            .sum();
        assert_eq!(slot_sum, 20.0);
        let frame_sum: f64 = (0..4)
            .map(|i| snap[&format!("service_shard{i}_frames")])
            .sum();
        assert_eq!(frame_sum, 20.0);
        // Scheduler-side slot clocks: slots / 1500 Hz, summed over shards.
        let total_slot_s: f64 = slot_s.iter().sum();
        assert!((total_slot_s - 20.0 / 1500.0).abs() < 1e-9);
    }

    #[test]
    fn modes_partition_charges_every_shard_per_frame() {
        let (svc, _, reg) = sharded(Partition::Modes, 3, 24, 64);
        let client = svc.client();
        let replies: Vec<_> = (0..4)
            .map(|i| client.submit(tern(2, 80 + i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_frames"], 8.0);
        for i in 0..3 {
            assert_eq!(snap[&format!("service_shard{i}_slots")], 8.0);
            assert_eq!(snap[&format!("service_shard{i}_frames")], 8.0);
        }
    }

    #[test]
    fn per_shard_metrics_roll_up_without_knowing_the_shard_count() {
        // Direct coverage for Registry::sum_counters/sum_gauges over the
        // service's per-shard names (previously only the soak exercised
        // this composition).
        let (svc, _, reg) = sharded(Partition::Batch, 4, 16, 64);
        let client = svc.client();
        let replies: Vec<_> = (0..5)
            .map(|i| client.submit(tern(4, 90 + i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        let slot_s = svc.shard_slot_seconds();
        svc.shutdown();
        assert_eq!(reg.sum_counters("service_shard", "_slots"), 20.0);
        assert_eq!(reg.sum_counters("service_shard", "_frames"), 20.0);
        // The gauge roll-up reproduces the scheduler's own clock view.
        let gauge_total = reg.sum_gauges("service_shard", "_slot_s");
        let clock_total: f64 = slot_s.iter().sum();
        assert!(
            (gauge_total - clock_total).abs() < 1e-12,
            "gauges {gauge_total} vs clocks {clock_total}"
        );
        assert!((clock_total - 20.0 / 1500.0).abs() < 1e-9);
        // Suffix discipline: _slots must not absorb _slot_s or frames.
        assert!(reg.sum_counters("service_shard", "_calls") > 0.0);
        assert_eq!(reg.sum_counters("service_shard", "_nope"), 0.0);
    }

    #[test]
    fn sharded_shutdown_rejects_new_requests() {
        let (svc, _, _) = sharded(Partition::Modes, 2, 8, 16);
        let client = svc.client();
        svc.shutdown();
        assert!(client.project(tern(1, 0)).is_err());
    }

    #[test]
    fn sharded_device_error_propagates_to_the_frame() {
        let medium = TransmissionMatrix::sample(20, 10, 8);
        let shards: Vec<Box<dyn Projector + Send>> = (0..2)
            .map(|i| {
                Box::new(
                    super::super::projector::NativeOpticalProjector::with_noise_stream(
                        crate::optics::OpuParams::default(),
                        medium.clone(),
                        3,
                        crate::optics::NOISE_STREAM_BASE + i as u64,
                    ),
                ) as Box<dyn Projector + Send>
            })
            .collect();
        let svc = ShardedProjectionService::start(
            shards,
            10,
            ShardServiceConfig {
                partition: Partition::Batch,
                ..Default::default()
            },
            Registry::new(),
        )
        .unwrap();
        let client = svc.client();
        let mut bad = tern(2, 3);
        bad.data_mut()[0] = 0.5; // not ternary: the SLM rejects it
        let err = client.project(bad).unwrap_err().to_string();
        assert!(err.contains("device error"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn over_farm_rejects_partition_mismatch() {
        let medium = TransmissionMatrix::sample(21, 10, 16);
        let farm = Topology::homogeneous(DeviceKind::Digital, 2)
            .build_farm(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                0,
                Registry::new(),
            )
            .unwrap();
        let cfg = ShardServiceConfig {
            partition: Partition::Batch,
            ..Default::default()
        };
        assert!(
            ShardedProjectionService::over_farm(farm, 10, cfg, Registry::new())
                .is_err()
        );
    }

    #[test]
    fn weighted_batch_scheduling_splits_rows_by_weight() {
        // 3:1 weights over two digital replicas: a 16-row frame sequence
        // schedules 12 rows on shard 0 and 4 on shard 1, and the reply
        // is still exactly the single-device projection.
        let medium = TransmissionMatrix::sample(23, 10, 16);
        let devices = digital_devices(&medium, 2, Partition::Batch);
        let reg = Registry::new();
        let svc = ShardedProjectionService::start_weighted(
            devices,
            vec![3, 1],
            10,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 32,
                lane_depth: 4,
                partition: Partition::Batch,
                frame_rate_hz: 1500.0,
            },
            reg.clone(),
        )
        .unwrap();
        let client = svc.client();
        let e = tern(16, 5);
        let (p1, p2) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re));
        assert_eq!(p2, matmul(&e, &medium.b_im));
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_shard0_slots"], 12.0);
        assert_eq!(snap["service_shard1_slots"], 4.0);
        // Zero weights are rejected up front, not silently starved.
        let devices = digital_devices(&medium, 2, Partition::Batch);
        assert!(ShardedProjectionService::start_weighted(
            devices,
            vec![1, 0],
            10,
            ShardServiceConfig::default(),
            Registry::new(),
        )
        .is_err());
    }

    #[test]
    fn metrics_observe_batching() {
        let medium = TransmissionMatrix::sample(11, 10, 8);
        let dev = Box::new(DigitalProjector::new(medium));
        let reg = Registry::new();
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig {
                max_batch: 64,
                queue_depth: 64,
            },
            reg.clone(),
        );
        let client = svc.client();
        // Burst of requests: dispatcher should pack at least some.
        let replies: Vec<_> = (0..10)
            .map(|i| client.submit(tern(4, i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_frames"], 40.0);
        assert!(snap["service_batches"] >= 1.0);
        assert!(snap["service_batches"] <= 10.0);
    }
}
