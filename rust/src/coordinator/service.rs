//! The projection service: shared projection devices, many clients.
//!
//! Two service shapes live here:
//!
//! * [`ProjectionService`] — the classic *device-agnostic* path: one
//!   dispatcher thread drains the request queue and packs pending
//!   requests into *shared device batches* (dynamic batching, the same
//!   motif as vLLM's router at a different timescale: here the deadline
//!   is the next camera frame).  The device may be a
//!   [`ProjectorFarm`](super::farm::ProjectorFarm), but the service
//!   neither knows nor exploits that: every batch is one opaque device
//!   call.
//! * [`ShardedProjectionService`] — the *shard-aware* path: a frame-slot
//!   scheduler assigns client submissions to concrete
//!   **(shard, frame-slot)** pairs.  Each farm shard gets its own
//!   bounded request lane ([`Lanes`]) and a dedicated worker thread that
//!   owns the shard device, so concurrent clients actually occupy the
//!   farm's devices concurrently instead of serializing behind one
//!   dispatcher.  Small requests coalesce into shared frame sequences;
//!   large ones are carved along the [`Partition`] axis — every shard
//!   images its mode slice of every frame (`modes`), or each shard takes
//!   a contiguous row range of the batch (`batch`).
//!
//! **Determinism contract** (pinned in `rust/tests/service_schedule.rs`):
//! the scheduler is a single thread, so for a fixed submission order the
//! frame packing, the (shard, slot) assignment and each shard's job
//! sequence — hence its noise-stream draws — are all deterministic, and
//! at `shards = 1` the scheduled result is bitwise identical to the
//! device-agnostic path (same greedy packing, same device, and the
//! single-part gather is a pure copy).  For digital shards the scheduled
//! result is bitwise equal to the single-device reference at *any* shard
//! count under either partition; noiseless optics agree to fp/ADC
//! tolerance.
//!
//! **Control plane** (every knob off by default — the defaults *are*
//! the pinned deterministic schedule): [`ShardServiceConfig::adapt`]
//! re-plans the batch-partition row weights live from worker-published
//! service-rate EWMAs (`--adapt-weights`);
//! [`ShardServiceConfig::failover`] trips erroring/stalled shards,
//! drains their lanes onto survivors and re-admits them on probation
//! (`--failover`); [`ShardServiceConfig::admission`] applies per-client
//! token-bucket fairness with a bounded wait (`--admit-rate-fps`).
//! Request latency is observed end-to-end in the `service_latency`
//! histogram (`_p50`/`_p95`/`_p99` via `Registry::snapshot`).  Turning
//! any of these on trades bitwise schedule determinism for
//! liveness/fairness — see the per-struct docs for exactly what moves.
//!
//! Invariants (property-tested below and in `rust/tests/`):
//! * every submitted frame is projected exactly once (no loss, no dup),
//!   including frames still queued when `shutdown` is called — shutdown
//!   drains the central queue into the lanes and the lanes into the
//!   devices before joining the workers;
//! * rows within a request keep their order;
//! * replies are routed to the submitting client only;
//! * a *coalesced* frame sequence never exceeds the configured capacity
//!   (`max_batch`); a single request larger than `max_batch` is never
//!   split — it passes through as its own oversized sequence, identical
//!   in both services;
//! * per-shard slot accounts explain the client-observed totals (modes:
//!   every shard is charged every frame; batch: charges sum to the
//!   submitted rows).

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::Partition;
use crate::exec::oneshot;
use crate::exec::queue::{BoundedQueue, Lanes};
use crate::metrics::trace::{self, NO_SHARD};
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::sim::clock::SimClock;
use crate::tensor::Tensor;

use crate::util::weighted_widths;

use super::farm::{concat_mode_parts, concat_row_parts, ProjectorFarm};
use super::projector::Projector;

/// Metric name for shard-worker device failures in the sharded service.
pub const SHARD_ERRORS: &str = "service_shard_errors";

/// One projection request: a few frames from one client.
struct Request {
    frames: Tensor,
    /// Submission wall time — the `service_latency` histogram observes
    /// `submitted.elapsed()` when the reply is routed.
    submitted: Instant,
    /// Trace frame id ([`trace::next_frame`]; `NO_FRAME` when tracing
    /// is off) — keys this request's spans across pipeline threads.
    trace_frame: u64,
    reply: oneshot::Sender<Result<(Tensor, Tensor), String>>,
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Max frames packed into one device call (SLM sequence depth).
    pub max_batch: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 128,
            queue_depth: 256,
        }
    }
}

/// Per-client token bucket: `rate_fps` frames (rows) per second with
/// `burst` frames of credit.  Pure — callers supply `now_s` — so the
/// refill math is unit-testable without wall clocks.
struct TokenBucket {
    rate_fps: f64,
    burst: f64,
    tokens: f64,
    last_s: f64,
}

impl TokenBucket {
    fn new(rate_fps: f64, burst: f64) -> TokenBucket {
        TokenBucket {
            rate_fps,
            burst,
            tokens: burst,
            last_s: 0.0,
        }
    }

    /// Try to admit `n` frames at `now_s`; `Err(wait_s)` is the time
    /// until enough tokens accrue.  A request wider than the whole
    /// burst is admitted whenever the bucket is full — it can never
    /// save more than `burst` tokens, and holding it forever would turn
    /// a fairness knob into a correctness cliff.
    fn try_take(&mut self, n: f64, now_s: f64) -> Result<(), f64> {
        let dt = (now_s - self.last_s).max(0.0);
        self.tokens = (self.tokens + dt * self.rate_fps).min(self.burst);
        self.last_s = now_s;
        let need = n.min(self.burst);
        if self.tokens >= need {
            self.tokens -= need;
            Ok(())
        } else {
            Err((need - self.tokens) / self.rate_fps.max(1e-9))
        }
    }
}

/// Admission state attached to one [`ProjectionClient`] handle.  Clones
/// of a handle share its bucket (they are the same client); call
/// [`ShardedProjectionService::client`] again for an independent budget.
#[derive(Clone)]
struct ClientAdmission {
    bucket: Arc<Mutex<TokenBucket>>,
    epoch: Instant,
    max_wait: Duration,
    throttled: Counter,
}

impl ClientAdmission {
    /// Block (bounded backpressure) until `rows` frames are admitted;
    /// error once the projected wait exceeds `max_wait`.
    fn admit(&self, rows: usize) -> Result<()> {
        let deadline = Instant::now() + self.max_wait;
        loop {
            let now_s = self.epoch.elapsed().as_secs_f64();
            let taken = {
                let mut b = self.bucket.lock().unwrap_or_else(PoisonError::into_inner);
                b.try_take(rows as f64, now_s)
            };
            let wait_s = match taken {
                Ok(()) => return Ok(()),
                Err(wait_s) => wait_s,
            };
            let now = Instant::now();
            if now + Duration::from_secs_f64(wait_s) > deadline {
                self.throttled.inc();
                anyhow::bail!(
                    "admission: request of {rows} frames exceeds this client's rate budget \
                     (service_admission_throttled); retry later"
                );
            }
            std::thread::sleep(Duration::from_secs_f64(wait_s).min(deadline - now));
        }
    }
}

/// Handle for submitting projection requests.
#[derive(Clone)]
pub struct ProjectionClient {
    queue: BoundedQueue<Request>,
    d_in: usize,
    admission: Option<ClientAdmission>,
}

impl ProjectionClient {
    /// Submit frames `[B, d_in]`; returns a future for `(P1, P2)`.
    /// Requests are coalesced up to the service's `max_batch`; a single
    /// request *larger* than `max_batch` is never split — it is
    /// scheduled as its own oversized frame sequence (pinned by
    /// `prop_service_preserves_payloads` in `rust/tests/props.rs`).
    /// With admission control on, this call may block up to the
    /// configured wait for this client's token budget and then error.
    pub fn submit(
        &self,
        frames: Tensor,
    ) -> Result<oneshot::Reply<Result<(Tensor, Tensor), String>>> {
        anyhow::ensure!(
            frames.shape().len() == 2 && frames.cols() == self.d_in,
            "projection frames must be [b, {}], got {:?}",
            self.d_in,
            frames.shape()
        );
        anyhow::ensure!(frames.rows() > 0, "empty projection request");
        let trace_frame = trace::next_frame();
        trace::begin(trace::STAGE_REQUEST, trace_frame, NO_SHARD);
        if let Some(admission) = &self.admission {
            let t = trace::start();
            let admitted = admission.admit(frames.rows());
            trace::complete(trace::STAGE_ADMIT, trace_frame, NO_SHARD, t);
            if let Err(e) = admitted {
                trace::end(trace::STAGE_REQUEST, trace_frame, NO_SHARD);
                return Err(e);
            }
        }
        let (tx, rx) = oneshot::channel();
        trace::begin(trace::STAGE_QUEUE_WAIT, trace_frame, NO_SHARD);
        self.queue
            .push(Request {
                frames,
                submitted: Instant::now(),
                trace_frame,
                reply: tx,
            })
            .map_err(|_| {
                trace::end(trace::STAGE_QUEUE_WAIT, trace_frame, NO_SHARD);
                trace::end(trace::STAGE_REQUEST, trace_frame, NO_SHARD);
                anyhow::anyhow!("projection service is shut down")
            })?;
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn project(&self, frames: Tensor) -> Result<(Tensor, Tensor)> {
        let reply = self.submit(frames)?;
        match reply.wait() {
            Some(Ok(pair)) => Ok(pair),
            Some(Err(e)) => anyhow::bail!("device error: {e}"),
            None => anyhow::bail!("projection service dropped the request"),
        }
    }
}

/// [`Projector`] adapter over a [`ProjectionClient`]: lets a trainer
/// (host or XLA) drive its error projections through a *running
/// projection service* — N trainers sharing one device fleet, the
/// Perspectives ensemble scenario.  Frame accounting mirrors the
/// optical frame clock (`rows / frame_rate`); the service's own
/// per-shard counters carry the authoritative slot/energy attribution.
pub struct ClientProjector {
    client: ProjectionClient,
    modes: usize,
    frame_rate_hz: f64,
    power_watts: f64,
    frames: u64,
    requires_ternary: bool,
}

impl ClientProjector {
    /// Adapter over `client` for a fleet exposing `modes` output modes.
    /// Defaults: the paper's 1.5 kHz / 30 W device rates, ternary
    /// frames required (the safe assumption when any shard is optical).
    pub fn new(client: ProjectionClient, modes: usize) -> ClientProjector {
        ClientProjector {
            client,
            modes,
            frame_rate_hz: 1500.0,
            power_watts: 30.0,
            frames: 0,
            requires_ternary: true,
        }
    }

    /// Override the frame clock / power used for this handle's local
    /// `sim_seconds`/`energy_joules` view.
    pub fn with_rates(mut self, frame_rate_hz: f64, power_watts: f64) -> ClientProjector {
        self.frame_rate_hz = frame_rate_hz;
        self.power_watts = power_watts;
        self
    }

    /// Accept float frames (an all-digital fleet has no SLM to please).
    pub fn allow_float(mut self) -> ClientProjector {
        self.requires_ternary = false;
        self
    }
}

impl Projector for ClientProjector {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        let out = self.client.project(frames.clone())?;
        self.frames += frames.rows() as u64;
        Ok(out)
    }

    fn modes(&self) -> usize {
        self.modes
    }

    fn sim_seconds(&self) -> f64 {
        self.frames as f64 / self.frame_rate_hz
    }

    fn energy_joules(&self) -> f64 {
        self.sim_seconds() * self.power_watts
    }

    fn kind(&self) -> &'static str {
        "service-client"
    }

    fn requires_ternary(&self) -> bool {
        self.requires_ternary
    }
}

/// The running service (owns the dispatcher thread and the device).
pub struct ProjectionService {
    queue: BoundedQueue<Request>,
    dispatcher: Option<JoinHandle<()>>,
    d_in: usize,
}

impl ProjectionService {
    /// Start a service over a device.  `d_in` is the frame width.
    pub fn start(
        mut device: Box<dyn Projector + Send>,
        d_in: usize,
        cfg: ServiceConfig,
        metrics: Registry,
    ) -> ProjectionService {
        let queue: BoundedQueue<Request> = BoundedQueue::new(cfg.queue_depth);
        let q2 = queue.clone();
        let frames_ctr = metrics.counter("service_frames");
        let batches_ctr = metrics.counter("service_batches");
        let occupancy = metrics.histogram("service_batch_occupancy");
        let latency = metrics.histogram("service_latency");
        let dispatcher = std::thread::Builder::new()
            .name("litl-projection-service".into())
            .spawn(move || {
                pack_loop(&q2, cfg.max_batch, |batch, total| {
                    frames_ctr.add(total as u64);
                    batches_ctr.inc();
                    Self::run_batch(&mut *device, batch, &occupancy, &latency);
                    true
                });
            })
            .expect("spawn dispatcher");
        ProjectionService {
            queue,
            dispatcher: Some(dispatcher),
            d_in,
        }
    }

    fn run_batch(
        device: &mut dyn Projector,
        batch: Vec<Request>,
        occupancy: &Histogram,
        latency: &Histogram,
    ) {
        let rows: usize = batch.iter().map(|r| r.frames.rows()).sum();
        occupancy.observe(rows as f64);
        let d_in = batch[0].frames.cols();
        let packed = pack_requests(&batch, rows, d_in);
        let t = trace::start();
        let projected = device.project(&packed);
        trace::complete(trace::STAGE_PROJECT, batch[0].trace_frame, NO_SHARD, t);
        match projected {
            Ok((p1, p2)) => {
                let modes = device.modes();
                send_replies(batch, &p1, &p2, modes, latency);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                fail_batch(batch, &msg, latency);
            }
        }
    }

    /// Create a client handle (the classic path has no admission
    /// control — that is a sharded-service feature).
    pub fn client(&self) -> ProjectionClient {
        ProjectionClient {
            queue: self.queue.clone(),
            d_in: self.d_in,
            admission: None,
        }
    }

    /// Stop accepting requests and join the dispatcher.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ProjectionService {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// Greedy dynamic batching, shared verbatim by the device-agnostic
/// dispatcher and the frame-slot scheduler — the `shards=1`
/// bitwise-parity contract requires the two to pack identically.
/// Blocks for one request, opportunistically coalesces pending ones up
/// to `max_batch` rows (a request that does not fit flushes the current
/// sequence and starts the next; re-queueing would reorder), and calls
/// `flush` for every packed sequence.  Returns when the queue is closed
/// AND drained; `flush` returning false aborts early (shutdown raced a
/// schedule).
fn pack_loop(
    queue: &BoundedQueue<Request>,
    max_batch: usize,
    mut flush: impl FnMut(Vec<Request>, usize) -> bool,
) {
    while let Some(first) = queue.pop() {
        trace::end(trace::STAGE_QUEUE_WAIT, first.trace_frame, NO_SHARD);
        let mut batch: Vec<Request> = vec![first];
        let mut total: usize = batch[0].frames.rows();
        while total < max_batch {
            match queue.try_pop() {
                Some(req) if total + req.frames.rows() <= max_batch => {
                    trace::end(trace::STAGE_QUEUE_WAIT, req.trace_frame, NO_SHARD);
                    total += req.frames.rows();
                    batch.push(req);
                }
                Some(req) => {
                    trace::end(trace::STAGE_QUEUE_WAIT, req.trace_frame, NO_SHARD);
                    if !flush(batch, total) {
                        return;
                    }
                    batch = vec![req];
                    total = batch[0].frames.rows();
                }
                None => break,
            }
        }
        if !flush(batch, total) {
            return;
        }
    }
}

/// Copy a batch of requests into one contiguous `[total, d_in]` frame
/// sequence, submission order preserved — shared by the dispatcher and
/// the frame-slot scheduler for the same reason as [`pack_loop`].
fn pack_requests(batch: &[Request], total: usize, d_in: usize) -> Tensor {
    let mut packed = Tensor::zeros(&[total, d_in]);
    let mut at = 0usize;
    for req in batch {
        let n = req.frames.rows() * d_in;
        packed.data_mut()[at * d_in..at * d_in + n]
            .copy_from_slice(req.frames.data());
        at += req.frames.rows();
    }
    packed
}

/// Slice a packed frame sequence's projections back out to the
/// submitting clients, preserving request row order.
fn send_replies(batch: Vec<Request>, p1: &Tensor, p2: &Tensor, modes: usize, latency: &Histogram) {
    let mut row = 0usize;
    for req in batch {
        let b = req.frames.rows();
        let take = |src: &Tensor| {
            Tensor::from_vec(
                &[b, modes],
                src.data()[row * modes..(row + b) * modes].to_vec(),
            )
        };
        latency.observe(req.submitted.elapsed().as_secs_f64());
        trace::end(trace::STAGE_REQUEST, req.trace_frame, NO_SHARD);
        req.reply.send(Ok((take(p1), take(p2))));
        row += b;
    }
}

/// Fail every request in a batch with the same error: backpressure,
/// device failures and failover must all degrade to *errors* the client
/// observes, never hangs.
fn fail_batch(batch: Vec<Request>, msg: &str, latency: &Histogram) {
    for req in batch {
        latency.observe(req.submitted.elapsed().as_secs_f64());
        trace::end(trace::STAGE_REQUEST, req.trace_frame, NO_SHARD);
        req.reply.send(Err(msg.to_string()));
    }
}

/// Adaptive-weight re-planning knobs (off by default — the static
/// declared plan is part of the determinism contract).  When on, the
/// scheduler re-derives the effective batch-partition row weights every
/// `replan_every` scheduled frame sequences from the per-shard
/// service-rate EWMAs the workers publish
/// (`service_shard{i}_rate_ewma`), ignoring proposals whose normalized
/// share moves no shard by more than the `hysteresis` band.  Shards
/// without a rate signal yet keep their declared relative share.
#[derive(Clone, Copy, Debug)]
pub struct AdaptConfig {
    pub enabled: bool,
    /// Re-plan cadence, in scheduled frame sequences.
    pub replan_every: u64,
    /// EWMA smoothing factor in (0, 1] (also smooths the `_util`
    /// occupancy gauge, which is windowed even when adaptation is off).
    pub alpha: f64,
    /// Minimum relative share change that commits a new plan.
    pub hysteresis: f64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            enabled: false,
            replan_every: 16,
            alpha: 0.2,
            hysteresis: 0.05,
        }
    }
}

/// Shard failover (off by default).  A shard trips after `trip_errors`
/// consecutive device errors, or when one device call exceeds
/// `stall_ms` (the stall detector force-fails the wedged in-flight part
/// so its clients see an error, never a hang).  Tripped shards stop
/// receiving new work — their queued lane drains onto survivors under
/// the batch partition (replica-trivial) and fails fast under modes —
/// and re-enter on probation after `probation_ms`, where one more error
/// re-trips immediately.  With a rebuild factory attached
/// ([`ShardedProjectionService::start_full`], which
/// `Topology::build_service` does automatically) an error-tripped
/// worker replaces its own device in place — the factory re-windows the
/// medium exactly as the original build did, which is what makes
/// modes-partition failover recoverable.
///
/// **Layering with session resume** (remote shards,
/// `NetOptions::resume_tries` > 0): resume absorbs *transport* death —
/// a cut connection redials, re-attaches its stream, and replays or
/// re-executes the in-flight frame exactly once, so the worker never
/// sees an error and failover never trips.  What still reaches this
/// state machine is everything resume cannot fix: device/app errors,
/// an exhausted retry budget, or a poisoned session (cursor mismatch
/// after a server-side failure) — each surfaces as one typed worker
/// error and trips the shard deterministically.  `rust/tests/chaos.rs`
/// pins both halves under a seeded fault plan.
#[derive(Clone, Copy, Debug)]
pub struct FailoverConfig {
    pub enabled: bool,
    /// Consecutive device errors that trip a healthy shard.
    pub trip_errors: u32,
    /// A single device call running longer than this is a stall.
    pub stall_ms: u64,
    /// Tripped → probation re-admission delay.
    pub probation_ms: u64,
}

impl Default for FailoverConfig {
    fn default() -> Self {
        FailoverConfig {
            enabled: false,
            trip_errors: 3,
            stall_ms: 2000,
            probation_ms: 250,
        }
    }
}

/// Per-client admission control (off by default): each
/// [`ShardedProjectionService::client`] handle gets a token bucket of
/// `rate_fps` frames (rows) per second with `burst` frames of credit;
/// `submit` blocks up to `max_wait_ms` for tokens (bounded
/// backpressure) and then errors, counting
/// `service_admission_throttled`.  Clones of a handle share its bucket;
/// call `client()` again for an independent budget.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    pub enabled: bool,
    /// Steady-state admitted frames (rows) per second per client.
    pub rate_fps: f64,
    /// Burst credit in frames.
    pub burst: f64,
    /// Longest a `submit` may wait for tokens before erroring.
    pub max_wait_ms: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            enabled: false,
            rate_fps: 1500.0,
            burst: 256.0,
            max_wait_ms: 50,
        }
    }
}

/// Scheduling configuration for the shard-aware service.
#[derive(Clone, Copy, Debug)]
pub struct ShardServiceConfig {
    /// Max frames (rows) coalesced into one scheduled frame sequence.
    pub max_batch: usize,
    /// Central submit-queue capacity (client backpressure bound).
    pub queue_depth: usize,
    /// Per-shard lane capacity (scheduler → worker backpressure bound).
    pub lane_depth: usize,
    /// How scheduled frames map onto shards.
    pub partition: Partition,
    /// Frame rate used for scheduler-side per-slot time attribution.
    pub frame_rate_hz: f64,
    /// Adaptive weight re-planning (off = pinned static schedule).
    pub adapt: AdaptConfig,
    /// Shard health / failover policy (off = no trip, no re-route).
    pub failover: FailoverConfig,
    /// Per-client admission control (off = unlimited submits).
    pub admission: AdmissionConfig,
}

impl Default for ShardServiceConfig {
    fn default() -> Self {
        ShardServiceConfig {
            max_batch: 128,
            queue_depth: 256,
            lane_depth: 8,
            partition: Partition::Modes,
            frame_rate_hz: 1500.0,
            adapt: AdaptConfig::default(),
            failover: FailoverConfig::default(),
            admission: AdmissionConfig::default(),
        }
    }
}

/// One shard's share of a scheduled frame sequence.  `frames` is shared
/// (`Arc`) because the mode partition sends the *same* packed sequence
/// to every shard — no per-shard deep copies on the scheduler thread.
struct ShardJob {
    frames: Arc<Tensor>,
    /// Index into the frame's part list (== gather position).
    part: usize,
    /// The scheduled frame's trace id (first coalesced request's).
    trace_frame: u64,
    assembly: Arc<FrameAssembly>,
}

/// Gather state for one scheduled frame sequence: the worker that
/// completes the last pending part assembles the full quadratures and
/// routes the replies.  Assembly order is by part index — fixed at
/// scheduling time — so results do not depend on which shard finishes
/// first.
struct FrameAssembly {
    requests: Mutex<Vec<Request>>,
    #[allow(clippy::type_complexity)]
    parts: Mutex<Vec<Option<Result<(Tensor, Tensor), String>>>>,
    pending: AtomicUsize,
    partition: Partition,
    rows_total: usize,
    modes_total: usize,
    /// Per-part mode counts (modes partition) or row counts (batch).
    part_dims: Vec<usize>,
    /// The scheduled frame's trace id (first coalesced request's).
    trace_frame: u64,
    latency: Histogram,
}

/// Record one part's result and, when it was the last pending part,
/// assemble and reply.  Poison-tolerant (a client panicking around its
/// reply must not kill the shard worker completing the frame) and
/// *idempotent*: the stall detector force-fails a wedged part, and the
/// wedged device call may still return later — a part that already has
/// a result (or a frame already finished, which empties the vec) is
/// dropped without touching `pending`.
fn complete_part(
    assembly: &Arc<FrameAssembly>,
    part: usize,
    result: Result<(Tensor, Tensor), String>,
) {
    {
        let mut parts = assembly.parts.lock().unwrap_or_else(PoisonError::into_inner);
        if part >= parts.len() || parts[part].is_some() {
            return;
        }
        parts[part] = Some(result);
    }
    if assembly.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        finish_frame(assembly);
    }
}

fn finish_frame(assembly: &FrameAssembly) {
    // The gather span covers result assembly + concat only; it closes
    // before the replies go out, so gather-end <= every request-end.
    let t = trace::start();
    let parts_raw = {
        let mut g = assembly.parts.lock().unwrap_or_else(PoisonError::into_inner);
        std::mem::take(&mut *g)
    };
    let requests = {
        let mut g = assembly.requests.lock().unwrap_or_else(PoisonError::into_inner);
        std::mem::take(&mut *g)
    };
    let mut parts: Vec<(Tensor, Tensor)> = Vec::with_capacity(parts_raw.len());
    let mut errors: Vec<String> = Vec::new();
    for (i, p) in parts_raw.into_iter().enumerate() {
        match p {
            Some(Ok(pair)) => parts.push(pair),
            Some(Err(e)) => errors.push(format!("shard part {i}: {e}")),
            None => errors.push(format!("shard part {i}: no result")),
        }
    }
    if !errors.is_empty() {
        let msg = errors.join("; ");
        trace::complete(trace::STAGE_GATHER, assembly.trace_frame, NO_SHARD, t);
        fail_batch(requests, &msg, &assembly.latency);
        return;
    }
    let (p1, p2) = concat_parts(&parts, assembly);
    trace::complete(trace::STAGE_GATHER, assembly.trace_frame, NO_SHARD, t);
    send_replies(requests, &p1, &p2, assembly.modes_total, &assembly.latency);
}

/// Concatenate per-shard quadratures back into the full frame result:
/// along columns for the mode partition, along rows for batch (the same
/// gather the farm uses — one implementation, one contract).
fn concat_parts(
    parts: &[(Tensor, Tensor)],
    assembly: &FrameAssembly,
) -> (Tensor, Tensor) {
    match assembly.partition {
        Partition::Modes => {
            concat_mode_parts(parts, &assembly.part_dims, assembly.rows_total)
        }
        Partition::Batch => {
            concat_row_parts(parts, &assembly.part_dims, assembly.modes_total)
        }
    }
}

/// Windowed exponential moving average, `v += α·(x − v)`, primed by the
/// first observation.  This is the windowed statistic that replaced the
/// old lifetime-cumulative `util` gauge: dividing lifetime `frames` by
/// lifetime `calls · max_batch` meant an hour of idleness (or a burst
/// of failed calls) skewed the gauge forever, which is exactly the
/// signal the adaptive planner must be able to trust.
struct Ewma {
    alpha: f64,
    value: f64,
    primed: bool,
}

impl Ewma {
    fn new(alpha: f64) -> Ewma {
        Ewma {
            alpha,
            value: 0.0,
            primed: false,
        }
    }

    fn observe(&mut self, x: f64) -> f64 {
        if self.primed {
            self.value += self.alpha * (x - self.value);
        } else {
            self.value = x;
            self.primed = true;
        }
        self.value
    }
}

/// Health states published in the `service_shard{i}_state` gauge.
const STATE_HEALTHY: u8 = 0;
const STATE_TRIPPED: u8 = 1;
const STATE_PROBATION: u8 = 2;

/// One shard's health state machine, shared lock-free between its
/// worker (error/progress accounting) and the scheduler (stall
/// detection, routing mask, probation re-admission).  Timestamps are
/// milliseconds since the service epoch; `busy_since_ms` stores ms+1 so
/// 0 can mean idle.
struct ShardHealth {
    state: AtomicU8,
    consecutive_errors: AtomicU32,
    busy_since_ms: AtomicU64,
    tripped_at_ms: AtomicU64,
}

impl ShardHealth {
    fn new() -> ShardHealth {
        ShardHealth {
            state: AtomicU8::new(STATE_HEALTHY),
            consecutive_errors: AtomicU32::new(0),
            busy_since_ms: AtomicU64::new(0),
            tripped_at_ms: AtomicU64::new(0),
        }
    }

    fn state(&self) -> u8 {
        self.state.load(Ordering::Relaxed)
    }

    fn begin_call(&self, now_ms: u64) {
        self.busy_since_ms.store(now_ms + 1, Ordering::Relaxed);
    }

    fn end_call(&self) {
        self.busy_since_ms.store(0, Ordering::Relaxed);
    }

    /// A success clears the error streak and heals any trip — a shard
    /// that serves again is, by observation, serving.
    fn note_success(&self) {
        self.consecutive_errors.store(0, Ordering::Relaxed);
        self.state.store(STATE_HEALTHY, Ordering::Relaxed);
    }

    /// Count one error; returns true when this error trips the shard
    /// (streak reached on a healthy shard, or any error on probation).
    fn note_error(&self, trip_errors: u32, now_ms: u64) -> bool {
        let streak = self.consecutive_errors.fetch_add(1, Ordering::Relaxed) + 1;
        let tripped = match self.state() {
            STATE_PROBATION => true,
            STATE_HEALTHY => streak >= trip_errors,
            _ => false,
        };
        if tripped {
            self.trip(now_ms);
        }
        tripped
    }

    fn trip(&self, now_ms: u64) {
        self.state.store(STATE_TRIPPED, Ordering::Relaxed);
        self.tripped_at_ms.store(now_ms, Ordering::Relaxed);
    }

    fn enter_probation(&self) {
        self.consecutive_errors.store(0, Ordering::Relaxed);
        self.state.store(STATE_PROBATION, Ordering::Relaxed);
    }

    /// True when a device call has been running longer than `stall_ms`.
    fn stalled(&self, stall_ms: u64, now_ms: u64) -> bool {
        let busy = self.busy_since_ms.load(Ordering::Relaxed);
        busy != 0 && now_ms.saturating_sub(busy - 1) > stall_ms
    }

    /// Tripped shards receive no new work; probation shards do.
    fn routable(&self) -> bool {
        self.state() != STATE_TRIPPED
    }

    /// Probation re-admission: a shard tripped at least `probation_ms`
    /// ago — and not still wedged inside a call — gets another chance.
    fn maybe_readmit(&self, probation_ms: u64, stall_ms: u64, now_ms: u64) -> bool {
        if self.state() != STATE_TRIPPED || self.stalled(stall_ms, now_ms) {
            return false;
        }
        let tripped_at = self.tripped_at_ms.load(Ordering::Relaxed);
        if now_ms.saturating_sub(tripped_at) < probation_ms {
            return false;
        }
        self.enter_probation();
        true
    }
}

/// The (part index, gather state) of a job currently inside a device
/// call — what the stall detector force-fails when the call never
/// returns.
type Inflight = Arc<Mutex<Option<(usize, Arc<FrameAssembly>)>>>;

fn take_inflight(slot: &Inflight) -> Option<(usize, Arc<FrameAssembly>)> {
    slot.lock().unwrap_or_else(PoisonError::into_inner).take()
}

fn set_inflight(slot: &Inflight, value: Option<(usize, Arc<FrameAssembly>)>) {
    *slot.lock().unwrap_or_else(PoisonError::into_inner) = value;
}

/// Failover device factory: builds a fresh replacement device for shard
/// `i` (mode windows re-derived from the medium, replicas re-cloned).
/// `Topology::build_service` attaches one automatically.
pub type ShardRebuild = Arc<dyn Fn(usize) -> Result<Box<dyn Projector + Send>> + Send + Sync>;

/// One shard's worker: owns the device, drains its lane in FIFO order.
/// A panicking device fails the frame (all clients in it see the error)
/// but the worker — and the lane — stay alive, mirroring the farm's
/// panic containment.  With failover enabled the worker also runs its
/// side of the health machine: error streaks trip the shard, and an
/// error-tripped worker with a rebuild factory replaces its own device
/// in place and re-enters on probation.
struct ShardWorker {
    shard: usize,
    device: Box<dyn Projector + Send>,
    lanes: Lanes<ShardJob>,
    max_batch: usize,
    failover: FailoverConfig,
    rebuild: Option<ShardRebuild>,
    health: Arc<ShardHealth>,
    inflight: Inflight,
    epoch: Instant,
    occ_ewma: Ewma,
    rate_ewma: Ewma,
    frames: Counter,
    calls: Counter,
    errors: Counter,
    failovers: Counter,
    util: Gauge,
    rate_gauge: Gauge,
    state_gauge: Gauge,
    lane_depth: Gauge,
}

impl ShardWorker {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn run(mut self) {
        while let Some(job) = self.lanes.pop(self.shard) {
            trace::end(trace::STAGE_LANE_WAIT, job.trace_frame, self.shard as u32);
            self.lane_depth.set(self.lanes.len(self.shard) as f64);
            let rows = job.frames.rows();
            set_inflight(&self.inflight, Some((job.part, job.assembly.clone())));
            self.health.begin_call(self.now_ms());
            let t0 = Instant::now();
            let tspan = trace::start();
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || self.device.project(&job.frames),
            ))
            .unwrap_or_else(|_| Err(anyhow::anyhow!("shard device panicked")))
            .map_err(|e| format!("{e:#}"));
            trace::complete(
                trace::STAGE_PROJECT,
                job.trace_frame,
                self.shard as u32,
                tspan,
            );
            let elapsed_s = t0.elapsed().as_secs_f64();
            self.health.end_call();
            set_inflight(&self.inflight, None);
            self.calls.inc();
            match &result {
                Ok(_) => {
                    self.frames.add(rows as u64);
                    self.note_success(rows, elapsed_s);
                }
                Err(_) => {
                    self.errors.inc();
                    self.note_error();
                }
            }
            // Windowed occupancy: rows projected per offered frame-slot
            // capacity for *this* call, EWMA-smoothed (clamped to 1.0 —
            // an oversized pass-through request can exceed one
            // sequence's nominal capacity).
            let occ = (rows as f64 / self.max_batch as f64).min(1.0);
            self.util.set(self.occ_ewma.observe(occ));
            complete_part(&job.assembly, job.part, result);
        }
    }

    fn note_success(&mut self, rows: usize, elapsed_s: f64) {
        let rate = rows as f64 / elapsed_s.max(1e-9);
        self.rate_gauge.set(self.rate_ewma.observe(rate));
        self.health.note_success();
        self.state_gauge.set(self.health.state() as f64);
    }

    fn note_error(&mut self) {
        self.rate_gauge.set(self.rate_ewma.observe(0.0));
        if !self.failover.enabled {
            return;
        }
        let now = self.now_ms();
        if self.health.note_error(self.failover.trip_errors, now) {
            self.failovers.inc();
            if let Some(rebuild) = self.rebuild.clone() {
                // In-place device replacement: the factory re-derives
                // shard `shard`'s device (re-windowed medium under the
                // modes partition), then the worker re-enters on
                // probation.  A failing factory leaves the shard
                // tripped for the scheduler to drain.
                match rebuild(self.shard) {
                    Ok(device) => {
                        self.device = device;
                        self.health.enter_probation();
                    }
                    Err(e) => {
                        log::warn!("shard {} rebuild failed: {e:#}", self.shard);
                    }
                }
            }
        }
        self.state_gauge.set(self.health.state() as f64);
    }
}

/// Relative scale effective weights are normalized to on a re-plan.
const WEIGHT_SCALE: f64 = 1000.0;

/// The frame-slot scheduler: a single thread, so frame packing and
/// (shard, slot) assignment are a pure function of submission order.
/// With the control plane off every field beyond the PR-2/PR-4 set is
/// inert: `eff_weights == weights` forever, no health transitions, no
/// re-plans — the pinned schedules cannot move.
struct FrameScheduler {
    cfg: ShardServiceConfig,
    d_in: usize,
    modes_total: usize,
    shard_modes: Vec<usize>,
    /// Declared service weights, shard order: the batch partition
    /// splits a frame's rows proportionally to these
    /// ([`weighted_widths`]); all-equal weights reproduce the
    /// historical even split bit for bit.
    weights: Vec<u32>,
    /// Live plan: equals `weights` until an adaptive re-plan commits.
    eff_weights: Vec<u32>,
    lanes: Lanes<ShardJob>,
    health: Vec<Arc<ShardHealth>>,
    inflight: Vec<Inflight>,
    /// Per-shard "lane already drained for the current trip" latch.
    drained: Vec<bool>,
    /// Round-robin cursor for failover re-routing.
    route_rr: usize,
    batches_seen: u64,
    epoch: Instant,
    frames_ctr: Counter,
    batches_ctr: Counter,
    failovers: Counter,
    replans: Counter,
    occupancy: Histogram,
    latency: Histogram,
    queue_depth: Gauge,
    shard_slots: Vec<Counter>,
    slot_clocks: Vec<SimClock>,
    slot_gauges: Vec<Gauge>,
    rate_gauges: Vec<Gauge>,
    eff_gauges: Vec<Gauge>,
    state_gauges: Vec<Gauge>,
}

impl FrameScheduler {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn run(mut self, queue: BoundedQueue<Request>) {
        // `pack_loop` is the same greedy coalescing the device-agnostic
        // dispatcher runs — that shared implementation is what makes
        // `shards=1` bitwise-reproduce the classic path.  `pop` drains
        // the queue after close, so everything submitted before
        // shutdown still gets scheduled.
        let max_batch = self.cfg.max_batch;
        pack_loop(&queue, max_batch, |batch, total| {
            self.queue_depth.set(queue.len() as f64);
            self.schedule_frame(batch, total).is_ok()
        });
    }

    /// Charge a scheduled slot range to one shard's accounts (whether
    /// or not the device later errors — a failed exposure still
    /// occupied the camera).
    fn charge_slots(&self, shard: usize, slots: u64) {
        self.shard_slots[shard].add(slots);
        self.slot_clocks[shard].advance_slots(slots, self.cfg.frame_rate_hz);
        self.slot_gauges[shard].set(self.slot_clocks[shard].now_secs());
    }

    /// Next routable shard other than `exclude`, round-robin so drained
    /// work spreads over the survivors instead of piling onto one.
    fn pick_routable(&mut self, exclude: usize) -> Option<usize> {
        let n = self.health.len();
        for k in 0..n {
            let cand = (self.route_rr + k) % n;
            if cand != exclude && self.health[cand].routable() {
                self.route_rr = (cand + 1) % n;
                return Some(cand);
            }
        }
        None
    }

    /// Health pass, run once per scheduled batch when failover is on:
    /// trip stalled shards (force-failing the wedged in-flight part so
    /// its clients error instead of hanging), drain freshly tripped
    /// lanes, and re-admit shards whose probation delay has elapsed.
    fn failover_maintenance(&mut self) {
        let fo = self.cfg.failover;
        let now = self.now_ms();
        for shard in 0..self.health.len() {
            let h = self.health[shard].clone();
            if h.state() != STATE_TRIPPED && h.stalled(fo.stall_ms, now) {
                h.trip(now);
                self.failovers.inc();
                if let Some((part, assembly)) = take_inflight(&self.inflight[shard]) {
                    let msg = format!("shard {shard} stalled (> {} ms)", fo.stall_ms);
                    complete_part(&assembly, part, Err(msg));
                }
            }
            if h.state() == STATE_TRIPPED && !self.drained[shard] {
                self.drained[shard] = true;
                self.drain_lane(shard);
            }
            h.maybe_readmit(fo.probation_ms, fo.stall_ms, now);
            if h.state() != STATE_TRIPPED {
                // Healed — by probation re-admission here or by the
                // worker's in-place rebuild — so re-arm the drain
                // latch for the next trip.
                self.drained[shard] = false;
            }
            self.state_gauges[shard].set(h.state() as f64);
        }
    }

    /// Move a tripped shard's queued-but-unstarted jobs off its lane:
    /// batch-partition jobs re-route to a surviving replica (same part
    /// index — the gather order is untouched); modes-partition jobs
    /// fail fast, because survivors image *other* mode windows (the
    /// in-place worker rebuild is the modes recovery path).  The worker
    /// may be consuming the same lane concurrently; `try_pop` hands
    /// each job to exactly one consumer either way.
    fn drain_lane(&mut self, shard: usize) {
        while let Some(job) = self.lanes.try_pop(shard) {
            // The drained job's lane wait ends here; a re-route below
            // opens a fresh one on the target shard's lane.
            trace::end(trace::STAGE_LANE_WAIT, job.trace_frame, shard as u32);
            match self.cfg.partition {
                Partition::Batch => match self.pick_routable(shard) {
                    Some(target) => {
                        self.charge_slots(target, job.frames.rows() as u64);
                        let frame = job.trace_frame;
                        trace::begin(trace::STAGE_LANE_WAIT, frame, target as u32);
                        if self.lanes.push(target, job).is_err() {
                            trace::end(trace::STAGE_LANE_WAIT, frame, target as u32);
                            return;
                        }
                    }
                    None => {
                        let msg = format!("shard {shard} tripped; no survivors");
                        complete_part(&job.assembly, job.part, Err(msg));
                    }
                },
                Partition::Modes => {
                    let msg = format!("shard {shard} tripped (modes partition)");
                    complete_part(&job.assembly, job.part, Err(msg));
                }
            }
        }
    }

    /// Re-derive the effective weights from the worker-published rate
    /// EWMAs: measured share for shards with a signal, declared share
    /// until they have one, floor 1 (the `weighted_widths` contract).
    /// Proposals inside the hysteresis band are dropped — weights only
    /// move on sustained drift, not per-batch noise.
    fn replan_weights(&mut self) {
        let rates: Vec<f64> = self.rate_gauges.iter().map(|g| g.get()).collect();
        let max_rate = rates.iter().cloned().fold(0.0_f64, f64::max);
        if max_rate <= 0.0 {
            return;
        }
        let declared_max = *self.weights.iter().max().expect("shards >= 1") as f64;
        let proposed: Vec<u32> = rates
            .iter()
            .zip(&self.weights)
            .map(|(&r, &w)| {
                let share = if r > 0.0 {
                    r / max_rate
                } else {
                    w as f64 / declared_max
                };
                (share * WEIGHT_SCALE).round().max(1.0) as u32
            })
            .collect();
        let cur_sum: f64 = self.eff_weights.iter().map(|&w| w as f64).sum();
        let new_sum: f64 = proposed.iter().map(|&w| w as f64).sum();
        let band = self.cfg.adapt.hysteresis;
        let moved = self
            .eff_weights
            .iter()
            .zip(&proposed)
            .any(|(&c, &p)| (p as f64 / new_sum - c as f64 / cur_sum).abs() > band);
        if !moved {
            return;
        }
        self.eff_weights = proposed;
        self.replans.inc();
        for (g, &w) in self.eff_gauges.iter().zip(&self.eff_weights) {
            g.set(w as f64);
        }
    }

    /// Pack `batch` into one frame sequence, carve it into per-shard
    /// jobs along the partition axis, and enqueue each job on its
    /// shard's lane, charging that shard's slot account at scheduling
    /// time.  `Err` means the lanes closed under us (shutdown raced a
    /// schedule) — the unsent parts' requests get dropped senders, which
    /// clients observe as a dropped request.
    fn schedule_frame(&mut self, batch: Vec<Request>, total: usize) -> Result<(), ()> {
        // The scheduled sequence traces under its first request's frame
        // id.  The span is closed explicitly (not RAII) before the lane
        // pushes so schedule-end <= every lane-wait begin — the ordering
        // the per-frame breakdown's sum <= end-to-end bound rests on.
        let trace_frame = batch[0].trace_frame;
        trace::begin(trace::STAGE_SCHEDULE, trace_frame, NO_SHARD);
        if self.cfg.failover.enabled {
            self.failover_maintenance();
        }
        if self.cfg.adapt.enabled {
            self.batches_seen += 1;
            if self.batches_seen % self.cfg.adapt.replan_every == 0 {
                self.replan_weights();
            }
        }
        self.frames_ctr.add(total as u64);
        self.batches_ctr.inc();
        self.occupancy.observe(total as f64);
        let shards = self.shard_modes.len();
        let routable: Vec<usize> = if self.cfg.failover.enabled {
            (0..shards).filter(|&s| self.health[s].routable()).collect()
        } else {
            (0..shards).collect()
        };
        let packed = pack_requests(&batch, total, self.d_in);
        // (frames, shard) in part order — the gather order.
        let mut jobs: Vec<(Arc<Tensor>, usize)> = Vec::with_capacity(shards);
        let mut part_dims: Vec<usize> = Vec::with_capacity(shards);
        match self.cfg.partition {
            Partition::Modes => {
                if routable.len() < shards {
                    // A tripped shard's mode window has no stand-in on
                    // the survivors; fail the frame fast (error, never a
                    // hang) until the worker's rebuild heals the shard.
                    let down = shards - routable.len();
                    let msg = format!("{down} of {shards} shards tripped (modes partition)");
                    trace::end(trace::STAGE_SCHEDULE, trace_frame, NO_SHARD);
                    fail_batch(batch, &msg, &self.latency);
                    return Ok(());
                }
                // Every shard images every frame: same slot range on
                // each device, coalesced requests share the slots (and
                // the one packed tensor — Arc, not a copy per shard).
                let shared = Arc::new(packed);
                for (shard, &mc) in self.shard_modes.iter().enumerate() {
                    jobs.push((shared.clone(), shard));
                    part_dims.push(mc);
                }
            }
            Partition::Batch => {
                if routable.is_empty() {
                    trace::end(trace::STAGE_SCHEDULE, trace_frame, NO_SHARD);
                    fail_batch(batch, "all shards tripped", &self.latency);
                    return Ok(());
                }
                // Contiguous weighted row ranges over the routable
                // shards (the farm's split — equal weights over a full
                // fleet are the historical balanced ranges); shards
                // whose range is empty sit this frame out.
                let masked: Vec<u32> = routable.iter().map(|&s| self.eff_weights[s]).collect();
                let mut row0 = 0usize;
                for (k, &c) in weighted_widths(total, &masked).iter().enumerate() {
                    if c == 0 {
                        continue;
                    }
                    jobs.push((
                        Arc::new(Tensor::from_vec(
                            &[c, self.d_in],
                            packed.data()[row0 * self.d_in..(row0 + c) * self.d_in]
                                .to_vec(),
                        )),
                        routable[k],
                    ));
                    part_dims.push(c);
                    row0 += c;
                }
            }
        }
        let n_parts = jobs.len();
        let mut part_slots: Vec<Option<Result<(Tensor, Tensor), String>>> =
            Vec::with_capacity(n_parts);
        part_slots.resize_with(n_parts, || None);
        let assembly = Arc::new(FrameAssembly {
            requests: Mutex::new(batch),
            parts: Mutex::new(part_slots),
            pending: AtomicUsize::new(n_parts),
            partition: self.cfg.partition,
            rows_total: total,
            modes_total: self.modes_total,
            part_dims,
            trace_frame,
            latency: self.latency.clone(),
        });
        trace::end(trace::STAGE_SCHEDULE, trace_frame, NO_SHARD);
        for (part, (frames, shard)) in jobs.into_iter().enumerate() {
            self.charge_slots(shard, frames.rows() as u64);
            let job = ShardJob {
                frames,
                part,
                trace_frame,
                assembly: assembly.clone(),
            };
            trace::begin(trace::STAGE_LANE_WAIT, trace_frame, shard as u32);
            if self.lanes.push(shard, job).is_err() {
                trace::end(trace::STAGE_LANE_WAIT, trace_frame, shard as u32);
                return Err(());
            }
        }
        Ok(())
    }
}

/// The running shard-aware service: scheduler + one worker per shard.
pub struct ShardedProjectionService {
    queue: BoundedQueue<Request>,
    lanes: Lanes<ShardJob>,
    scheduler: Option<JoinHandle<()>>,
    workers: Vec<(usize, JoinHandle<()>)>,
    slot_clocks: Vec<SimClock>,
    health: Vec<Arc<ShardHealth>>,
    inflight: Vec<Inflight>,
    epoch: Instant,
    cfg: ShardServiceConfig,
    throttled: Counter,
    d_in: usize,
}

impl ShardedProjectionService {
    /// Start a service over equal-weight shard devices (shard `i` ↔
    /// lane `i`; order is the gather order).  `d_in` is the frame
    /// width.
    pub fn start(
        shards: Vec<Box<dyn Projector + Send>>,
        d_in: usize,
        cfg: ShardServiceConfig,
        metrics: Registry,
    ) -> Result<ShardedProjectionService> {
        let weights = vec![1u32; shards.len()];
        Self::start_weighted(shards, weights, d_in, cfg, metrics)
    }

    /// [`ShardedProjectionService::start`] with per-shard service
    /// weights: under the batch partition the frame-slot scheduler
    /// splits each frame's rows proportionally to `weights` — the
    /// heterogeneous-fleet schedule where a `@3` device takes 3× the
    /// rows of a `@1` one.  Equal weights reproduce [`start`]'s
    /// schedule bit for bit.  Topologies route through here
    /// ([`Topology::build_service`]).
    ///
    /// [`start`]: ShardedProjectionService::start
    /// [`Topology::build_service`]: super::topology::Topology::build_service
    pub fn start_weighted(
        shards: Vec<Box<dyn Projector + Send>>,
        weights: Vec<u32>,
        d_in: usize,
        cfg: ShardServiceConfig,
        metrics: Registry,
    ) -> Result<ShardedProjectionService> {
        Self::start_full(shards, weights, d_in, cfg, metrics, None)
    }

    /// [`start_weighted`] plus an optional failover rebuild factory:
    /// when a shard trips on device errors, its worker calls
    /// `rebuild(shard)` for a fresh replacement device (the factory
    /// re-windows the medium under the modes partition) and re-enters
    /// on probation.  `Topology::build_service` attaches one
    /// automatically; without one, error-tripped shards stay tripped
    /// until probation re-admission.
    ///
    /// [`start_weighted`]: ShardedProjectionService::start_weighted
    /// [`Topology::build_service`]: super::topology::Topology::build_service
    pub fn start_full(
        shards: Vec<Box<dyn Projector + Send>>,
        weights: Vec<u32>,
        d_in: usize,
        cfg: ShardServiceConfig,
        metrics: Registry,
        rebuild: Option<ShardRebuild>,
    ) -> Result<ShardedProjectionService> {
        anyhow::ensure!(!shards.is_empty(), "service needs at least one shard");
        anyhow::ensure!(
            weights.len() == shards.len(),
            "{} weights for {} shards",
            weights.len(),
            shards.len()
        );
        anyhow::ensure!(
            weights.iter().all(|&w| w >= 1),
            "zero-weight shard in {weights:?} (weights must be >= 1)"
        );
        anyhow::ensure!(
            cfg.max_batch > 0 && cfg.queue_depth > 0 && cfg.lane_depth > 0,
            "service capacities must be positive: {cfg:?}"
        );
        anyhow::ensure!(
            cfg.frame_rate_hz > 0.0,
            "frame_rate_hz must be positive: {cfg:?}"
        );
        anyhow::ensure!(
            cfg.adapt.alpha > 0.0 && cfg.adapt.alpha <= 1.0,
            "adapt.alpha must be in (0, 1]: {}",
            cfg.adapt.alpha
        );
        if cfg.adapt.enabled {
            anyhow::ensure!(
                cfg.adapt.replan_every >= 1 && cfg.adapt.hysteresis >= 0.0,
                "adapt knobs out of range: {:?}",
                cfg.adapt
            );
        }
        if cfg.failover.enabled {
            anyhow::ensure!(
                cfg.failover.trip_errors >= 1 && cfg.failover.stall_ms >= 1,
                "failover knobs out of range: {:?}",
                cfg.failover
            );
        }
        if cfg.admission.enabled {
            anyhow::ensure!(
                cfg.admission.rate_fps.is_finite()
                    && cfg.admission.rate_fps > 0.0
                    && cfg.admission.burst >= 1.0,
                "admission knobs out of range: {:?}",
                cfg.admission
            );
        }
        let shard_modes: Vec<usize> = shards.iter().map(|s| s.modes()).collect();
        let modes_total = match cfg.partition {
            Partition::Modes => shard_modes.iter().sum(),
            Partition::Batch => {
                anyhow::ensure!(
                    shard_modes.iter().all(|&m| m == shard_modes[0]),
                    "batch-partition shards must expose identical mode \
                     counts, got {shard_modes:?}"
                );
                shard_modes[0]
            }
        };
        let n = shards.len();
        let queue: BoundedQueue<Request> = BoundedQueue::new(cfg.queue_depth);
        let lanes: Lanes<ShardJob> = Lanes::new(n, cfg.lane_depth);
        let slot_clocks: Vec<SimClock> = (0..n).map(|_| SimClock::new()).collect();
        let epoch = Instant::now();
        let health: Vec<Arc<ShardHealth>> =
            (0..n).map(|_| Arc::new(ShardHealth::new())).collect();
        let inflight: Vec<Inflight> = (0..n).map(|_| Arc::new(Mutex::new(None))).collect();
        let latency = metrics.histogram("service_latency");
        let failovers = metrics.counter("service_failovers");
        let mut workers = Vec::with_capacity(n);
        for (i, device) in shards.into_iter().enumerate() {
            let worker = ShardWorker {
                shard: i,
                device,
                lanes: lanes.clone(),
                max_batch: cfg.max_batch,
                failover: cfg.failover,
                rebuild: rebuild.clone(),
                health: health[i].clone(),
                inflight: inflight[i].clone(),
                epoch,
                occ_ewma: Ewma::new(cfg.adapt.alpha),
                rate_ewma: Ewma::new(cfg.adapt.alpha),
                frames: metrics.counter(&format!("service_shard{i}_frames")),
                calls: metrics.counter(&format!("service_shard{i}_calls")),
                errors: metrics.counter(SHARD_ERRORS),
                failovers: failovers.clone(),
                util: metrics.gauge(&format!("service_shard{i}_util")),
                rate_gauge: metrics.gauge(&format!("service_shard{i}_rate_ewma")),
                state_gauge: metrics.gauge(&format!("service_shard{i}_state")),
                lane_depth: metrics.gauge(&format!("service_shard{i}_lane_depth")),
            };
            workers.push((
                i,
                std::thread::Builder::new()
                    .name(format!("litl-shard-worker-{i}"))
                    .spawn(move || worker.run())
                    .expect("spawn shard worker"),
            ));
        }
        let eff_gauges: Vec<Gauge> = (0..n)
            .map(|i| metrics.gauge(&format!("service_shard{i}_eff_weight")))
            .collect();
        for (g, &w) in eff_gauges.iter().zip(&weights) {
            g.set(w as f64);
        }
        let scheduler = FrameScheduler {
            cfg,
            d_in,
            modes_total,
            shard_modes,
            eff_weights: weights.clone(),
            weights,
            lanes: lanes.clone(),
            health: health.clone(),
            inflight: inflight.clone(),
            drained: vec![false; n],
            route_rr: 0,
            batches_seen: 0,
            epoch,
            frames_ctr: metrics.counter("service_frames"),
            batches_ctr: metrics.counter("service_batches"),
            failovers,
            replans: metrics.counter("service_replans"),
            occupancy: metrics.histogram("service_batch_occupancy"),
            latency,
            queue_depth: metrics.gauge("service_queue_depth"),
            shard_slots: (0..n)
                .map(|i| metrics.counter(&format!("service_shard{i}_slots")))
                .collect(),
            slot_clocks: slot_clocks.clone(),
            slot_gauges: (0..n)
                .map(|i| metrics.gauge(&format!("service_shard{i}_slot_s")))
                .collect(),
            rate_gauges: (0..n)
                .map(|i| metrics.gauge(&format!("service_shard{i}_rate_ewma")))
                .collect(),
            eff_gauges,
            state_gauges: (0..n)
                .map(|i| metrics.gauge(&format!("service_shard{i}_state")))
                .collect(),
        };
        let q2 = queue.clone();
        let sched_handle = std::thread::Builder::new()
            .name("litl-shard-scheduler".into())
            .spawn(move || scheduler.run(q2))
            .expect("spawn frame scheduler");
        Ok(ShardedProjectionService {
            queue,
            lanes,
            scheduler: Some(sched_handle),
            workers,
            slot_clocks,
            health,
            inflight,
            epoch,
            cfg,
            throttled: metrics.counter("service_admission_throttled"),
            d_in,
        })
    }

    /// Start over a [`ProjectorFarm`], taking ownership of its shard
    /// devices *and its service weights* (so a weighted topology's farm
    /// keeps its row split behind the service).  The farm's partition
    /// must match the scheduler's — a mode-sliced farm cannot serve
    /// batch row ranges.
    pub fn over_farm(
        farm: ProjectorFarm,
        d_in: usize,
        cfg: ShardServiceConfig,
        metrics: Registry,
    ) -> Result<ShardedProjectionService> {
        anyhow::ensure!(
            farm.partition() == cfg.partition,
            "farm partition {:?} != service partition {:?}",
            farm.partition(),
            cfg.partition
        );
        let weights = farm.weights().to_vec();
        Self::start_weighted(farm.into_shards(), weights, d_in, cfg, metrics)
    }

    /// Create a client handle (same submit/project API as the
    /// device-agnostic service).  With admission control on, every
    /// handle from this call gets its own token-bucket budget — clones
    /// of one handle share theirs.
    pub fn client(&self) -> ProjectionClient {
        let admission = if self.cfg.admission.enabled {
            Some(ClientAdmission {
                bucket: Arc::new(Mutex::new(TokenBucket::new(
                    self.cfg.admission.rate_fps,
                    self.cfg.admission.burst,
                ))),
                epoch: self.epoch,
                max_wait: Duration::from_millis(self.cfg.admission.max_wait_ms),
                throttled: self.throttled.clone(),
            })
        } else {
            None
        };
        ProjectionClient {
            queue: self.queue.clone(),
            d_in: self.d_in,
            admission,
        }
    }

    /// Per-shard scheduled-slot seconds — the scheduler's timing
    /// attribution (`slots / frame_rate`), independent of each device's
    /// own clock.
    pub fn shard_slot_seconds(&self) -> Vec<f64> {
        self.slot_clocks.iter().map(|c| c.now_secs()).collect()
    }

    fn shutdown_inner(&mut self) {
        // Ordered drain: stop intake, let the scheduler drain the
        // central queue into the lanes, then close the lanes and let
        // each worker drain its lane.  No in-flight work is abandoned.
        self.queue.close();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        self.lanes.close_all();
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        for (shard, h) in self.workers.drain(..) {
            // A worker wedged inside a device call (stall-tripped)
            // never observes the closed lane; joining it would hang
            // shutdown, so it is detached and its in-flight frame and
            // queued lane are failed below — clients get errors, not
            // hangs.
            let wedged = self.cfg.failover.enabled
                && self.health[shard].stalled(self.cfg.failover.stall_ms, now_ms);
            if wedged {
                drop(h);
            } else {
                let _ = h.join();
            }
        }
        for shard in 0..self.lanes.count() {
            while let Some(job) = self.lanes.try_pop(shard) {
                let msg = format!("service shut down; shard {shard} unavailable");
                complete_part(&job.assembly, job.part, Err(msg));
            }
            if let Some((part, assembly)) = take_inflight(&self.inflight[shard]) {
                let msg = format!("service shut down; shard {shard} stalled mid-call");
                complete_part(&assembly, part, Err(msg));
            }
        }
    }

    /// Stop accepting requests, drain everything in flight, join all
    /// threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }
}

impl Drop for ShardedProjectionService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::projector::DigitalProjector;
    use crate::coordinator::topology::{DeviceKind, Topology};
    use crate::optics::medium::TransmissionMatrix;
    use crate::optics::stream::Medium;
    use crate::optics::OpuParams;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    fn digital_devices(
        medium: &TransmissionMatrix,
        shards: usize,
        partition: Partition,
    ) -> Vec<Box<dyn Projector + Send>> {
        Topology::homogeneous(DeviceKind::Digital, shards)
            .with_partition(partition)
            .build_devices(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                0,
                &Registry::new(),
            )
            .unwrap()
    }

    fn service(modes: usize, max_batch: usize) -> (ProjectionService, TransmissionMatrix) {
        let medium = TransmissionMatrix::sample(11, 10, modes);
        let dev = Box::new(DigitalProjector::new(medium.clone()));
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig {
                max_batch,
                queue_depth: 64,
            },
            Registry::new(),
        );
        (svc, medium)
    }

    fn tern(rows: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * 10)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, 10], data)
    }

    #[test]
    fn single_request_roundtrip() {
        let (svc, medium) = service(16, 32);
        let client = svc.client();
        let e = tern(4, 1);
        let (p1, _) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re));
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_get_their_own_answers() {
        let (svc, medium) = service(8, 16);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let client = svc.client();
                let medium = medium.clone();
                std::thread::spawn(move || {
                    for j in 0..5 {
                        let e = tern(3, 100 + i * 10 + j);
                        let (p1, p2) = client.project(e.clone()).unwrap();
                        assert_eq!(p1, matmul(&e, &medium.b_re), "client {i} req {j}");
                        assert_eq!(p2, matmul(&e, &medium.b_im));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn oversized_request_is_rejected_politely() {
        let (svc, _) = service(8, 16);
        let client = svc.client();
        let bad = Tensor::zeros(&[2, 7]); // wrong width
        assert!(client.submit(bad).is_err());
        let empty = Tensor::zeros(&[0, 10]);
        assert!(client.submit(empty).is_err());
        svc.shutdown();
    }

    #[test]
    fn sharded_oversized_request_passes_through_like_the_classic_path() {
        // A request larger than max_batch is never split: both services
        // schedule it as its own oversized frame sequence (the classic
        // path's behavior is pinned at tier 1 by
        // prop_service_preserves_payloads in rust/tests/props.rs).
        for partition in [Partition::Modes, Partition::Batch] {
            let (svc, medium, _) = sharded(partition, 2, 8, 16);
            let client = svc.client();
            let e = tern(17, 11); // 17 rows > max_batch 16
            let (p1, p2) = client.project(e.clone()).unwrap();
            assert_eq!(p1, matmul(&e, &medium.b_re), "{partition:?}");
            assert_eq!(p2, matmul(&e, &medium.b_im), "{partition:?}");
            svc.shutdown();
        }
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let (svc, _) = service(8, 16);
        let client = svc.client();
        svc.shutdown();
        assert!(client.project(tern(1, 0)).is_err());
    }

    #[test]
    fn device_error_propagates_to_all_in_batch() {
        // Non-ternary frames through an optical device error out.
        let medium = TransmissionMatrix::sample(11, 10, 8);
        let dev = Box::new(super::super::projector::NativeOpticalProjector::new(
            crate::optics::OpuParams::default(),
            medium,
            1,
        ));
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig::default(),
            Registry::new(),
        );
        let client = svc.client();
        let mut bad = tern(2, 3);
        bad.data_mut()[0] = 0.5;
        let err = client.project(bad).unwrap_err().to_string();
        assert!(err.contains("device error"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn sharded_farm_behind_the_service_matches_single_device() {
        // The farm is just another device to the service: dynamic
        // batching in front, mode sharding behind, payloads intact.
        let medium = TransmissionMatrix::sample(11, 10, 24);
        let farm = Box::new(
            Topology::homogeneous(DeviceKind::Digital, 4)
                .build_farm(
                    OpuParams::default(),
                    &Medium::Dense(medium.clone()),
                    0,
                    Registry::new(),
                )
                .unwrap(),
        );
        let svc = ProjectionService::start(
            farm,
            10,
            ServiceConfig {
                max_batch: 32,
                queue_depth: 64,
            },
            Registry::new(),
        );
        let client = svc.client();
        let replies: Vec<_> = (0..6)
            .map(|i| {
                let e = tern(3, 50 + i);
                (e.clone(), client.submit(e).unwrap())
            })
            .collect();
        for (e, reply) in replies {
            let (p1, p2) = reply.wait().unwrap().unwrap();
            assert_eq!(p1, matmul(&e, &medium.b_re));
            assert_eq!(p2, matmul(&e, &medium.b_im));
        }
        svc.shutdown();
    }

    fn sharded(
        partition: Partition,
        shards: usize,
        modes: usize,
        max_batch: usize,
    ) -> (ShardedProjectionService, TransmissionMatrix, Registry) {
        let medium = TransmissionMatrix::sample(19, 10, modes);
        let devices = digital_devices(&medium, shards, partition);
        let reg = Registry::new();
        let svc = ShardedProjectionService::start(
            devices,
            10,
            ShardServiceConfig {
                max_batch,
                queue_depth: 64,
                lane_depth: 4,
                partition,
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
        (svc, medium, reg)
    }

    #[test]
    fn sharded_roundtrip_under_both_partitions() {
        for partition in [Partition::Modes, Partition::Batch] {
            let (svc, medium, _) = sharded(partition, 4, 24, 32);
            let client = svc.client();
            let replies: Vec<_> = (0..6)
                .map(|i| {
                    let e = tern(3, 60 + i);
                    (e.clone(), client.submit(e).unwrap())
                })
                .collect();
            for (e, r) in replies {
                let (p1, p2) = r.wait().unwrap().unwrap();
                assert_eq!(p1, matmul(&e, &medium.b_re), "{partition:?}");
                assert_eq!(p2, matmul(&e, &medium.b_im), "{partition:?}");
            }
            svc.shutdown();
        }
    }

    #[test]
    fn batch_partition_slots_sum_to_client_rows() {
        let (svc, _, reg) = sharded(Partition::Batch, 4, 16, 64);
        let client = svc.client();
        let replies: Vec<_> = (0..5)
            .map(|i| client.submit(tern(4, 70 + i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        let slot_s = svc.shard_slot_seconds();
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_frames"], 20.0);
        let slot_sum: f64 = (0..4)
            .map(|i| snap[&format!("service_shard{i}_slots")])
            .sum();
        assert_eq!(slot_sum, 20.0);
        let frame_sum: f64 = (0..4)
            .map(|i| snap[&format!("service_shard{i}_frames")])
            .sum();
        assert_eq!(frame_sum, 20.0);
        // Scheduler-side slot clocks: slots / 1500 Hz, summed over shards.
        let total_slot_s: f64 = slot_s.iter().sum();
        assert!((total_slot_s - 20.0 / 1500.0).abs() < 1e-9);
    }

    #[test]
    fn modes_partition_charges_every_shard_per_frame() {
        let (svc, _, reg) = sharded(Partition::Modes, 3, 24, 64);
        let client = svc.client();
        let replies: Vec<_> = (0..4)
            .map(|i| client.submit(tern(2, 80 + i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_frames"], 8.0);
        for i in 0..3 {
            assert_eq!(snap[&format!("service_shard{i}_slots")], 8.0);
            assert_eq!(snap[&format!("service_shard{i}_frames")], 8.0);
        }
    }

    #[test]
    fn per_shard_metrics_roll_up_without_knowing_the_shard_count() {
        // Direct coverage for Registry::sum_counters/sum_gauges over the
        // service's per-shard names (previously only the soak exercised
        // this composition).
        let (svc, _, reg) = sharded(Partition::Batch, 4, 16, 64);
        let client = svc.client();
        let replies: Vec<_> = (0..5)
            .map(|i| client.submit(tern(4, 90 + i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        let slot_s = svc.shard_slot_seconds();
        svc.shutdown();
        assert_eq!(reg.sum_counters("service_shard", "_slots"), 20.0);
        assert_eq!(reg.sum_counters("service_shard", "_frames"), 20.0);
        // The gauge roll-up reproduces the scheduler's own clock view.
        let gauge_total = reg.sum_gauges("service_shard", "_slot_s");
        let clock_total: f64 = slot_s.iter().sum();
        assert!(
            (gauge_total - clock_total).abs() < 1e-12,
            "gauges {gauge_total} vs clocks {clock_total}"
        );
        assert!((clock_total - 20.0 / 1500.0).abs() < 1e-9);
        // Suffix discipline: _slots must not absorb _slot_s or frames.
        assert!(reg.sum_counters("service_shard", "_calls") > 0.0);
        assert_eq!(reg.sum_counters("service_shard", "_nope"), 0.0);
    }

    #[test]
    fn sharded_shutdown_rejects_new_requests() {
        let (svc, _, _) = sharded(Partition::Modes, 2, 8, 16);
        let client = svc.client();
        svc.shutdown();
        assert!(client.project(tern(1, 0)).is_err());
    }

    #[test]
    fn sharded_device_error_propagates_to_the_frame() {
        let medium = TransmissionMatrix::sample(20, 10, 8);
        let shards: Vec<Box<dyn Projector + Send>> = (0..2)
            .map(|i| {
                Box::new(
                    super::super::projector::NativeOpticalProjector::with_noise_stream(
                        crate::optics::OpuParams::default(),
                        medium.clone(),
                        3,
                        crate::optics::NOISE_STREAM_BASE + i as u64,
                    ),
                ) as Box<dyn Projector + Send>
            })
            .collect();
        let svc = ShardedProjectionService::start(
            shards,
            10,
            ShardServiceConfig {
                partition: Partition::Batch,
                ..Default::default()
            },
            Registry::new(),
        )
        .unwrap();
        let client = svc.client();
        let mut bad = tern(2, 3);
        bad.data_mut()[0] = 0.5; // not ternary: the SLM rejects it
        let err = client.project(bad).unwrap_err().to_string();
        assert!(err.contains("device error"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn over_farm_rejects_partition_mismatch() {
        let medium = TransmissionMatrix::sample(21, 10, 16);
        let farm = Topology::homogeneous(DeviceKind::Digital, 2)
            .build_farm(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                0,
                Registry::new(),
            )
            .unwrap();
        let cfg = ShardServiceConfig {
            partition: Partition::Batch,
            ..Default::default()
        };
        assert!(
            ShardedProjectionService::over_farm(farm, 10, cfg, Registry::new())
                .is_err()
        );
    }

    #[test]
    fn weighted_batch_scheduling_splits_rows_by_weight() {
        // 3:1 weights over two digital replicas: a 16-row frame sequence
        // schedules 12 rows on shard 0 and 4 on shard 1, and the reply
        // is still exactly the single-device projection.
        let medium = TransmissionMatrix::sample(23, 10, 16);
        let devices = digital_devices(&medium, 2, Partition::Batch);
        let reg = Registry::new();
        let svc = ShardedProjectionService::start_weighted(
            devices,
            vec![3, 1],
            10,
            ShardServiceConfig {
                max_batch: 64,
                queue_depth: 32,
                lane_depth: 4,
                partition: Partition::Batch,
                ..Default::default()
            },
            reg.clone(),
        )
        .unwrap();
        let client = svc.client();
        let e = tern(16, 5);
        let (p1, p2) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re));
        assert_eq!(p2, matmul(&e, &medium.b_im));
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_shard0_slots"], 12.0);
        assert_eq!(snap["service_shard1_slots"], 4.0);
        // Zero weights are rejected up front, not silently starved.
        let devices = digital_devices(&medium, 2, Partition::Batch);
        assert!(ShardedProjectionService::start_weighted(
            devices,
            vec![1, 0],
            10,
            ShardServiceConfig::default(),
            Registry::new(),
        )
        .is_err());
    }

    #[test]
    fn occupancy_ewma_converges_after_idle() {
        // The old gauge divided lifetime counters: an hour of empty
        // calls dragged utilization down forever.  The windowed EWMA
        // must converge to the true occupancy once the shard is busy.
        let mut ewma = Ewma::new(0.2);
        for _ in 0..1000 {
            ewma.observe(0.0);
        }
        assert!(ewma.value < 1e-6);
        let mut last = 0.0;
        for _ in 0..50 {
            last = ewma.observe(1.0);
        }
        assert!(last > 0.99, "idle-then-busy EWMA stuck at {last}");
        // And back: a busy-then-idle shard decays toward zero.
        for _ in 0..50 {
            last = ewma.observe(0.0);
        }
        assert!(last < 0.01, "busy-then-idle EWMA stuck at {last}");
    }

    #[test]
    fn token_bucket_refills_at_rate() {
        let mut b = TokenBucket::new(100.0, 10.0);
        // Burst drains immediately...
        assert!(b.try_take(10.0, 0.0).is_ok());
        // ...then a 5-frame ask must wait 50 ms.
        let wait = b.try_take(5.0, 0.0).unwrap_err();
        assert!((wait - 0.05).abs() < 1e-9, "{wait}");
        assert!(b.try_take(5.0, 0.05).is_ok());
        // A request wider than the whole burst is admitted at full
        // bucket rather than starved forever.
        let mut b = TokenBucket::new(100.0, 10.0);
        assert!(b.try_take(500.0, 0.0).is_ok());
        assert!(b.try_take(1.0, 0.0).is_err());
    }

    #[test]
    fn shard_health_trips_and_readmits() {
        let h = ShardHealth::new();
        assert!(h.routable());
        assert!(!h.note_error(3, 10));
        assert!(!h.note_error(3, 11));
        assert!(h.note_error(3, 12), "third consecutive error trips");
        assert_eq!(h.state(), STATE_TRIPPED);
        assert!(!h.routable());
        // Probation only after the delay...
        assert!(!h.maybe_readmit(100, 1000, 50));
        assert!(h.maybe_readmit(100, 1000, 120));
        assert_eq!(h.state(), STATE_PROBATION);
        assert!(h.routable());
        // ...one error on probation re-trips immediately...
        assert!(h.note_error(3, 130));
        assert_eq!(h.state(), STATE_TRIPPED);
        // ...and a success heals completely.
        assert!(h.maybe_readmit(100, 1000, 300));
        h.note_success();
        assert_eq!(h.state(), STATE_HEALTHY);
        // Stall detection: busy past the deadline, idle never.
        h.begin_call(1000);
        assert!(!h.stalled(500, 1400));
        assert!(h.stalled(500, 1600));
        h.end_call();
        assert!(!h.stalled(500, 1_000_000));
    }

    #[test]
    fn client_panic_mid_frame_does_not_wedge_the_lane() {
        // Regression for the poisoned-lock cascade: a client thread
        // panicking while holding assembly state must not kill the
        // shard worker that completes the frame — later clients on the
        // same lane must still be served.
        for partition in [Partition::Modes, Partition::Batch] {
            let (svc, medium, _) = sharded(partition, 2, 8, 16);
            let client = svc.client();
            let reply = client.submit(tern(2, 40)).unwrap();
            let _ = std::thread::spawn(move || {
                let _reply = reply;
                panic!("client dies holding its reply");
            })
            .join();
            // The lane must keep serving after the panicking client.
            for i in 0..4 {
                let e = tern(3, 41 + i);
                let (p1, _) = client.project(e.clone()).unwrap();
                assert_eq!(p1, matmul(&e, &medium.b_re), "{partition:?}");
            }
            svc.shutdown();
        }
    }

    #[test]
    fn complete_part_is_idempotent() {
        // A force-failed stalled part may complete again later; the
        // late result must not double-decrement pending or panic after
        // the frame finished.
        let reg = Registry::new();
        let (tx, rx) = oneshot::channel();
        let assembly = Arc::new(FrameAssembly {
            requests: Mutex::new(vec![Request {
                frames: tern(1, 0),
                submitted: Instant::now(),
                trace_frame: trace::NO_FRAME,
                reply: tx,
            }]),
            parts: Mutex::new(vec![None, None]),
            pending: AtomicUsize::new(2),
            partition: Partition::Modes,
            rows_total: 1,
            modes_total: 2,
            part_dims: vec![1, 1],
            trace_frame: trace::NO_FRAME,
            latency: reg.histogram("service_latency"),
        });
        complete_part(&assembly, 0, Err("forced stall failure".into()));
        // Late duplicate for part 0: dropped, pending still 1.
        complete_part(&assembly, 0, Ok((Tensor::zeros(&[1, 1]), Tensor::zeros(&[1, 1]))));
        assert_eq!(assembly.pending.load(Ordering::Acquire), 1);
        complete_part(&assembly, 1, Ok((Tensor::zeros(&[1, 1]), Tensor::zeros(&[1, 1]))));
        // The frame finished with the forced error; a straggler after
        // the finish (parts vec emptied) is also a no-op.
        complete_part(&assembly, 1, Err("straggler".into()));
        let err = rx.wait().unwrap().unwrap_err();
        assert!(err.contains("forced stall failure"), "{err}");
    }

    #[test]
    fn metrics_observe_batching() {
        let medium = TransmissionMatrix::sample(11, 10, 8);
        let dev = Box::new(DigitalProjector::new(medium));
        let reg = Registry::new();
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig {
                max_batch: 64,
                queue_depth: 64,
            },
            reg.clone(),
        );
        let client = svc.client();
        // Burst of requests: dispatcher should pack at least some.
        let replies: Vec<_> = (0..10)
            .map(|i| client.submit(tern(4, i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_frames"], 40.0);
        assert!(snap["service_batches"] >= 1.0);
        assert!(snap["service_batches"] <= 10.0);
    }
}
