//! The projection service: one shared projection device, many clients.
//!
//! The device behind the service is anything implementing
//! [`Projector`] + `Send` — a single OPU with a frame clock, or a
//! [`ProjectorFarm`](super::farm::ProjectorFarm) of N virtual devices
//! (the service's dynamic batching and the farm's mode sharding
//! compose: requests are packed into shared device batches, then each
//! batch fans out across the farm's shards).  Everything in the process
//! that needs a random projection — each ensemble member's trainer,
//! alignment probes, calibration — goes through this service.
//! A dispatcher thread drains the request queue and packs pending
//! requests into *shared device batches* (dynamic batching, the same
//! motif as vLLM's router at a different timescale: here the deadline is
//! the next camera frame).
//!
//! Invariants (property-tested below and in `rust/tests/`):
//! * every submitted frame is projected exactly once (no loss, no dup);
//! * rows within a request keep their order;
//! * replies are routed to the submitting client only;
//! * a batch never exceeds the configured device capacity.

use std::thread::JoinHandle;

use anyhow::Result;

use crate::exec::oneshot;
use crate::exec::queue::BoundedQueue;
use crate::metrics::Registry;
use crate::tensor::Tensor;

use super::projector::Projector;

/// One projection request: a few frames from one client.
struct Request {
    frames: Tensor,
    reply: oneshot::Sender<Result<(Tensor, Tensor), String>>,
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Max frames packed into one device call (SLM sequence depth).
    pub max_batch: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 128,
            queue_depth: 256,
        }
    }
}

/// Handle for submitting projection requests.
#[derive(Clone)]
pub struct ProjectionClient {
    queue: BoundedQueue<Request>,
    d_in: usize,
}

impl ProjectionClient {
    /// Submit frames `[B, d_in]`; returns a future for `(P1, P2)`.
    pub fn submit(
        &self,
        frames: Tensor,
    ) -> Result<oneshot::Reply<Result<(Tensor, Tensor), String>>> {
        anyhow::ensure!(
            frames.shape().len() == 2 && frames.cols() == self.d_in,
            "projection frames must be [b, {}], got {:?}",
            self.d_in,
            frames.shape()
        );
        anyhow::ensure!(frames.rows() > 0, "empty projection request");
        let (tx, rx) = oneshot::channel();
        self.queue
            .push(Request { frames, reply: tx })
            .map_err(|_| anyhow::anyhow!("projection service is shut down"))?;
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn project(&self, frames: Tensor) -> Result<(Tensor, Tensor)> {
        let reply = self.submit(frames)?;
        match reply.wait() {
            Some(Ok(pair)) => Ok(pair),
            Some(Err(e)) => anyhow::bail!("device error: {e}"),
            None => anyhow::bail!("projection service dropped the request"),
        }
    }
}

/// The running service (owns the dispatcher thread and the device).
pub struct ProjectionService {
    queue: BoundedQueue<Request>,
    dispatcher: Option<JoinHandle<()>>,
    d_in: usize,
}

impl ProjectionService {
    /// Start a service over a device.  `d_in` is the frame width.
    pub fn start(
        mut device: Box<dyn Projector + Send>,
        d_in: usize,
        cfg: ServiceConfig,
        metrics: Registry,
    ) -> ProjectionService {
        let queue: BoundedQueue<Request> = BoundedQueue::new(cfg.queue_depth);
        let q2 = queue.clone();
        let frames_ctr = metrics.counter("service_frames");
        let batches_ctr = metrics.counter("service_batches");
        let occupancy = metrics.histogram("service_batch_occupancy");
        let dispatcher = std::thread::Builder::new()
            .name("litl-projection-service".into())
            .spawn(move || {
                // Drain loop: block for the first request, then
                // opportunistically pack more pending ones (dynamic
                // batching up to max_batch frames).
                while let Some(first) = q2.pop() {
                    let mut batch: Vec<Request> = vec![first];
                    let mut total: usize = batch[0].frames.rows();
                    while total < cfg.max_batch {
                        match q2.try_pop() {
                            Some(req) if total + req.frames.rows() <= cfg.max_batch => {
                                total += req.frames.rows();
                                batch.push(req);
                            }
                            Some(req) => {
                                // Doesn't fit this frame sequence: flush,
                                // then start the next batch with it
                                // (re-queueing would reorder).
                                frames_ctr.add(total as u64);
                                batches_ctr.inc();
                                Self::run_batch(&mut *device, batch, &occupancy);
                                batch = vec![req];
                                total = batch[0].frames.rows();
                            }
                            None => break,
                        }
                    }
                    frames_ctr.add(total as u64);
                    batches_ctr.inc();
                    Self::run_batch(&mut *device, batch, &occupancy);
                }
            })
            .expect("spawn dispatcher");
        ProjectionService {
            queue,
            dispatcher: Some(dispatcher),
            d_in,
        }
    }

    fn run_batch(
        device: &mut dyn Projector,
        batch: Vec<Request>,
        occupancy: &crate::metrics::Histogram,
    ) {
        let rows: usize = batch.iter().map(|r| r.frames.rows()).sum();
        occupancy.observe(rows as f64);
        let d_in = batch[0].frames.cols();
        // Pack all requests into one device tensor.
        let mut packed = Tensor::zeros(&[rows, d_in]);
        let mut at = 0usize;
        for req in &batch {
            let n = req.frames.rows() * d_in;
            packed.data_mut()[at * d_in..at * d_in + n]
                .copy_from_slice(req.frames.data());
            at += req.frames.rows();
        }
        match device.project(&packed) {
            Ok((p1, p2)) => {
                // Slice replies back out, preserving request row order.
                let modes = device.modes();
                let mut row = 0usize;
                for req in batch {
                    let b = req.frames.rows();
                    let take = |src: &Tensor| {
                        Tensor::from_vec(
                            &[b, modes],
                            src.data()[row * modes..(row + b) * modes].to_vec(),
                        )
                    };
                    req.reply.send(Ok((take(&p1), take(&p2))));
                    row += b;
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in batch {
                    req.reply.send(Err(msg.clone()));
                }
            }
        }
    }

    /// Create a client handle.
    pub fn client(&self) -> ProjectionClient {
        ProjectionClient {
            queue: self.queue.clone(),
            d_in: self.d_in,
        }
    }

    /// Stop accepting requests and join the dispatcher.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ProjectionService {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::projector::DigitalProjector;
    use crate::optics::medium::TransmissionMatrix;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    fn service(modes: usize, max_batch: usize) -> (ProjectionService, TransmissionMatrix) {
        let medium = TransmissionMatrix::sample(11, 10, modes);
        let dev = Box::new(DigitalProjector::new(medium.clone()));
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig {
                max_batch,
                queue_depth: 64,
            },
            Registry::new(),
        );
        (svc, medium)
    }

    fn tern(rows: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * 10)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, 10], data)
    }

    #[test]
    fn single_request_roundtrip() {
        let (svc, medium) = service(16, 32);
        let client = svc.client();
        let e = tern(4, 1);
        let (p1, _) = client.project(e.clone()).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re));
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_get_their_own_answers() {
        let (svc, medium) = service(8, 16);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let client = svc.client();
                let medium = medium.clone();
                std::thread::spawn(move || {
                    for j in 0..5 {
                        let e = tern(3, 100 + i * 10 + j);
                        let (p1, p2) = client.project(e.clone()).unwrap();
                        assert_eq!(p1, matmul(&e, &medium.b_re), "client {i} req {j}");
                        assert_eq!(p2, matmul(&e, &medium.b_im));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn oversized_request_is_rejected_politely() {
        let (svc, _) = service(8, 16);
        let client = svc.client();
        let bad = Tensor::zeros(&[2, 7]); // wrong width
        assert!(client.submit(bad).is_err());
        let empty = Tensor::zeros(&[0, 10]);
        assert!(client.submit(empty).is_err());
        svc.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let (svc, _) = service(8, 16);
        let client = svc.client();
        svc.shutdown();
        assert!(client.project(tern(1, 0)).is_err());
    }

    #[test]
    fn device_error_propagates_to_all_in_batch() {
        // Non-ternary frames through an optical device error out.
        let medium = TransmissionMatrix::sample(11, 10, 8);
        let dev = Box::new(super::super::projector::NativeOpticalProjector::new(
            crate::optics::OpuParams::default(),
            medium,
            1,
        ));
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig::default(),
            Registry::new(),
        );
        let client = svc.client();
        let mut bad = tern(2, 3);
        bad.data_mut()[0] = 0.5;
        let err = client.project(bad).unwrap_err().to_string();
        assert!(err.contains("device error"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn sharded_farm_behind_the_service_matches_single_device() {
        // The farm is just another device to the service: dynamic
        // batching in front, mode sharding behind, payloads intact.
        let medium = TransmissionMatrix::sample(11, 10, 24);
        let farm = Box::new(
            crate::coordinator::farm::ProjectorFarm::digital(&medium, 4).unwrap(),
        );
        let svc = ProjectionService::start(
            farm,
            10,
            ServiceConfig {
                max_batch: 32,
                queue_depth: 64,
            },
            Registry::new(),
        );
        let client = svc.client();
        let replies: Vec<_> = (0..6)
            .map(|i| {
                let e = tern(3, 50 + i);
                (e.clone(), client.submit(e).unwrap())
            })
            .collect();
        for (e, reply) in replies {
            let (p1, p2) = reply.wait().unwrap().unwrap();
            assert_eq!(p1, matmul(&e, &medium.b_re));
            assert_eq!(p2, matmul(&e, &medium.b_im));
        }
        svc.shutdown();
    }

    #[test]
    fn metrics_observe_batching() {
        let medium = TransmissionMatrix::sample(11, 10, 8);
        let dev = Box::new(DigitalProjector::new(medium));
        let reg = Registry::new();
        let svc = ProjectionService::start(
            dev,
            10,
            ServiceConfig {
                max_batch: 64,
                queue_depth: 64,
            },
            reg.clone(),
        );
        let client = svc.client();
        // Burst of requests: dispatcher should pack at least some.
        let replies: Vec<_> = (0..10)
            .map(|i| client.submit(tern(4, i)).unwrap())
            .collect();
        for r in replies {
            r.wait().unwrap().unwrap();
        }
        svc.shutdown();
        let snap = reg.snapshot();
        assert_eq!(snap["service_frames"], 40.0);
        assert!(snap["service_batches"] >= 1.0);
        assert!(snap["service_batches"] <= 10.0);
    }
}
