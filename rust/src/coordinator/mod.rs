//! The hybrid analog-digital training coordinator — the paper's system.
//!
//! The paper's architecture (Fig. 1, right): the *forward* pass of the
//! network runs on silicon, the *feedback* path of DFA — a fixed random
//! projection of the output error — runs on the photonic co-processor,
//! and once training finishes the OPU is no longer needed.  This module
//! is the rust embodiment of that loop:
//!
//! * [`projector`] — the device abstraction: optical (native physics or
//!   HLO twin) and digital (exact) projectors behind one trait.
//! * [`topology`] — the declarative device graph: one validated
//!   [`topology::Topology`] descriptor (shard specs with device kind,
//!   service weight, optional mode range and noise stream; partition
//!   axis; medium backing; pool policy) replaces the farm's legacy
//!   constructor matrix.  `build_devices`/`build_farm`/
//!   `build_projector`/`build_service` are the one construction path;
//!   heterogeneous (mixed optical/digital) and weighted fleets fall out
//!   of the spec list.
//! * [`farm`] — the sharded multi-device layer: N virtual OPUs over
//!   contiguous mode ranges of one medium (`--partition modes`) or
//!   full-medium replicas serving contiguous batch-row ranges
//!   (`--partition batch`), executed concurrently on the `exec` pool and
//!   concatenated deterministically.  `shards=1` is bit-identical to the
//!   single-device path; `--shards N` on the CLI routes the trainer
//!   through it.
//! * [`service`] — the projection services: the device-agnostic
//!   [`service::ProjectionService`] (one dispatcher, dynamic frame
//!   batching, any `Projector` behind it) and the shard-aware
//!   [`service::ShardedProjectionService`] (a frame-slot scheduler that
//!   assigns client submissions to concrete (shard, frame-slot) pairs
//!   over per-shard bounded lanes and worker threads, coalescing small
//!   requests into shared frames and splitting large ones along the
//!   partition axis).  Concurrent clients (ensemble members, eval
//!   probes, ablation sweeps) share OPU frames; one optical frame
//!   carries the feedback for *every* hidden layer (re/im quadratures).
//! * [`trainer`] — the training loop over the AOT artifacts: forward →
//!   ternarize → optical projection → fused DFA+Adam apply; plus the
//!   fully-fused digital DFA and BP baselines.
//! * [`host`] — pure-rust reference trainers (test oracle + the CPU rows
//!   of E2/E3), including the per-layer *asynchronous* update scheduler
//!   that DFA enables ([`host::AsyncDfaTrainer`]).
//! * [`optim`] — host Adam (matches the fused kernel bit-for-tolerance).
//! * [`align`] — DFA↔BP gradient-alignment diagnostics (E5).
//! * [`checkpoint`] — model state serialization (own binary format).

pub mod align;
pub mod checkpoint;
pub mod farm;
pub mod host;
pub mod optim;
pub mod projector;
pub mod service;
pub mod topology;
pub mod trainer;

pub use farm::ProjectorFarm;
pub use projector::{DigitalProjector, HloOpticalProjector, NativeOpticalProjector, Projector};
pub use service::{
    AdaptConfig, AdmissionConfig, ClientProjector, FailoverConfig, ProjectionClient,
    ProjectionService, ServiceConfig, ShardRebuild, ShardServiceConfig, ShardedProjectionService,
};
pub use topology::{DeviceKind, PoolPolicy, ShardSpec, Topology};
pub use trainer::{EvalResult, TrainReport, Trainer};
