//! DFA alignment diagnostics (E5).
//!
//! The phenomenon behind DFA ("feedback *alignment*"): although `B` is
//! random, the network's forward weights evolve so that the DFA update
//! becomes positively correlated with the true gradient.  This module
//! measures per-layer `cos(δW_dfa, δW_bp)` on the host oracle — the same
//! quantity the `alignment` artifact computes in XLA.

use crate::tensor::{ternarize, Tensor};
use crate::util::stats::cosine;

use super::host::HostMlp;
use super::projector::Projector;

/// Per-layer alignment of the DFA update with the BP gradient.
#[derive(Clone, Copy, Debug)]
pub struct Alignment {
    pub layer1: f64,
    pub layer2: f64,
}

/// Measure alignment on one batch.  `theta < 0` uses the float error.
pub fn measure(
    mlp: &HostMlp,
    projector: &mut dyn Projector,
    x: &Tensor,
    yoh: &Tensor,
    theta: f32,
) -> anyhow::Result<Alignment> {
    let (bp, _) = mlp.bp_grads(x, yoh);
    let fwd = mlp.forward(x);
    let (_, e) = HostMlp::loss_err(&fwd.probs, yoh);
    let feedback = if theta >= 0.0 {
        ternarize(&e, theta)
    } else {
        e.clone()
    };
    let (p1, p2) = projector.project(&feedback)?;
    let dfa = mlp.dfa_grads(x, &fwd, &e, &p1, &p2);
    Ok(Alignment {
        layer1: cosine(dfa[0].data(), bp[0].data()),
        layer2: cosine(dfa[2].data(), bp[2].data()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::host::{HostAlgo, HostTrainer};
    use crate::coordinator::projector::DigitalProjector;
    use crate::optics::medium::TransmissionMatrix;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    fn task_batch(seed: u64, b: usize) -> (Tensor, Tensor) {
        let mut proto_rng = Pcg64::new(1234, 0);
        let proto = Tensor::randn(&[10, 20], &mut proto_rng, 1.0);
        let mut rng = Pcg64::seeded(seed);
        let x = Tensor::randn(&[b, 20], &mut rng, 1.0);
        let mut pt = Tensor::zeros(&[20, 10]);
        for i in 0..10 {
            for j in 0..20 {
                *pt.at_mut(j, i) = proto.at(i, j);
            }
        }
        let scores = matmul(&x, &pt);
        let mut yoh = Tensor::zeros(&[b, 10]);
        for r in 0..b {
            let row = scores.row(r);
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            *yoh.at_mut(r, best) = 1.0;
        }
        (x, yoh)
    }

    #[test]
    fn alignment_grows_with_training() {
        let layers = &[20usize, 16, 16, 10];
        let medium = TransmissionMatrix::sample(99, 10, 16);
        let mut tr = HostTrainer::new(
            0,
            layers,
            0.01,
            HostAlgo::DfaFloat,
            Box::new(DigitalProjector::new(medium.clone())),
        );
        let mut probe = DigitalProjector::new(medium);
        let (px, py) = task_batch(9999, 128);
        let before = measure(&tr.mlp, &mut probe, &px, &py, -1.0).unwrap();
        for t in 0..100 {
            let (x, y) = task_batch(500 + t, 64);
            tr.step(&x, &y).unwrap();
        }
        let after = measure(&tr.mlp, &mut probe, &px, &py, -1.0).unwrap();
        // The classic DFA result: alignment becomes clearly positive.
        assert!(
            after.layer1 > before.layer1.min(0.2) && after.layer1 > 0.1,
            "layer1: before={:.3} after={:.3}",
            before.layer1,
            after.layer1
        );
        assert!(after.layer2 > 0.1, "layer2 after={:.3}", after.layer2);
    }

    #[test]
    fn ternarization_degrades_alignment_mildly() {
        let layers = &[20usize, 16, 16, 10];
        let medium = TransmissionMatrix::sample(7, 10, 16);
        let mut tr = HostTrainer::new(
            1,
            layers,
            0.01,
            HostAlgo::DfaFloat,
            Box::new(DigitalProjector::new(medium.clone())),
        );
        for t in 0..80 {
            let (x, y) = task_batch(700 + t, 64);
            tr.step(&x, &y).unwrap();
        }
        let mut probe = DigitalProjector::new(medium);
        let (px, py) = task_batch(8888, 256);
        let float_a = measure(&tr.mlp, &mut probe, &px, &py, -1.0).unwrap();
        let tern_a = measure(&tr.mlp, &mut probe, &px, &py, 0.1).unwrap();
        // Ternary feedback stays positively aligned (it still learns)…
        assert!(tern_a.layer1 > 0.05, "{tern_a:?}");
        // …but not better than the float feedback by a wide margin.
        assert!(tern_a.layer1 < float_a.layer1 + 0.2);
    }
}
