//! The training loop over the AOT artifacts (the paper's experiment).
//!
//! One [`Trainer`] = one run of §III: a 784→H→H→10 tanh MLP trained with
//! Adam for N epochs under one of four feedback algorithms:
//!
//! | algo          | feedback path                                 | artifacts used |
//! |---------------|-----------------------------------------------|----------------|
//! | `bp`          | true gradients (Eq. 2)                        | `bp_step`      |
//! | `dfa-float`   | digital `B·e`, float error                    | `dfa_digital_step` (θ<0) |
//! | `dfa-ternary` | digital `B·e`, Eq. 4 ternary error            | `dfa_digital_step` (θ=0.1) |
//! | `optical`     | simulated OPU: light in the loop              | `fwd_train` + projector + `dfa_apply` |
//!
//! The optical path is the paper's contribution: the forward pass and
//! the weight update run in XLA ("silicon"), while the error projection
//! leaves the digital world through a [`Projector`] device.

use std::fmt::Write as _;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{Algo, MediumBacking, ProjectorKind, TrainConfig};
use crate::data::{Dataset, Split};
use crate::metrics::trace::{self, NO_SHARD};
use crate::metrics::{CsvWriter, Registry};
use crate::optics::medium::TransmissionMatrix;
use crate::optics::stream::{Medium, StreamedMedium, STREAM_CACHE_HITS, STREAM_CACHE_MISSES};
use crate::runtime::{Engine, Model};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

use super::projector::{HloOpticalProjector, Projector};

/// Rolling window for the periodic `--trace` summary line: wall time,
/// steps, and cache-counter baselines since the last line.
struct SummaryWindow {
    t0: Instant,
    steps: u64,
    hits0: u64,
    misses0: u64,
}

impl SummaryWindow {
    fn open(metrics: &Registry) -> SummaryWindow {
        SummaryWindow {
            t0: Instant::now(),
            steps: 0,
            hits0: metrics.counter(STREAM_CACHE_HITS).get(),
            misses0: metrics.counter(STREAM_CACHE_MISSES).get(),
        }
    }
}

/// Result of one evaluation pass.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub accuracy: f64,
    pub loss: f64,
    pub samples: usize,
}

/// Per-epoch record.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub mean_loss: f64,
    pub steps: u64,
    pub wall_seconds: f64,
    pub eval: Option<EvalResult>,
}

/// Full-run report (what EXPERIMENTS.md records).
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub algo: Algo,
    pub lr: f32,
    pub epochs: Vec<EpochStats>,
    pub final_eval: EvalResult,
    pub wall_seconds: f64,
    pub sim_device_seconds: f64,
    pub device_energy_joules: f64,
    pub frames: u64,
    pub num_params: usize,
}

impl TrainReport {
    pub fn final_accuracy_pct(&self) -> f64 {
        self.final_eval.accuracy * 100.0
    }
}

/// The hybrid training coordinator.
pub struct Trainer {
    pub cfg: TrainConfig,
    engine: Engine,
    model: Model,
    /// The medium *policy object* — `Medium::Dense` holds the tensors,
    /// `Medium::Streamed` holds the seed-defined window (the matrix
    /// exists only as its seed; the digital-DFA artifacts, which need
    /// the dense tensors, reject the streamed backing at construction).
    /// Streamed runs are first-class here, not an invisible `None`.
    medium: Medium,
    projector: Option<Box<dyn Projector>>,
    metrics: Registry,
    rng: Pcg64,
    step: u64,
    // Reused scalar tensors (hot path: no per-step allocation for these).
    lr_t: Tensor,
    theta_t: Tensor,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        Self::with_metrics(cfg, Registry::new())
    }

    pub fn with_metrics(cfg: TrainConfig, metrics: Registry) -> Result<Self> {
        let engine = Engine::new(&cfg.artifacts_dir)?;
        let model = Model::init(&engine, &cfg.artifact_config, cfg.seed)?;
        let bc = engine.manifest().config(&cfg.artifact_config)?.clone();
        let err_dim = engine.manifest().err_dim;

        // Projection-path configuration sanity — a pure function of the
        // config, shared with the CLI so `litl train` can fail fast
        // before touching artifacts.
        cfg.validate_projection()?;
        // The declarative device graph: the explicit `[topology]` when
        // given, else the homogeneous equivalent of the legacy
        // shards/partition/medium knobs (bit-identical construction —
        // one build path for everything).
        let topology = cfg.projection_topology();

        // The fixed random feedback matrices ARE the optical medium: the
        // digital baselines project through the same B quadratures, so
        // "optical vs digital" differs only by the physics (DESIGN.md
        // §2).  Under the streamed backing the dense tensors are never
        // built — the seed alone defines the matrix, and `medium` is the
        // policy object that says so.
        let medium_seed = cfg.seed ^ 0xB;
        let medium = match cfg.medium {
            MediumBacking::Materialized => Medium::Dense(TransmissionMatrix::sample(
                medium_seed,
                err_dim,
                bc.modes,
            )),
            MediumBacking::Streamed => {
                // Stripe count for the shared cache: explicit knob, or
                // (default 0) the next power of two at or above the
                // shared pool's thread count, so a fully loaded pool
                // rarely contends on one stripe lock.
                let pool = crate::exec::shared_pool();
                let stripes = if cfg.tile_cache_stripes == 0 {
                    pool.threads().max(1).next_power_of_two()
                } else {
                    cfg.tile_cache_stripes
                };
                Medium::Streamed(
                    StreamedMedium::new(medium_seed, err_dim, bc.modes)
                        .with_pool(pool)
                        .with_metrics(&metrics)
                        // Cross-step tile cache (--tile-cache-mb; 0 =
                        // off).  Attached before the topology carves
                        // windows, so every shard shares one budget and
                        // repeated training steps hit instead of
                        // regenerating.
                        .with_tile_cache_mb_striped(cfg.tile_cache_mb, stripes),
                )
            }
        };
        // Warm-start the shared tile cache before the topology carves
        // shard windows (clones share the cache Arc, so tiles loaded
        // here serve every shard).  `validate_projection` has already
        // required streamed backing + a cache budget for this knob.
        if let Some(path) = &cfg.tile_cache_load {
            if let Medium::Streamed(sm) = &medium {
                let cache = sm.tile_cache().ok_or_else(|| {
                    anyhow::anyhow!("--tile-cache-load needs --tile-cache-mb >= 1")
                })?;
                let n = cache
                    .load_snapshot(path)
                    .with_context(|| format!("loading tile cache snapshot {path}"))?;
                log::info!("tile cache warm-started: {n} tiles from {path}");
            }
        }
        let projector: Option<Box<dyn Projector>> = match cfg.algo {
            Algo::Optical => Some(match cfg.projector {
                ProjectorKind::OpticalHlo => {
                    let twin_engine = Engine::new(&cfg.artifacts_dir)?;
                    Box::new(HloOpticalProjector::new(
                        twin_engine,
                        &cfg.artifact_config,
                        medium
                            .dense()
                            .expect("hlo projector is materialized-only")
                            .clone(),
                        cfg.seed ^ 0xF00,
                    )?) as Box<dyn Projector>
                }
                // Native and digital projectors — single device, farm,
                // heterogeneous, weighted — are all one topology build.
                ProjectorKind::OpticalNative | ProjectorKind::Digital => {
                    let mut opu_params = engine.manifest().opu;
                    if let Some(n_ph) = cfg.n_ph {
                        opu_params.n_ph = n_ph;
                    }
                    if let Some(rs) = cfg.read_sigma {
                        opu_params.read_sigma = rs;
                    }
                    topology.build_projector(
                        opu_params,
                        &medium,
                        cfg.seed ^ 0xF00,
                        metrics.clone(),
                    )?
                }
            }),
            _ => None,
        };

        let theta = match cfg.algo {
            Algo::DfaFloat => -1.0,
            _ => cfg.theta,
        };
        Ok(Trainer {
            rng: Pcg64::new(cfg.seed ^ 0xDA7A, 1),
            lr_t: Tensor::scalar(cfg.lr),
            theta_t: Tensor::scalar(theta),
            engine,
            model,
            medium,
            projector,
            metrics,
            step: 0,
            cfg,
        })
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// The medium *policy object* behind this run's projection —
    /// [`Medium::Dense`] or [`Medium::Streamed`].  Callers that need the
    /// raw tensors use [`Medium::dense`]; streamed runs are visible here
    /// instead of hiding behind a `None`.
    pub fn medium(&self) -> &Medium {
        &self.medium
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Pre-compile every artifact this run will call (so the first step
    /// isn't a compile stall).
    pub fn warmup(&mut self) -> Result<()> {
        let c = self.cfg.artifact_config.clone();
        match self.cfg.algo {
            Algo::Bp => self.engine.prepare("bp_step", &c)?,
            Algo::DfaFloat | Algo::DfaTernary => {
                self.engine.prepare("dfa_digital_step", &c)?
            }
            Algo::Optical => {
                self.engine.prepare("fwd_train", &c)?;
                self.engine.prepare("dfa_apply", &c)?;
            }
        }
        self.engine.prepare("eval_batch", &c)?;
        Ok(())
    }

    /// One training step on a batch; returns the loss.
    pub fn train_step(&mut self, x: &Tensor, yoh: &Tensor) -> Result<f32> {
        self.model.t += 1.0;
        self.step += 1;
        let t_t = Tensor::scalar(self.model.t);
        let cfgname = self.cfg.artifact_config.clone();
        let loss = match self.cfg.algo {
            Algo::Bp => {
                let mut args = self.model.state_refs();
                args.extend([&t_t, &self.lr_t, x, yoh]);
                let outs = self.engine.call("bp_step", &cfgname, &args)?;
                let rest = self.model.update_state(outs)?;
                rest[0].data()[0]
            }
            Algo::DfaFloat | Algo::DfaTernary => {
                let tm = self
                    .medium
                    .dense()
                    .context("digital DFA requires a materialized medium")?;
                let mut args = self.model.state_refs();
                args.extend([
                    &t_t,
                    &self.lr_t,
                    x,
                    yoh,
                    &tm.b_re,
                    &tm.b_im,
                    &self.theta_t,
                ]);
                let outs = self.engine.call("dfa_digital_step", &cfgname, &args)?;
                let rest = self.model.update_state(outs)?;
                rest[0].data()[0]
            }
            Algo::Optical => {
                // Trace spans mirror the phase histograms, keyed by the
                // step index so a step's three phases group in Perfetto.
                // (1) digital forward → error (+ Eq. 4 ternarization)
                let t0 = Instant::now();
                let tr = trace::start();
                let mut args: Vec<&Tensor> = self.model.params.iter().collect();
                args.extend([x, yoh, &self.theta_t]);
                let outs = self.engine.call("fwd_train", &cfgname, &args)?;
                let [h1, h2, e, e_t, loss]: [Tensor; 5] = outs
                    .try_into()
                    .map_err(|_| anyhow::anyhow!("fwd_train output arity"))?;
                self.metrics
                    .histogram("phase_fwd_s")
                    .observe(t0.elapsed().as_secs_f64());
                trace::complete(trace::STAGE_TRAIN_FWD, self.step, NO_SHARD, tr);
                // (2) light in the loop: the OPU projects the error
                let t1 = Instant::now();
                let tr = trace::start();
                let projector =
                    self.projector.as_mut().context("optical algo needs projector")?;
                let (p1, p2) = projector.project(&e_t)?;
                self.metrics
                    .histogram("phase_project_s")
                    .observe(t1.elapsed().as_secs_f64());
                trace::complete(trace::STAGE_TRAIN_PROJECT, self.step, NO_SHARD, tr);
                // (3) digital fused DFA + Adam update
                let t2 = Instant::now();
                let tr = trace::start();
                let mut args = self.model.state_refs();
                args.extend([&t_t, &self.lr_t, x, &h1, &h2, &e, &p1, &p2]);
                let outs = self.engine.call("dfa_apply", &cfgname, &args)?;
                self.model.update_state(outs)?;
                self.metrics
                    .histogram("phase_apply_s")
                    .observe(t2.elapsed().as_secs_f64());
                trace::complete(trace::STAGE_TRAIN_APPLY, self.step, NO_SHARD, tr);
                loss.data()[0]
            }
        };
        self.metrics.gauge("train_loss").set(loss as f64);
        self.metrics.counter("train_steps").inc();
        Ok(loss)
    }

    /// Evaluate on a split using the `eval_batch` artifact.
    pub fn evaluate(&mut self, ds: &Dataset, split: Split) -> Result<EvalResult> {
        let cfgname = self.cfg.artifact_config.clone();
        let be = self.model.eval_batch;
        let mut correct = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut batches = 0usize;
        for idxs in ds.eval_batches(split, be) {
            let (x, yoh) = ds.gather(split, &idxs);
            let mut args: Vec<&Tensor> = self.model.params.iter().collect();
            args.extend([&x, &yoh]);
            let outs = self.engine.call("eval_batch", &cfgname, &args)?;
            correct += outs[0].data()[0] as f64;
            loss_sum += outs[1].data()[0] as f64;
            batches += 1;
        }
        let samples = batches * be; // includes wrap padding on the tail
        Ok(EvalResult {
            accuracy: correct / samples as f64,
            loss: loss_sum / batches as f64,
            samples,
        })
    }

    /// Full run: epochs × batches, periodic eval, optional CSV logging.
    pub fn run(&mut self, ds: &Dataset) -> Result<TrainReport> {
        // Resume: restore model + optimizer state, then fast-forward the
        // data pipeline past the steps the checkpoint already trained.
        // Skipped batches are still DRAWN from each epoch's shuffle
        // stream (and every epoch still splits the trainer rng once), so
        // the remaining schedule is bitwise the schedule an
        // uninterrupted run would have executed.
        let mut to_skip = 0u64;
        if let Some(path) = self.cfg.resume.clone() {
            self.load_checkpoint(&path)?;
            self.step = self.model.t as u64;
            to_skip = self.step;
            log::info!("resumed from {path}: continuing at step {}", self.step);
        }
        self.warmup()?;
        let batch = self.model.batch;
        let mut csv = match &self.cfg.out_dir {
            Some(dir) => Some(CsvWriter::create(
                &format!("{dir}/loss_{}.csv", self.cfg.algo.name()),
                &["step", "epoch", "loss", "wall_s", "sim_device_s"],
            )?),
            None => None,
        };
        let run_start = Instant::now();
        let mut epochs = Vec::new();
        let step_hist = self.metrics.histogram("step_seconds");
        let summary_every = self.cfg.summary_every_batches as u64;
        let mut summary = SummaryWindow::open(&self.metrics);

        for epoch in 0..self.cfg.epochs {
            let ep_start = Instant::now();
            let mut loss_sum = 0.0f64;
            let mut steps = 0u64;
            let mut shuffle_rng = self.rng.split();
            let mut batches = ds.batches(Split::Train, batch, &mut shuffle_rng);
            // Manual `next()` so the batch fetch itself gets a
            // `data_load` span (keyed by the step it feeds).
            loop {
                let tr = trace::start();
                let next = batches.next();
                trace::complete(trace::STAGE_DATA_LOAD, self.step + 1, NO_SHARD, tr);
                let Some((x, yoh)) = next else { break };
                if to_skip > 0 {
                    // Replayed prefix of a resumed run: the batch was
                    // consumed (the shuffle stream advances exactly as
                    // it did pre-kill) but was trained before the
                    // checkpoint, so it is not trained again.
                    to_skip -= 1;
                    continue;
                }
                let t0 = Instant::now();
                let loss = self.train_step(&x, &yoh)?;
                step_hist.observe(t0.elapsed().as_secs_f64());
                loss_sum += loss as f64;
                steps += 1;
                summary.steps += 1;
                if let Some(csv) = csv.as_mut() {
                    csv.row(&[
                        self.step as f64,
                        epoch as f64,
                        loss as f64,
                        run_start.elapsed().as_secs_f64(),
                        self.sim_device_seconds(),
                    ])?;
                }
                if summary_every > 0 && trace::enabled() && summary.steps >= summary_every
                {
                    summary = self.emit_trace_summary(summary, batch);
                }
                if self.cfg.eval_every > 0 && self.step % self.cfg.eval_every as u64 == 0
                {
                    let ev = self.evaluate(ds, Split::Test)?;
                    log::info!(
                        "step {}: loss={loss:.4} test_acc={:.2}%",
                        self.step,
                        ev.accuracy * 100.0
                    );
                }
            }
            let eval = Some(self.evaluate(ds, Split::Test)?);
            let stats = EpochStats {
                epoch,
                mean_loss: loss_sum / steps.max(1) as f64,
                steps,
                wall_seconds: ep_start.elapsed().as_secs_f64(),
                eval,
            };
            log::info!(
                "epoch {epoch}: loss={:.4} acc={:.2}% ({} steps, {:.1}s)",
                stats.mean_loss,
                stats.eval.unwrap().accuracy * 100.0,
                steps,
                stats.wall_seconds
            );
            epochs.push(stats);
        }
        if let Some(csv) = csv.as_mut() {
            csv.flush()?;
        }

        // Persist the resident TM tiles so the next run (or a projector
        // server) warm-starts with zero regeneration for cached tiles.
        if let Some(path) = &self.cfg.tile_cache_save {
            if let Medium::Streamed(sm) = &self.medium {
                if let Some(cache) = sm.tile_cache() {
                    cache
                        .save_snapshot(path)
                        .with_context(|| format!("saving tile cache snapshot {path}"))?;
                    log::info!(
                        "tile cache snapshot saved to {path} ({} tiles)",
                        cache.tiles_resident()
                    );
                }
            }
        }

        let final_eval = self.evaluate(ds, Split::Test)?;
        Ok(TrainReport {
            algo: self.cfg.algo,
            lr: self.cfg.lr,
            epochs,
            final_eval,
            wall_seconds: run_start.elapsed().as_secs_f64(),
            sim_device_seconds: self.sim_device_seconds(),
            device_energy_joules: self
                .projector
                .as_ref()
                .map(|p| p.energy_joules())
                .unwrap_or(0.0),
            frames: self.step * batch as u64,
            num_params: self.model.num_params(),
        })
    }

    /// Emit one human-readable telemetry line covering the window since
    /// `w` opened — frames/s, per-phase p50/p95/p99 (ms), tile-cache
    /// hit rate — and open the next window.  The phase histograms are
    /// `reset()` so each line reports fresh windowed percentiles; this
    /// only runs under `--trace summary|full` with a summary cadence
    /// configured, so default runs keep their lifetime histograms.
    fn emit_trace_summary(&self, w: SummaryWindow, batch: usize) -> SummaryWindow {
        let dt = w.t0.elapsed().as_secs_f64().max(1e-9);
        let fps = (w.steps * batch as u64) as f64 / dt;
        let mut line = format!("telemetry: {fps:.1} frames/s");
        for (label, name) in [
            ("fwd", "phase_fwd_s"),
            ("project", "phase_project_s"),
            ("apply", "phase_apply_s"),
            ("step", "step_seconds"),
        ] {
            let h = self.metrics.histogram(name);
            if h.count() == 0 {
                continue;
            }
            let _ = write!(
                line,
                " | {label} p50/p95/p99 {:.2}/{:.2}/{:.2} ms",
                h.percentile(50.0) * 1e3,
                h.percentile(95.0) * 1e3,
                h.percentile(99.0) * 1e3,
            );
            h.reset();
        }
        let hits = self.metrics.counter(STREAM_CACHE_HITS).get();
        let misses = self.metrics.counter(STREAM_CACHE_MISSES).get();
        let (dh, dm) = (hits - w.hits0, misses - w.misses0);
        if dh + dm > 0 {
            let _ = write!(
                line,
                " | cache hit {:.1}%",
                100.0 * dh as f64 / (dh + dm) as f64
            );
        }
        log::info!("{line}");
        SummaryWindow::open(&self.metrics)
    }

    /// Simulated projector-device seconds (0 for fused digital paths).
    pub fn sim_device_seconds(&self) -> f64 {
        self.projector.as_ref().map(|p| p.sim_seconds()).unwrap_or(0.0)
    }

    /// Save model + optimizer state.
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let tensors = self.model.state_refs();
        super::checkpoint::save(path, &tensors, self.model.t)
    }

    /// Restore model + optimizer state.
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let (tensors, t) = super::checkpoint::load(path)?;
        anyhow::ensure!(
            tensors.len() == 18,
            "checkpoint has {} tensors, expected 18",
            tensors.len()
        );
        let mut it = tensors.into_iter();
        for slot in self
            .model
            .params
            .iter_mut()
            .chain(self.model.m.iter_mut())
            .chain(self.model.v.iter_mut())
        {
            let t = it.next().unwrap();
            anyhow::ensure!(
                t.shape() == slot.shape(),
                "checkpoint shape {:?} vs model {:?}",
                t.shape(),
                slot.shape()
            );
            *slot = t;
        }
        self.model.t = t;
        Ok(())
    }
}
