//! The projector farm: N virtual OPU devices behind one [`Projector`].
//!
//! The paper's scaling story ("Perspectives": inputs and outputs up to
//! 1e6, trillion-parameter projections) outgrows a single camera region.
//! A [`ProjectorFarm`] models the next step the follow-up work takes —
//! multiple co-processors driven as one logical device — by sharding the
//! **output-mode axis** across virtual devices:
//!
//! ```text
//!            ┌── shard 0: medium[:, 0..m₀]    → OPU₀ ─┐
//!  e [B,d]──▶│   shard 1: medium[:, m₀..m₁]   → OPU₁  ├──▶ concat → [B, modes]
//!            └── shard k: medium[:, …]        → OPUₖ ─┘
//! ```
//!
//! Every shard owns its own [`TransmissionMatrix`] slice, camera-noise
//! RNG *stream* (same seed, decorrelated draws), simulated clock and
//! energy account; shards execute concurrently on an
//! [`exec::ThreadPool`] scope and the per-shard quadratures are
//! concatenated in shard order — results are deterministic for a given
//! seed regardless of scheduling.
//!
//! Invariants (tested here and in `rust/tests/farm_parity.rs`):
//! * `shards == 1` is **bit-identical** to the plain single-device path;
//! * at any shard count, the farm equals a single device over the
//!   equivalent stacked medium (exactly for digital shards; to fp/ADC
//!   tolerance for noiseless optical shards);
//! * `sim_seconds()`/`energy_joules()` are *device-second* sums over
//!   shards (capacity accounting); `sim_seconds_wall()` is their max
//!   (what a wall clock would see, since shards run in parallel);
//! * a panicking shard is contained: the batch fails with an error, the
//!   panic is counted on the pool and surfaced through `metrics/`.
//!
//! [`exec::ThreadPool`]: crate::exec::ThreadPool

use std::sync::Arc;

use anyhow::Result;

use crate::exec::ThreadPool;
use crate::metrics::{Counter, Registry};
use crate::optics::medium::TransmissionMatrix;
use crate::optics::{OpuParams, NOISE_STREAM_BASE};
use crate::tensor::Tensor;

use super::projector::{DigitalProjector, NativeOpticalProjector, Projector};

/// Metric name for shard batch failures (panic or device error).
pub const SHARD_FAILURES: &str = "farm_shard_failures";
/// Metric name for farm batches executed.
pub const FARM_BATCHES: &str = "farm_batches";

/// A sharded, batched projection layer over N virtual devices.
pub struct ProjectorFarm {
    shards: Vec<Box<dyn Projector + Send>>,
    mode_counts: Vec<usize>,
    modes_total: usize,
    pool: Arc<ThreadPool>,
    kind: &'static str,
    shard_failures: Counter,
    batches: Counter,
}

fn default_pool(shards: usize, registry: &Registry) -> Arc<ThreadPool> {
    let cores = crate::exec::host_cores();
    Arc::new(ThreadPool::with_registry(
        shards.clamp(1, cores),
        2 * shards.max(1),
        registry,
    ))
}

impl ProjectorFarm {
    /// Optical farm: `shards` simulated OPUs over contiguous mode ranges
    /// of `medium`.  Shard `i` draws camera noise from PCG stream
    /// `NOISE_STREAM_BASE + i` of `noise_seed`, so `shards=1` reproduces
    /// the standalone [`NativeOpticalProjector`] bit-for-bit.
    pub fn optical(
        params: OpuParams,
        medium: &TransmissionMatrix,
        noise_seed: u64,
        shards: usize,
    ) -> Result<Self> {
        Self::optical_with(params, medium, noise_seed, shards, Registry::new())
    }

    /// [`ProjectorFarm::optical`] with an explicit metrics registry (the
    /// trainer passes its own so shard failures land next to the
    /// training counters).
    pub fn optical_with(
        params: OpuParams,
        medium: &TransmissionMatrix,
        noise_seed: u64,
        shards: usize,
        registry: Registry,
    ) -> Result<Self> {
        anyhow::ensure!(shards >= 1, "farm needs at least one shard");
        anyhow::ensure!(
            shards <= medium.modes,
            "cannot shard {} modes across {shards} devices",
            medium.modes
        );
        let devices: Vec<Box<dyn Projector + Send>> = medium
            .split_modes(shards)
            .into_iter()
            .enumerate()
            .map(|(i, slice)| {
                Box::new(NativeOpticalProjector::with_noise_stream(
                    params,
                    slice,
                    noise_seed,
                    NOISE_STREAM_BASE + i as u64,
                )) as Box<dyn Projector + Send>
            })
            .collect();
        Self::from_shards(devices, "farm-optical", registry)
    }

    /// Digital farm: the silicon comparator sharded the same way.
    /// Exactly equal (not just within tolerance) to a single
    /// [`DigitalProjector`] over the full medium, because each output
    /// column's dot product is computed identically either way.
    pub fn digital(medium: &TransmissionMatrix, shards: usize) -> Result<Self> {
        Self::digital_with(medium, shards, Registry::new())
    }

    /// [`ProjectorFarm::digital`] with an explicit metrics registry.
    pub fn digital_with(
        medium: &TransmissionMatrix,
        shards: usize,
        registry: Registry,
    ) -> Result<Self> {
        anyhow::ensure!(shards >= 1, "farm needs at least one shard");
        anyhow::ensure!(
            shards <= medium.modes,
            "cannot shard {} modes across {shards} devices",
            medium.modes
        );
        let devices: Vec<Box<dyn Projector + Send>> = medium
            .split_modes(shards)
            .into_iter()
            .map(|slice| Box::new(DigitalProjector::new(slice)) as Box<dyn Projector + Send>)
            .collect();
        Self::from_shards(devices, "farm-digital", registry)
    }

    /// Assemble a farm from pre-built shard devices (mode ranges are
    /// taken from each device's `modes()`; outputs concatenate in shard
    /// order).  The execution pool is sized to the shard count.
    pub fn from_shards(
        shards: Vec<Box<dyn Projector + Send>>,
        kind: &'static str,
        registry: Registry,
    ) -> Result<Self> {
        let pool = default_pool(shards.len(), &registry);
        Self::from_shards_pooled(shards, kind, registry, pool)
    }

    /// [`ProjectorFarm::from_shards`] over a caller-supplied pool, so
    /// several farms/components in one process can share worker threads.
    /// Note: shard panics are counted on the *supplied pool's* registry
    /// (wherever it was built with [`ThreadPool::with_registry`]), while
    /// [`SHARD_FAILURES`]/[`FARM_BATCHES`] land on `registry`.
    pub fn from_shards_pooled(
        shards: Vec<Box<dyn Projector + Send>>,
        kind: &'static str,
        registry: Registry,
        pool: Arc<ThreadPool>,
    ) -> Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "farm needs at least one shard");
        let mode_counts: Vec<usize> = shards.iter().map(|s| s.modes()).collect();
        let modes_total = mode_counts.iter().sum();
        Ok(ProjectorFarm {
            shards,
            mode_counts,
            modes_total,
            pool,
            kind,
            shard_failures: registry.counter(SHARD_FAILURES),
            batches: registry.counter(FARM_BATCHES),
        })
    }

    /// Number of virtual devices.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Mode count of each shard, in concatenation order.
    pub fn mode_counts(&self) -> &[usize] {
        &self.mode_counts
    }

    /// Per-shard simulated device-seconds.
    pub fn shard_sim_seconds(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.sim_seconds()).collect()
    }

    /// Wall-clock view of simulated time: shards expose concurrently, so
    /// the farm's critical path is the slowest shard.
    pub fn sim_seconds_wall(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| s.sim_seconds())
            .fold(0.0, f64::max)
    }

    /// The shared execution pool (shard panics are counted here).
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }
}

impl Projector for ProjectorFarm {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        self.batches.inc();
        // All shard counts (including 1) take the same scoped path, so
        // panic containment and failure accounting are uniform.  Bit
        // parity at `shards=1` holds because the gather is a pure copy
        // of the single shard's output.
        let b = frames.rows();
        let n = self.shards.len();
        // One result slot per shard; slots are disjoint `&mut`s handed
        // to the scoped shard jobs, so no locking and a deterministic
        // gather order.  `None` after the scope means the shard job
        // panicked (the pool contains and counts the panic).
        let mut slots: Vec<Option<Result<(Tensor, Tensor)>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        self.pool.scope(|scope| {
            for (shard, slot) in self.shards.iter_mut().zip(slots.iter_mut()) {
                scope.submit(move || {
                    *slot = Some(shard.project(frames));
                });
            }
        });

        // Inspect every slot before failing, so concurrent shard
        // failures are all counted (the pool's panic counter and
        // SHARD_FAILURES must agree batch by batch).
        let mut outputs: Vec<(Tensor, Tensor)> = Vec::with_capacity(n);
        let mut failures: Vec<String> = Vec::new();
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(pair)) => outputs.push(pair),
                Some(Err(e)) => failures.push(format!("shard {i}: {e:#}")),
                None => failures.push(format!(
                    "shard {i}: panicked (contained; see pool panic counter)"
                )),
            }
        }
        if !failures.is_empty() {
            self.shard_failures.add(failures.len() as u64);
            anyhow::bail!(
                "farm batch failed on {}/{n} shards: {}",
                failures.len(),
                failures.join("; ")
            );
        }

        let mut p1 = Tensor::zeros(&[b, self.modes_total]);
        let mut p2 = Tensor::zeros(&[b, self.modes_total]);
        let mut col = 0usize;
        for ((s1, s2), &mc) in outputs.iter().zip(&self.mode_counts) {
            debug_assert_eq!(s1.shape(), &[b, mc]);
            for r in 0..b {
                let dst = r * self.modes_total + col;
                p1.data_mut()[dst..dst + mc]
                    .copy_from_slice(&s1.data()[r * mc..(r + 1) * mc]);
                p2.data_mut()[dst..dst + mc]
                    .copy_from_slice(&s2.data()[r * mc..(r + 1) * mc]);
            }
            col += mc;
        }
        Ok((p1, p2))
    }

    fn modes(&self) -> usize {
        self.modes_total
    }

    /// Device-seconds summed over shards (N devices each charge their
    /// own frame clock; capacity accounting, not wall clock).
    fn sim_seconds(&self) -> f64 {
        self.shards.iter().map(|s| s.sim_seconds()).sum()
    }

    fn energy_joules(&self) -> f64 {
        self.shards.iter().map(|s| s.energy_joules()).sum()
    }

    fn kind(&self) -> &'static str {
        self.kind
    }

    fn requires_ternary(&self) -> bool {
        self.shards.iter().any(|s| s.requires_ternary())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    fn tern(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, cols], data)
    }

    fn noiseless() -> OpuParams {
        OpuParams {
            n_ph: -1.0,
            read_sigma: 0.0,
            ..OpuParams::default()
        }
    }

    #[test]
    fn one_shard_optical_is_bit_identical_to_single_device() {
        let medium = TransmissionMatrix::sample(5, 10, 32);
        let mut single =
            NativeOpticalProjector::new(OpuParams::default(), medium.clone(), 77);
        let mut farm = ProjectorFarm::optical(OpuParams::default(), &medium, 77, 1).unwrap();
        let e = tern(6, 10, 1);
        let (s1, s2) = single.project(&e).unwrap();
        let (f1, f2) = farm.project(&e).unwrap();
        assert_eq!(s1, f1);
        assert_eq!(s2, f2);
        assert_eq!(single.sim_seconds(), farm.sim_seconds());
        assert_eq!(single.energy_joules(), farm.energy_joules());
    }

    #[test]
    fn digital_farm_equals_stacked_single_device_exactly() {
        let medium = TransmissionMatrix::sample(6, 10, 40);
        let e = tern(5, 10, 2);
        let want1 = matmul(&e, &medium.b_re);
        let want2 = matmul(&e, &medium.b_im);
        for shards in [2usize, 4, 7] {
            let mut farm = ProjectorFarm::digital(&medium, shards).unwrap();
            let (p1, p2) = farm.project(&e).unwrap();
            assert_eq!(p1, want1, "{shards} shards");
            assert_eq!(p2, want2, "{shards} shards");
        }
    }

    #[test]
    fn noiseless_optical_farm_matches_stacked_device() {
        let medium = TransmissionMatrix::sample(7, 10, 48);
        let e = tern(4, 10, 3);
        let mut single = NativeOpticalProjector::new(noiseless(), medium.clone(), 5);
        let (want1, want2) = single.project(&e).unwrap();
        for shards in [2usize, 4, 7] {
            let mut farm = ProjectorFarm::optical(noiseless(), &medium, 5, shards).unwrap();
            let (p1, p2) = farm.project(&e).unwrap();
            // Noise off → the physics is deterministic and column-local,
            // so sharding cannot change any output mode.
            assert!(p1.max_abs_diff(&want1) < 1e-5, "{shards} shards");
            assert!(p2.max_abs_diff(&want2) < 1e-5, "{shards} shards");
        }
    }

    #[test]
    fn accounting_sums_across_shards() {
        let medium = TransmissionMatrix::sample(8, 10, 30);
        let mut farm = ProjectorFarm::optical(OpuParams::default(), &medium, 9, 3).unwrap();
        let e = tern(12, 10, 4);
        farm.project(&e).unwrap();
        // Each of the 3 virtual devices exposes 12 frames at 1.5 kHz.
        let per_shard = 12.0 / 1500.0;
        let shard_secs = farm.shard_sim_seconds();
        assert_eq!(shard_secs.len(), 3);
        for s in &shard_secs {
            assert!((s - per_shard).abs() < 1e-12);
        }
        assert!((farm.sim_seconds() - 3.0 * per_shard).abs() < 1e-12);
        assert!((farm.sim_seconds_wall() - per_shard).abs() < 1e-12);
        assert!((farm.energy_joules() - 3.0 * per_shard * 30.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_farm_is_deterministic_per_seed_and_decorrelated_across_shards() {
        let medium = TransmissionMatrix::sample(9, 10, 24);
        let e = tern(4, 10, 5);
        let run = |seed: u64| {
            let mut farm = ProjectorFarm::optical(OpuParams::default(), &medium, seed, 4).unwrap();
            farm.project(&e).unwrap().0
        };
        assert_eq!(run(11), run(11), "same seed, same result");
        assert_ne!(run(11), run(12), "different noise seeds differ");
    }

    struct PanickingShard;

    impl Projector for PanickingShard {
        fn project(&mut self, _: &Tensor) -> Result<(Tensor, Tensor)> {
            panic!("injected shard crash");
        }
        fn modes(&self) -> usize {
            4
        }
        fn sim_seconds(&self) -> f64 {
            0.0
        }
        fn energy_joules(&self) -> f64 {
            0.0
        }
        fn kind(&self) -> &'static str {
            "panicking"
        }
    }

    #[test]
    fn shard_failure_is_contained_and_observable() {
        let medium = TransmissionMatrix::sample(10, 10, 8);
        let registry = Registry::new();
        let shards: Vec<Box<dyn Projector + Send>> = vec![
            Box::new(DigitalProjector::new(medium.clone())),
            Box::new(PanickingShard),
        ];
        let mut farm =
            ProjectorFarm::from_shards(shards, "farm-test", registry.clone()).unwrap();
        let err = farm.project(&tern(2, 10, 6)).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        let snap = registry.snapshot();
        assert_eq!(snap[SHARD_FAILURES], 1.0);
        assert_eq!(snap[crate::exec::pool::PANIC_COUNTER], 1.0);
        assert_eq!(farm.pool().panic_count(), 1);
        // The farm object stays usable for the next batch.
        assert_eq!(farm.modes(), medium.modes + 4);
    }

    #[test]
    fn concurrent_shard_failures_are_all_counted() {
        let registry = Registry::new();
        let shards: Vec<Box<dyn Projector + Send>> = vec![
            Box::new(PanickingShard),
            Box::new(DigitalProjector::new(TransmissionMatrix::sample(1, 10, 8))),
            Box::new(PanickingShard),
            Box::new(PanickingShard),
        ];
        let mut farm =
            ProjectorFarm::from_shards(shards, "farm-test", registry.clone()).unwrap();
        let err = farm.project(&tern(2, 10, 8)).unwrap_err().to_string();
        assert!(err.contains("3/4 shards"), "{err}");
        let snap = registry.snapshot();
        assert_eq!(snap[SHARD_FAILURES], 3.0);
        assert_eq!(snap[crate::exec::pool::PANIC_COUNTER], 3.0);
    }

    #[test]
    fn one_shard_panic_is_contained_too() {
        // No fast path may bypass containment: a 1-shard farm must turn
        // a device panic into an error, same as any other shard count.
        let registry = Registry::new();
        let shards: Vec<Box<dyn Projector + Send>> = vec![Box::new(PanickingShard)];
        let mut farm = ProjectorFarm::from_shards(shards, "farm-test", registry.clone()).unwrap();
        let err = farm.project(&tern(2, 10, 7)).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        assert_eq!(registry.snapshot()[SHARD_FAILURES], 1.0);
    }

    #[test]
    fn rejects_more_shards_than_modes() {
        let medium = TransmissionMatrix::sample(1, 10, 4);
        assert!(ProjectorFarm::optical(OpuParams::default(), &medium, 1, 5).is_err());
        assert!(ProjectorFarm::digital(&medium, 0).is_err());
    }

    #[test]
    fn requires_ternary_follows_the_shards() {
        let medium = TransmissionMatrix::sample(2, 10, 16);
        let optical = ProjectorFarm::optical(OpuParams::default(), &medium, 1, 2).unwrap();
        assert!(optical.requires_ternary());
        let digital = ProjectorFarm::digital(&medium, 2).unwrap();
        assert!(!digital.requires_ternary());
    }
}
