//! The projector farm: N virtual OPU devices behind one [`Projector`].
//!
//! The paper's scaling story ("Perspectives": inputs and outputs up to
//! 1e6, trillion-parameter projections) outgrows a single camera region.
//! A [`ProjectorFarm`] models the next step the follow-up work takes —
//! multiple co-processors driven as one logical device — by sharding the
//! **output-mode axis** across virtual devices:
//!
//! ```text
//!            ┌── shard 0: medium[:, 0..m₀]    → OPU₀ ─┐
//!  e [B,d]──▶│   shard 1: medium[:, m₀..m₁]   → OPU₁  ├──▶ concat → [B, modes]
//!            └── shard k: medium[:, …]        → OPUₖ ─┘
//! ```
//!
//! Every shard owns its own [`TransmissionMatrix`] slice, camera-noise
//! RNG *stream* (same seed, decorrelated draws), simulated clock and
//! energy account; shards execute concurrently on an
//! [`exec::ThreadPool`] scope and the per-shard quadratures are
//! concatenated in shard order — results are deterministic for a given
//! seed regardless of scheduling.
//!
//! Two [`Partition`] policies are supported (the `--partition` switch):
//!
//! * [`Partition::Modes`] — the diagram above: every shard sees every
//!   frame and images a contiguous slice of the output modes.
//! * [`Partition::Batch`] — each shard holds a full-medium replica and
//!   exposes a contiguous **row range** of the batch (the ROADMAP's
//!   batch-axis sharding, for small-mode / large-batch regimes); shard
//!   outputs concatenate along rows.
//!
//! Construction now goes through the declarative
//! [`Topology`](super::topology::Topology) descriptor — the legacy
//! constructor matrix below (`optical`, `digital_partitioned_backed`,
//! …) survives as thin `#[deprecated]` shims over it.  Note the
//! `&TransmissionMatrix` shims clone the dense matrix into an owned
//! [`Medium::Dense`] before windowing (a transient full-matrix copy the
//! old constructors avoided) — new code should hold a [`Medium`] and
//! call `Topology::build_*` directly.  A farm carries
//! per-shard **service weights** ([`ProjectorFarm::weights`]): under the
//! batch partition rows split proportionally to them
//! ([`crate::util::weighted_widths`]), and equal weights reproduce the
//! historical even split bit for bit.
//!
//! Invariants (tested here and in `rust/tests/farm_parity.rs` /
//! `rust/tests/service_schedule.rs`):
//! * `shards == 1` is **bit-identical** to the plain single-device path
//!   under either partition;
//! * at any shard count, the farm equals a single device over the
//!   equivalent stacked medium (exactly for digital shards; to fp/ADC
//!   tolerance for noiseless optical shards) — for the batch partition
//!   the digital farm is exact at any shard count because the host
//!   matmul is row-local;
//! * `sim_seconds()`/`energy_joules()` are *device-second* sums over
//!   shards (capacity accounting); `sim_seconds_wall()` is their max
//!   (what a wall clock would see, since shards run in parallel);
//! * a panicking shard is contained: the batch fails with an error, the
//!   panic is counted on the pool and surfaced through `metrics/`.
//!
//! Panic containment here is per-*call*: the farm fails the batch and
//! stays usable, but a shard that keeps failing keeps getting work.
//! The serving-path escalation of the same policy — trip a repeatedly
//! failing or stalled shard, drain its lane onto survivors, re-admit it
//! on probation — lives in the service control plane
//! ([`ShardedProjectionService`](super::service::ShardedProjectionService),
//! `FailoverConfig`), which `Topology::build_service` wires up with a
//! device rebuild factory over this same build path.
//!
//! [`exec::ThreadPool`]: crate::exec::ThreadPool

use std::sync::Arc;

use anyhow::Result;

use crate::config::Partition;
use crate::exec::ThreadPool;
use crate::metrics::{Counter, Registry};
use crate::optics::medium::TransmissionMatrix;
use crate::optics::stream::Medium;
use crate::optics::OpuParams;
use crate::tensor::Tensor;
use crate::util::weighted_widths;

use super::projector::Projector;
use super::topology::{DeviceKind, Topology};

/// Metric name for shard batch failures (panic or device error).
pub const SHARD_FAILURES: &str = "farm_shard_failures";
/// Metric name for farm batches executed.
pub const FARM_BATCHES: &str = "farm_batches";

/// A sharded, batched projection layer over N virtual devices.
pub struct ProjectorFarm {
    shards: Vec<Box<dyn Projector + Send>>,
    mode_counts: Vec<usize>,
    modes_total: usize,
    /// Relative service weights, shard order.  The batch partition
    /// splits rows proportionally to these
    /// ([`crate::util::weighted_widths`]); all-equal weights reproduce
    /// the historical even split bit for bit.
    weights: Vec<u32>,
    pool: Arc<ThreadPool>,
    kind: &'static str,
    partition: Partition,
    /// Completed frame slots per shard (one slot = one row exposed on
    /// that virtual device's display/camera sequence).
    slot_counts: Vec<u64>,
    shard_failures: Counter,
    batches: Counter,
}

/// Concatenate per-part quadrature pairs along the mode axis: part `i`
/// is `[rows, dims[i]]`, the result `[rows, dims.sum()]`.  The single
/// gather implementation behind both the farm's mode partition and the
/// sharded service's frame assembly.
pub(crate) fn concat_mode_parts(
    parts: &[(Tensor, Tensor)],
    dims: &[usize],
    rows: usize,
) -> (Tensor, Tensor) {
    let total: usize = dims.iter().sum();
    let mut p1 = Tensor::zeros(&[rows, total]);
    let mut p2 = Tensor::zeros(&[rows, total]);
    let mut col = 0usize;
    for ((s1, s2), &mc) in parts.iter().zip(dims) {
        debug_assert_eq!(s1.shape(), &[rows, mc]);
        for r in 0..rows {
            let dst = r * total + col;
            p1.data_mut()[dst..dst + mc]
                .copy_from_slice(&s1.data()[r * mc..(r + 1) * mc]);
            p2.data_mut()[dst..dst + mc]
                .copy_from_slice(&s2.data()[r * mc..(r + 1) * mc]);
        }
        col += mc;
    }
    (p1, p2)
}

/// Concatenate per-part quadrature pairs along the row axis: part `i`
/// is `[dims[i], modes]`, the result `[dims.sum(), modes]`.  Zero-row
/// parts are legal (a shard that sat the frame out).
pub(crate) fn concat_row_parts(
    parts: &[(Tensor, Tensor)],
    dims: &[usize],
    modes: usize,
) -> (Tensor, Tensor) {
    let rows: usize = dims.iter().sum();
    let mut p1 = Tensor::zeros(&[rows, modes]);
    let mut p2 = Tensor::zeros(&[rows, modes]);
    let mut at = 0usize;
    for ((s1, s2), &rc) in parts.iter().zip(dims) {
        debug_assert_eq!(s1.shape(), &[rc, modes]);
        p1.data_mut()[at * modes..(at + rc) * modes].copy_from_slice(s1.data());
        p2.data_mut()[at * modes..(at + rc) * modes].copy_from_slice(s2.data());
        at += rc;
    }
    (p1, p2)
}

fn default_pool(shards: usize, registry: &Registry) -> Arc<ThreadPool> {
    let cores = crate::exec::host_cores();
    Arc::new(ThreadPool::with_registry(
        shards.clamp(1, cores),
        2 * shards.max(1),
        registry,
    ))
}

impl ProjectorFarm {
    /// Optical farm: `shards` simulated OPUs over contiguous mode ranges
    /// of `medium`.  Shard `i` draws camera noise from PCG stream
    /// `NOISE_STREAM_BASE + i` of `noise_seed`, so `shards=1` reproduces
    /// the standalone `NativeOpticalProjector` bit-for-bit.
    #[deprecated(note = "use Topology::homogeneous(..).build_farm(..)")]
    pub fn optical(
        params: OpuParams,
        medium: &TransmissionMatrix,
        noise_seed: u64,
        shards: usize,
    ) -> Result<Self> {
        Topology::homogeneous(DeviceKind::Optical, shards).build_farm(
            params,
            &Medium::Dense(medium.clone()),
            noise_seed,
            Registry::new(),
        )
    }

    /// [`ProjectorFarm::optical`] with an explicit metrics registry (the
    /// trainer passes its own so shard failures land next to the
    /// training counters).
    #[deprecated(note = "use Topology::homogeneous(..).build_farm(..)")]
    pub fn optical_with(
        params: OpuParams,
        medium: &TransmissionMatrix,
        noise_seed: u64,
        shards: usize,
        registry: Registry,
    ) -> Result<Self> {
        Topology::homogeneous(DeviceKind::Optical, shards).build_farm(
            params,
            &Medium::Dense(medium.clone()),
            noise_seed,
            registry,
        )
    }

    /// Optical farm under either [`Partition`]: mode slices (the classic
    /// farm) or full-medium replicas serving contiguous row ranges.
    #[deprecated(note = "use Topology::with_partition(..).build_farm(..)")]
    pub fn optical_partitioned(
        params: OpuParams,
        medium: &TransmissionMatrix,
        noise_seed: u64,
        shards: usize,
        partition: Partition,
        registry: Registry,
    ) -> Result<Self> {
        Topology::homogeneous(DeviceKind::Optical, shards)
            .with_partition(partition)
            .build_farm(params, &Medium::Dense(medium.clone()), noise_seed, registry)
    }

    /// [`ProjectorFarm::optical_partitioned`] over either [`Medium`]
    /// backing.
    #[deprecated(note = "use Topology::with_backing_of(..).build_farm(..)")]
    pub fn optical_partitioned_backed(
        params: OpuParams,
        medium: &Medium,
        noise_seed: u64,
        shards: usize,
        partition: Partition,
        registry: Registry,
    ) -> Result<Self> {
        Topology::homogeneous(DeviceKind::Optical, shards)
            .with_partition(partition)
            .with_backing_of(medium)
            .build_farm(params, medium, noise_seed, registry)
    }

    /// [`ProjectorFarm::digital_partitioned`] over either [`Medium`]
    /// backing.
    #[deprecated(note = "use Topology::with_backing_of(..).build_farm(..)")]
    pub fn digital_partitioned_backed(
        medium: &Medium,
        shards: usize,
        partition: Partition,
        registry: Registry,
    ) -> Result<Self> {
        Topology::homogeneous(DeviceKind::Digital, shards)
            .with_partition(partition)
            .with_backing_of(medium)
            .build_farm(OpuParams::default(), medium, 0, registry)
    }

    /// Build just the shard devices for a partitioned optical projector —
    /// no pool, no farm state.
    #[deprecated(note = "use Topology::build_devices(..)")]
    pub fn optical_shard_devices(
        params: OpuParams,
        medium: &TransmissionMatrix,
        noise_seed: u64,
        shards: usize,
        partition: Partition,
    ) -> Result<Vec<Box<dyn Projector + Send>>> {
        Topology::homogeneous(DeviceKind::Optical, shards)
            .with_partition(partition)
            .build_devices(params, &Medium::Dense(medium.clone()), noise_seed, &Registry::new())
    }

    /// [`ProjectorFarm::optical_shard_devices`] over either [`Medium`]
    /// backing.
    #[deprecated(note = "use Topology::with_backing_of(..).build_devices(..)")]
    pub fn optical_shard_devices_backed(
        params: OpuParams,
        medium: &Medium,
        noise_seed: u64,
        shards: usize,
        partition: Partition,
    ) -> Result<Vec<Box<dyn Projector + Send>>> {
        Topology::homogeneous(DeviceKind::Optical, shards)
            .with_partition(partition)
            .with_backing_of(medium)
            .build_devices(params, medium, noise_seed, &Registry::new())
    }

    /// Digital farm under either [`Partition`].  Exactly equal to the
    /// single device at any shard count for both policies: column dot
    /// products are computed identically (modes), and the host matmul is
    /// row-local (batch).
    #[deprecated(note = "use Topology::with_partition(..).build_farm(..)")]
    pub fn digital_partitioned(
        medium: &TransmissionMatrix,
        shards: usize,
        partition: Partition,
        registry: Registry,
    ) -> Result<Self> {
        Topology::homogeneous(DeviceKind::Digital, shards)
            .with_partition(partition)
            .build_farm(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                0,
                registry,
            )
    }

    /// [`ProjectorFarm::optical_shard_devices`] for the digital
    /// comparator.
    #[deprecated(note = "use Topology::build_devices(..)")]
    pub fn digital_shard_devices(
        medium: &TransmissionMatrix,
        shards: usize,
        partition: Partition,
    ) -> Result<Vec<Box<dyn Projector + Send>>> {
        Topology::homogeneous(DeviceKind::Digital, shards)
            .with_partition(partition)
            .build_devices(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                0,
                &Registry::new(),
            )
    }

    /// [`ProjectorFarm::digital_shard_devices`] over either [`Medium`]
    /// backing.
    #[deprecated(note = "use Topology::with_backing_of(..).build_devices(..)")]
    pub fn digital_shard_devices_backed(
        medium: &Medium,
        shards: usize,
        partition: Partition,
    ) -> Result<Vec<Box<dyn Projector + Send>>> {
        Topology::homogeneous(DeviceKind::Digital, shards)
            .with_partition(partition)
            .with_backing_of(medium)
            .build_devices(OpuParams::default(), medium, 0, &Registry::new())
    }

    /// Digital farm: the silicon comparator sharded the same way.
    #[deprecated(note = "use Topology::homogeneous(..).build_farm(..)")]
    pub fn digital(medium: &TransmissionMatrix, shards: usize) -> Result<Self> {
        Topology::homogeneous(DeviceKind::Digital, shards).build_farm(
            OpuParams::default(),
            &Medium::Dense(medium.clone()),
            0,
            Registry::new(),
        )
    }

    /// [`ProjectorFarm::digital`] with an explicit metrics registry.
    #[deprecated(note = "use Topology::homogeneous(..).build_farm(..)")]
    pub fn digital_with(
        medium: &TransmissionMatrix,
        shards: usize,
        registry: Registry,
    ) -> Result<Self> {
        Topology::homogeneous(DeviceKind::Digital, shards).build_farm(
            OpuParams::default(),
            &Medium::Dense(medium.clone()),
            0,
            registry,
        )
    }

    /// Assemble a mode-partitioned farm from pre-built shard devices
    /// (mode ranges are taken from each device's `modes()`; outputs
    /// concatenate in shard order).  The execution pool is sized to the
    /// shard count.  This is the *custom-device* assembly —
    /// declaratively describable farms should go through
    /// [`Topology`](super::topology::Topology) instead.
    pub fn from_shards(
        shards: Vec<Box<dyn Projector + Send>>,
        kind: &'static str,
        registry: Registry,
    ) -> Result<Self> {
        Self::from_shards_partitioned(shards, kind, Partition::Modes, registry)
    }

    /// [`ProjectorFarm::from_shards`] with an explicit [`Partition`].
    /// Batch-partition shards must expose identical mode counts (they
    /// are replicas of one medium, not slices).
    pub fn from_shards_partitioned(
        shards: Vec<Box<dyn Projector + Send>>,
        kind: &'static str,
        partition: Partition,
        registry: Registry,
    ) -> Result<Self> {
        let weights = vec![1u32; shards.len()];
        Self::from_shards_weighted(shards, weights, kind, partition, registry, None)
    }

    /// The one full-fidelity assembly everything else reduces to:
    /// pre-built shard devices + per-shard service weights + partition +
    /// an optional caller-supplied pool (`None` = the farm owns a pool
    /// sized to its shard count).  Note: with a supplied pool, shard
    /// panics are counted on *that pool's* registry (wherever it was
    /// built with [`ThreadPool::with_registry`]), while
    /// [`SHARD_FAILURES`]/[`FARM_BATCHES`] land on `registry`.
    pub fn from_shards_weighted(
        shards: Vec<Box<dyn Projector + Send>>,
        weights: Vec<u32>,
        kind: &'static str,
        partition: Partition,
        registry: Registry,
        pool: Option<Arc<ThreadPool>>,
    ) -> Result<Self> {
        let pool = pool.unwrap_or_else(|| default_pool(shards.len(), &registry));
        Self::assemble(shards, weights, kind, partition, registry, pool)
    }

    /// [`ProjectorFarm::from_shards`] over a caller-supplied pool.
    #[deprecated(note = "use from_shards_weighted(.., Some(pool))")]
    pub fn from_shards_pooled(
        shards: Vec<Box<dyn Projector + Send>>,
        kind: &'static str,
        registry: Registry,
        pool: Arc<ThreadPool>,
    ) -> Result<Self> {
        let weights = vec![1u32; shards.len()];
        Self::from_shards_weighted(
            shards,
            weights,
            kind,
            Partition::Modes,
            registry,
            Some(pool),
        )
    }

    fn assemble(
        shards: Vec<Box<dyn Projector + Send>>,
        weights: Vec<u32>,
        kind: &'static str,
        partition: Partition,
        registry: Registry,
        pool: Arc<ThreadPool>,
    ) -> Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "farm needs at least one shard");
        anyhow::ensure!(
            weights.len() == shards.len(),
            "{} weights for {} shards",
            weights.len(),
            shards.len()
        );
        anyhow::ensure!(
            weights.iter().all(|&w| w >= 1),
            "zero-weight shard in {weights:?} (weights must be >= 1)"
        );
        let mode_counts: Vec<usize> = shards.iter().map(|s| s.modes()).collect();
        let modes_total = match partition {
            Partition::Modes => mode_counts.iter().sum(),
            Partition::Batch => {
                anyhow::ensure!(
                    mode_counts.iter().all(|&m| m == mode_counts[0]),
                    "batch-partition shards must expose identical mode \
                     counts, got {mode_counts:?}"
                );
                mode_counts[0]
            }
        };
        let n = shards.len();
        Ok(ProjectorFarm {
            shards,
            mode_counts,
            modes_total,
            weights,
            pool,
            kind,
            partition,
            slot_counts: vec![0; n],
            shard_failures: registry.counter(SHARD_FAILURES),
            batches: registry.counter(FARM_BATCHES),
        })
    }

    /// Number of virtual devices.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Mode count of each shard, in concatenation order.
    pub fn mode_counts(&self) -> &[usize] {
        &self.mode_counts
    }

    /// Relative service weight of each shard, in shard order.  The
    /// batch partition splits rows proportionally to these; the
    /// shard-aware service inherits them through
    /// [`ShardedProjectionService::over_farm`].
    ///
    /// [`ShardedProjectionService::over_farm`]: super::service::ShardedProjectionService::over_farm
    pub fn weights(&self) -> &[u32] {
        &self.weights
    }

    /// The partition policy this farm executes.
    pub fn partition(&self) -> Partition {
        self.partition
    }

    /// Completed frame slots per shard (a slot = one row exposed on that
    /// virtual device).  Mode partition charges every shard the full
    /// batch; batch partition charges each shard its row range.
    pub fn shard_slots(&self) -> &[u64] {
        &self.slot_counts
    }

    /// Per-shard submit entry point: run `frames` on shard `shard` alone
    /// and return that shard's quadratures (`[B, mode_counts()[shard]]`
    /// for the mode partition; `[B, modes()]` for batch replicas).  The
    /// shard-aware projection service schedules through this shape of
    /// call — one (shard, frame-slot range) at a time — and only the
    /// target shard's slot account is charged.
    pub fn project_on(
        &mut self,
        shard: usize,
        frames: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        anyhow::ensure!(
            shard < self.shards.len(),
            "shard {shard} out of range ({} shards)",
            self.shards.len()
        );
        let out = self.shards[shard].project(frames)?;
        self.slot_counts[shard] += frames.rows() as u64;
        Ok(out)
    }

    /// Decompose the farm into its shard devices (shard order preserved),
    /// handing ownership to a caller that schedules them directly — the
    /// shard-aware projection service gives each device its own worker
    /// thread and bounded request lane.
    pub fn into_shards(self) -> Vec<Box<dyn Projector + Send>> {
        self.shards
    }

    /// Per-shard simulated device-seconds.
    pub fn shard_sim_seconds(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.sim_seconds()).collect()
    }

    /// Wall-clock view of simulated time: shards expose concurrently, so
    /// the farm's critical path is the slowest shard.
    pub fn sim_seconds_wall(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| s.sim_seconds())
            .fold(0.0, f64::max)
    }

    /// The shared execution pool (shard panics are counted here).
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Turn per-shard result slots into outputs, counting every failure
    /// (the pool's panic counter and SHARD_FAILURES must agree batch by
    /// batch).  `None` means the shard job panicked (contained by the
    /// pool).
    #[allow(clippy::type_complexity)]
    fn collect_outputs(
        &self,
        slots: Vec<Option<Result<(Tensor, Tensor)>>>,
    ) -> Result<Vec<(Tensor, Tensor)>> {
        let n = slots.len();
        let mut outputs: Vec<(Tensor, Tensor)> = Vec::with_capacity(n);
        let mut failures: Vec<String> = Vec::new();
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(pair)) => outputs.push(pair),
                Some(Err(e)) => failures.push(format!("shard {i}: {e:#}")),
                None => failures.push(format!(
                    "shard {i}: panicked (contained; see pool panic counter)"
                )),
            }
        }
        if !failures.is_empty() {
            self.shard_failures.add(failures.len() as u64);
            anyhow::bail!(
                "farm batch failed on {}/{n} shards: {}",
                failures.len(),
                failures.join("; ")
            );
        }
        Ok(outputs)
    }

    /// Mode partition: every shard sees the whole batch and computes its
    /// mode slice; gather concatenates along columns.
    fn project_modes(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        // All shard counts (including 1) take the same scoped path, so
        // panic containment and failure accounting are uniform.  Bit
        // parity at `shards=1` holds because the gather is a pure copy
        // of the single shard's output.
        let b = frames.rows();
        let n = self.shards.len();
        // One result slot per shard; slots are disjoint `&mut`s handed
        // to the scoped shard jobs, so no locking and a deterministic
        // gather order.  `None` after the scope means the shard job
        // panicked (the pool contains and counts the panic).
        let mut slots: Vec<Option<Result<(Tensor, Tensor)>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        self.pool.scope(|scope| {
            for (shard, slot) in self.shards.iter_mut().zip(slots.iter_mut()) {
                scope.submit(move || {
                    *slot = Some(shard.project(frames));
                });
            }
        });
        let outputs = self.collect_outputs(slots)?;
        let (p1, p2) = concat_mode_parts(&outputs, &self.mode_counts, b);
        // Every virtual camera exposed all b rows: b slots per shard.
        for count in self.slot_counts.iter_mut() {
            *count += b as u64;
        }
        Ok((p1, p2))
    }

    /// Batch partition: shard `i` (a full-medium replica) processes the
    /// `i`-th contiguous row range — sized proportionally to the shard
    /// weights ([`crate::util::weighted_widths`]; equal weights are the
    /// historical even split, bit for bit); gather concatenates along
    /// rows.  Shards with an empty range are skipped entirely — their
    /// noise streams, clocks and slot accounts stay untouched.
    fn project_batch(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        let b = frames.rows();
        let n = self.shards.len();
        let d_in = frames.cols();
        let modes = self.modes_total;
        let counts = weighted_widths(b, &self.weights);
        let mut slices: Vec<Option<Tensor>> = Vec::with_capacity(n);
        let mut row0 = 0usize;
        for &c in &counts {
            if c == 0 {
                slices.push(None);
            } else {
                slices.push(Some(Tensor::from_vec(
                    &[c, d_in],
                    frames.data()[row0 * d_in..(row0 + c) * d_in].to_vec(),
                )));
            }
            row0 += c;
        }
        let mut slots: Vec<Option<Result<(Tensor, Tensor)>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        self.pool.scope(|scope| {
            for ((shard, slice), slot) in self
                .shards
                .iter_mut()
                .zip(slices.iter())
                .zip(slots.iter_mut())
            {
                if let Some(rows) = slice {
                    scope.submit(move || {
                        *slot = Some(shard.project(rows));
                    });
                } else {
                    *slot = Some(Ok((
                        Tensor::zeros(&[0, modes]),
                        Tensor::zeros(&[0, modes]),
                    )));
                }
            }
        });
        let outputs = self.collect_outputs(slots)?;
        let (p1, p2) = concat_row_parts(&outputs, &counts, modes);
        for (count, &c) in self.slot_counts.iter_mut().zip(&counts) {
            *count += c as u64;
        }
        Ok((p1, p2))
    }
}

impl Projector for ProjectorFarm {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        self.batches.inc();
        match self.partition {
            Partition::Modes => self.project_modes(frames),
            Partition::Batch => self.project_batch(frames),
        }
    }

    fn modes(&self) -> usize {
        self.modes_total
    }

    /// Device-seconds summed over shards (N devices each charge their
    /// own frame clock; capacity accounting, not wall clock).
    fn sim_seconds(&self) -> f64 {
        self.shards.iter().map(|s| s.sim_seconds()).sum()
    }

    fn energy_joules(&self) -> f64 {
        self.shards.iter().map(|s| s.energy_joules()).sum()
    }

    fn kind(&self) -> &'static str {
        self.kind
    }

    fn requires_ternary(&self) -> bool {
        self.shards.iter().any(|s| s.requires_ternary())
    }
}

#[cfg(test)]
mod tests {
    use super::super::projector::{DigitalProjector, NativeOpticalProjector};
    use super::*;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    fn tern(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, cols], data)
    }

    fn noiseless() -> OpuParams {
        OpuParams {
            n_ph: -1.0,
            read_sigma: 0.0,
            ..OpuParams::default()
        }
    }

    fn optical_farm(
        params: OpuParams,
        medium: &TransmissionMatrix,
        noise_seed: u64,
        shards: usize,
    ) -> Result<ProjectorFarm> {
        Topology::homogeneous(DeviceKind::Optical, shards).build_farm(
            params,
            &Medium::Dense(medium.clone()),
            noise_seed,
            Registry::new(),
        )
    }

    fn digital_farm(medium: &TransmissionMatrix, shards: usize) -> Result<ProjectorFarm> {
        Topology::homogeneous(DeviceKind::Digital, shards).build_farm(
            OpuParams::default(),
            &Medium::Dense(medium.clone()),
            0,
            Registry::new(),
        )
    }

    #[test]
    fn one_shard_optical_is_bit_identical_to_single_device() {
        let medium = TransmissionMatrix::sample(5, 10, 32);
        let mut single =
            NativeOpticalProjector::new(OpuParams::default(), medium.clone(), 77);
        let mut farm = optical_farm(OpuParams::default(), &medium, 77, 1).unwrap();
        let e = tern(6, 10, 1);
        let (s1, s2) = single.project(&e).unwrap();
        let (f1, f2) = farm.project(&e).unwrap();
        assert_eq!(s1, f1);
        assert_eq!(s2, f2);
        assert_eq!(single.sim_seconds(), farm.sim_seconds());
        assert_eq!(single.energy_joules(), farm.energy_joules());
    }

    #[test]
    fn digital_farm_equals_stacked_single_device_exactly() {
        let medium = TransmissionMatrix::sample(6, 10, 40);
        let e = tern(5, 10, 2);
        let want1 = matmul(&e, &medium.b_re);
        let want2 = matmul(&e, &medium.b_im);
        for shards in [2usize, 4, 7] {
            let mut farm = digital_farm(&medium, shards).unwrap();
            let (p1, p2) = farm.project(&e).unwrap();
            assert_eq!(p1, want1, "{shards} shards");
            assert_eq!(p2, want2, "{shards} shards");
        }
    }

    /// Every legacy constructor is a shim over `Topology::build_farm` —
    /// pin that the shims still build the *same* farm, bit for bit
    /// (noisy optics included: same windows, same noise streams).  The
    /// shims are the thing under test, so the `allow(deprecated)` is
    /// intentional (the only other one lives in tests/topology.rs's
    /// legacy-parity pin).
    #[test]
    #[allow(deprecated)]
    fn legacy_shims_match_their_topologies_bitwise() {
        let medium = TransmissionMatrix::sample(51, 10, 36);
        let e = tern(7, 10, 6);
        let mut legacy =
            ProjectorFarm::optical(OpuParams::default(), &medium, 13, 3).unwrap();
        let mut topo = optical_farm(OpuParams::default(), &medium, 13, 3).unwrap();
        assert_eq!(legacy.project(&e).unwrap(), topo.project(&e).unwrap());

        for partition in [Partition::Modes, Partition::Batch] {
            let mut legacy = ProjectorFarm::optical_partitioned(
                OpuParams::default(),
                &medium,
                13,
                4,
                partition,
                Registry::new(),
            )
            .unwrap();
            let mut topo = Topology::homogeneous(DeviceKind::Optical, 4)
                .with_partition(partition)
                .build_farm(
                    OpuParams::default(),
                    &Medium::Dense(medium.clone()),
                    13,
                    Registry::new(),
                )
                .unwrap();
            assert_eq!(
                legacy.project(&e).unwrap(),
                topo.project(&e).unwrap(),
                "{partition:?}"
            );
            assert_eq!(legacy.weights(), topo.weights());
        }

        let mut legacy = ProjectorFarm::digital(&medium, 5).unwrap();
        let mut topo = digital_farm(&medium, 5).unwrap();
        assert_eq!(legacy.project(&e).unwrap(), topo.project(&e).unwrap());
    }

    #[test]
    fn noiseless_optical_farm_matches_stacked_device() {
        let medium = TransmissionMatrix::sample(7, 10, 48);
        let e = tern(4, 10, 3);
        let mut single = NativeOpticalProjector::new(noiseless(), medium.clone(), 5);
        let (want1, want2) = single.project(&e).unwrap();
        for shards in [2usize, 4, 7] {
            let mut farm = optical_farm(noiseless(), &medium, 5, shards).unwrap();
            let (p1, p2) = farm.project(&e).unwrap();
            // Noise off → the physics is deterministic and column-local,
            // so sharding cannot change any output mode.
            assert!(p1.max_abs_diff(&want1) < 1e-5, "{shards} shards");
            assert!(p2.max_abs_diff(&want2) < 1e-5, "{shards} shards");
        }
    }

    #[test]
    fn accounting_sums_across_shards() {
        let medium = TransmissionMatrix::sample(8, 10, 30);
        let mut farm = optical_farm(OpuParams::default(), &medium, 9, 3).unwrap();
        let e = tern(12, 10, 4);
        farm.project(&e).unwrap();
        // Each of the 3 virtual devices exposes 12 frames at 1.5 kHz.
        let per_shard = 12.0 / 1500.0;
        let shard_secs = farm.shard_sim_seconds();
        assert_eq!(shard_secs.len(), 3);
        for s in &shard_secs {
            assert!((s - per_shard).abs() < 1e-12);
        }
        assert!((farm.sim_seconds() - 3.0 * per_shard).abs() < 1e-12);
        assert!((farm.sim_seconds_wall() - per_shard).abs() < 1e-12);
        assert!((farm.energy_joules() - 3.0 * per_shard * 30.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_farm_is_deterministic_per_seed_and_decorrelated_across_shards() {
        let medium = TransmissionMatrix::sample(9, 10, 24);
        let e = tern(4, 10, 5);
        let run = |seed: u64| {
            let mut farm = optical_farm(OpuParams::default(), &medium, seed, 4).unwrap();
            farm.project(&e).unwrap().0
        };
        assert_eq!(run(11), run(11), "same seed, same result");
        assert_ne!(run(11), run(12), "different noise seeds differ");
    }

    struct PanickingShard;

    impl Projector for PanickingShard {
        fn project(&mut self, _: &Tensor) -> Result<(Tensor, Tensor)> {
            panic!("injected shard crash");
        }
        fn modes(&self) -> usize {
            4
        }
        fn sim_seconds(&self) -> f64 {
            0.0
        }
        fn energy_joules(&self) -> f64 {
            0.0
        }
        fn kind(&self) -> &'static str {
            "panicking"
        }
    }

    #[test]
    fn shard_failure_is_contained_and_observable() {
        let medium = TransmissionMatrix::sample(10, 10, 8);
        let registry = Registry::new();
        let shards: Vec<Box<dyn Projector + Send>> = vec![
            Box::new(DigitalProjector::new(medium.clone())),
            Box::new(PanickingShard),
        ];
        let mut farm =
            ProjectorFarm::from_shards(shards, "farm-test", registry.clone()).unwrap();
        let err = farm.project(&tern(2, 10, 6)).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        let snap = registry.snapshot();
        assert_eq!(snap[SHARD_FAILURES], 1.0);
        assert_eq!(snap[crate::exec::pool::PANIC_COUNTER], 1.0);
        assert_eq!(farm.pool().panic_count(), 1);
        // The farm object stays usable for the next batch.
        assert_eq!(farm.modes(), medium.modes + 4);
    }

    #[test]
    fn concurrent_shard_failures_are_all_counted() {
        let registry = Registry::new();
        let shards: Vec<Box<dyn Projector + Send>> = vec![
            Box::new(PanickingShard),
            Box::new(DigitalProjector::new(TransmissionMatrix::sample(1, 10, 8))),
            Box::new(PanickingShard),
            Box::new(PanickingShard),
        ];
        let mut farm =
            ProjectorFarm::from_shards(shards, "farm-test", registry.clone()).unwrap();
        let err = farm.project(&tern(2, 10, 8)).unwrap_err().to_string();
        assert!(err.contains("3/4 shards"), "{err}");
        let snap = registry.snapshot();
        assert_eq!(snap[SHARD_FAILURES], 3.0);
        assert_eq!(snap[crate::exec::pool::PANIC_COUNTER], 3.0);
    }

    #[test]
    fn one_shard_panic_is_contained_too() {
        // No fast path may bypass containment: a 1-shard farm must turn
        // a device panic into an error, same as any other shard count.
        let registry = Registry::new();
        let shards: Vec<Box<dyn Projector + Send>> = vec![Box::new(PanickingShard)];
        let mut farm = ProjectorFarm::from_shards(shards, "farm-test", registry.clone()).unwrap();
        let err = farm.project(&tern(2, 10, 7)).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        assert_eq!(registry.snapshot()[SHARD_FAILURES], 1.0);
    }

    #[test]
    fn rejects_more_shards_than_modes() {
        let medium = TransmissionMatrix::sample(1, 10, 4);
        assert!(optical_farm(OpuParams::default(), &medium, 1, 5).is_err());
        assert!(digital_farm(&medium, 0).is_err());
    }

    #[test]
    fn requires_ternary_follows_the_shards() {
        let medium = TransmissionMatrix::sample(2, 10, 16);
        let optical = optical_farm(OpuParams::default(), &medium, 1, 2).unwrap();
        assert!(optical.requires_ternary());
        let digital = digital_farm(&medium, 2).unwrap();
        assert!(!digital.requires_ternary());
    }

    #[test]
    fn batch_partition_digital_is_exact_at_any_shard_count() {
        let medium = TransmissionMatrix::sample(12, 10, 24);
        let want = |e: &Tensor| (matmul(e, &medium.b_re), matmul(e, &medium.b_im));
        // Includes b < shards (empty ranges on the tail shards).
        for (shards, b) in [(1usize, 5usize), (2, 5), (4, 9), (7, 3)] {
            let mut farm = Topology::homogeneous(DeviceKind::Digital, shards)
                .with_partition(Partition::Batch)
                .build_farm(
                    OpuParams::default(),
                    &Medium::Dense(medium.clone()),
                    0,
                    Registry::new(),
                )
                .unwrap();
            assert_eq!(farm.partition(), Partition::Batch);
            assert_eq!(farm.modes(), 24);
            let e = tern(b, 10, 40 + shards as u64);
            let (want1, want2) = want(&e);
            let (p1, p2) = farm.project(&e).unwrap();
            assert_eq!(p1, want1, "{shards} shards, batch {b}");
            assert_eq!(p2, want2, "{shards} shards, batch {b}");
        }
    }

    #[test]
    fn batch_partition_one_shard_is_bit_identical_to_single_device() {
        // Noisy optics: the one batch replica uses the same noise stream
        // as the standalone device, so even the draws agree.
        let medium = TransmissionMatrix::sample(13, 10, 20);
        let mut single =
            NativeOpticalProjector::new(OpuParams::default(), medium.clone(), 55);
        let mut farm = Topology::homogeneous(DeviceKind::Optical, 1)
            .with_partition(Partition::Batch)
            .build_farm(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                55,
                Registry::new(),
            )
            .unwrap();
        for step in 0..3 {
            let e = tern(4, 10, 200 + step);
            let (s1, s2) = single.project(&e).unwrap();
            let (f1, f2) = farm.project(&e).unwrap();
            assert_eq!(s1, f1, "step {step}");
            assert_eq!(s2, f2, "step {step}");
        }
    }

    #[test]
    fn batch_partition_slot_accounting_is_per_row_range() {
        let medium = TransmissionMatrix::sample(14, 10, 16);
        let mut farm = Topology::homogeneous(DeviceKind::Optical, 4)
            .with_partition(Partition::Batch)
            .build_farm(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                3,
                Registry::new(),
            )
            .unwrap();
        farm.project(&tern(10, 10, 1)).unwrap();
        // 10 rows over 4 shards: 3,3,2,2 — slots sum to the batch.
        assert_eq!(farm.shard_slots(), &[3, 3, 2, 2]);
        // Each shard charged its own frame clock for its rows only.
        let secs = farm.shard_sim_seconds();
        assert!((secs[0] - 3.0 / 1500.0).abs() < 1e-12);
        assert!((secs[3] - 2.0 / 1500.0).abs() < 1e-12);
        assert!((farm.sim_seconds() - 10.0 / 1500.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_batch_partition_splits_rows_proportionally() {
        // A 3:1 weighted digital pair: 8 rows split 6/2, and the result
        // is still exactly the single-device projection (the host matmul
        // is row-local, so the split cannot change a bit).
        let medium = TransmissionMatrix::sample(52, 10, 16);
        let mut topo = Topology::homogeneous(DeviceKind::Digital, 2)
            .with_partition(Partition::Batch);
        topo.shards[0].weight = 3;
        let mut farm = topo
            .build_farm(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                0,
                Registry::new(),
            )
            .unwrap();
        assert_eq!(farm.weights(), &[3, 1]);
        let e = tern(8, 10, 7);
        let (p1, p2) = farm.project(&e).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re));
        assert_eq!(p2, matmul(&e, &medium.b_im));
        assert_eq!(farm.shard_slots(), &[6, 2]);
    }

    #[test]
    fn modes_partition_slot_accounting_charges_every_shard() {
        let medium = TransmissionMatrix::sample(15, 10, 30);
        let mut farm = digital_farm(&medium, 3).unwrap();
        farm.project(&tern(6, 10, 2)).unwrap();
        farm.project(&tern(2, 10, 3)).unwrap();
        assert_eq!(farm.shard_slots(), &[8, 8, 8]);
    }

    #[test]
    fn project_on_runs_one_shard_and_charges_it_only() {
        let medium = TransmissionMatrix::sample(16, 10, 30);
        let mut farm = digital_farm(&medium, 3).unwrap();
        let e = tern(5, 10, 4);
        let slices = medium.split_modes(3);
        let (p1, p2) = farm.project_on(1, &e).unwrap();
        assert_eq!(p1, matmul(&e, &slices[1].b_re));
        assert_eq!(p2, matmul(&e, &slices[1].b_im));
        assert_eq!(farm.shard_slots(), &[0, 5, 0]);
        assert!(farm.project_on(3, &e).is_err());
    }

    #[test]
    fn into_shards_hands_out_devices_in_order() {
        let medium = TransmissionMatrix::sample(17, 10, 30);
        let farm = digital_farm(&medium, 3).unwrap();
        let counts: Vec<usize> = farm.mode_counts().to_vec();
        let devices = farm.into_shards();
        assert_eq!(devices.len(), 3);
        for (dev, mc) in devices.iter().zip(&counts) {
            assert_eq!(dev.modes(), *mc);
        }
    }

    #[test]
    fn batch_partition_rejects_mismatched_replicas() {
        let a = TransmissionMatrix::sample(18, 10, 8);
        let b = TransmissionMatrix::sample(18, 10, 12);
        let shards: Vec<Box<dyn Projector + Send>> = vec![
            Box::new(DigitalProjector::new(a)),
            Box::new(DigitalProjector::new(b)),
        ];
        assert!(ProjectorFarm::from_shards_partitioned(
            shards,
            "farm-test",
            Partition::Batch,
            Registry::new()
        )
        .is_err());
    }
}
