//! Projector devices: who computes `B·e`.
//!
//! The paper's comparison hinges on swapping this one component:
//!
//! * [`NativeOpticalProjector`] — the simulated OPU physics in rust
//!   (default optical device; supports runtime noise sweeps).
//! * [`HloOpticalProjector`] — the *same* physics through the AOT
//!   `opu_project` artifact (JAX/Pallas twin): used to prove the twins
//!   agree and to keep the whole numeric path in XLA when desired.
//! * [`DigitalProjector`] — exact `e @ B` on silicon (the paper's GPU
//!   rows; here host matmul over the same medium quadratures).
//!
//! All three expose the same trait so the trainer and the projection
//! service are device-agnostic, and all three account simulated time.

use std::sync::Arc;

use anyhow::Result;

use crate::exec::ThreadPool;
use crate::optics::medium::TransmissionMatrix;
use crate::optics::stream::Medium;
use crate::optics::{OpticalOpu, OpuParams, NOISE_STREAM_BASE};
use crate::runtime::Engine;
use crate::sim::power::GpuModel;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// A device that projects ternary/float error frames through the fixed
/// random matrix, returning the two quadrature projections.
///
/// Note: not `Send` by itself — [`HloOpticalProjector`] holds a PJRT
/// client (`Rc` internally).  The projection *service* requires
/// `dyn Projector + Send`; the native and digital devices satisfy it.
pub trait Projector {
    /// `[B, d_in]` frames → `(P1, P2)`, each `[B, modes]`.
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)>;

    /// Output modes per quadrature.
    fn modes(&self) -> usize;

    /// Simulated device-seconds consumed so far.
    fn sim_seconds(&self) -> f64;

    /// Simulated energy in joules.
    fn energy_joules(&self) -> f64;

    /// Human tag for logs/metrics.
    fn kind(&self) -> &'static str;

    /// Whether frames must be ternary (optical SLM) or may be float.
    fn requires_ternary(&self) -> bool {
        true
    }
}

/// Simulated OPU, rust-native physics.
pub struct NativeOpticalProjector {
    opu: OpticalOpu,
}

impl NativeOpticalProjector {
    pub fn new(params: OpuParams, medium: TransmissionMatrix, noise_seed: u64) -> Self {
        NativeOpticalProjector {
            opu: OpticalOpu::new(params, medium, noise_seed),
        }
    }

    /// Shard constructor: same seed, independent noise stream (see
    /// [`crate::optics::NOISE_STREAM_BASE`]).
    pub fn with_noise_stream(
        params: OpuParams,
        medium: TransmissionMatrix,
        noise_seed: u64,
        noise_stream: u64,
    ) -> Self {
        Self::with_medium_stream(params, Medium::Dense(medium), noise_seed, noise_stream)
    }

    /// Backing-polymorphic constructor on the base noise stream —
    /// `Medium::Streamed` gives the memory-less device, bit-identical to
    /// the dense one of the same seed.
    pub fn with_medium(params: OpuParams, medium: Medium, noise_seed: u64) -> Self {
        Self::with_medium_stream(params, medium, noise_seed, NOISE_STREAM_BASE)
    }

    /// [`NativeOpticalProjector::with_medium`] with an explicit noise
    /// stream (farm shards).
    pub fn with_medium_stream(
        params: OpuParams,
        medium: Medium,
        noise_seed: u64,
        noise_stream: u64,
    ) -> Self {
        NativeOpticalProjector {
            opu: OpticalOpu::with_medium(params, medium, noise_seed, noise_stream),
        }
    }

    pub fn opu_mut(&mut self) -> &mut OpticalOpu {
        &mut self.opu
    }

    pub fn opu(&self) -> &OpticalOpu {
        &self.opu
    }
}

impl Projector for NativeOpticalProjector {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        self.opu.project(frames)
    }

    fn modes(&self) -> usize {
        self.opu.modes()
    }

    fn sim_seconds(&self) -> f64 {
        self.opu.stats().sim_seconds
    }

    fn energy_joules(&self) -> f64 {
        self.opu.stats().energy_joules
    }

    fn kind(&self) -> &'static str {
        "optical-native"
    }
}

/// Simulated OPU through the `opu_project` HLO artifact.
///
/// The rust side supplies the camera-noise draws (so the artifact stays
/// a pure function and runs are reproducible) and charges the same frame
/// clock as the native device.
pub struct HloOpticalProjector {
    engine: Engine,
    config: String,
    medium: TransmissionMatrix,
    params: OpuParams,
    noise_rng: Pcg64,
    frames_done: u64,
    batch: usize,
    cosk: Tensor,
    sink: Tensor,
}

impl HloOpticalProjector {
    pub fn new(
        mut engine: Engine,
        config: &str,
        medium: TransmissionMatrix,
        noise_seed: u64,
    ) -> Result<Self> {
        let params = engine.manifest().opu;
        let batch = engine.manifest().config(config)?.batch;
        engine.prepare("opu_project", config)?;
        // Carrier tables are runtime inputs to the artifact (large
        // constants do not survive the HLO-text interchange).
        let npix = params.oversample * medium.modes;
        let mut cosk = Tensor::zeros(&[1, npix]);
        let mut sink = Tensor::zeros(&[1, npix]);
        for p in 0..npix {
            let ph = params.carrier * p as f64;
            cosk.data_mut()[p] = ph.cos() as f32;
            sink.data_mut()[p] = ph.sin() as f32;
        }
        Ok(HloOpticalProjector {
            engine,
            config: config.to_string(),
            medium,
            params,
            noise_rng: Pcg64::new(noise_seed, 0xb10),
            frames_done: 0,
            batch,
            cosk,
            sink,
        })
    }
}

impl Projector for HloOpticalProjector {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        let b = frames.rows();
        anyhow::ensure!(
            b == self.batch,
            "opu_project artifact is compiled for batch {}, got {b}",
            self.batch
        );
        let npix = self.params.oversample * self.medium.modes;
        let mut n1 = Tensor::zeros(&[b, npix]);
        let mut n2 = Tensor::zeros(&[b, npix]);
        self.noise_rng.fill_normal(n1.data_mut());
        self.noise_rng.fill_normal(n2.data_mut());
        let n_ph = Tensor::scalar(self.params.n_ph);
        let sig = Tensor::scalar(self.params.read_sigma);
        let outs = self.engine.call(
            "opu_project",
            &self.config,
            &[
                frames,
                &self.medium.b_re,
                &self.medium.b_im,
                &n1,
                &n2,
                &n_ph,
                &sig,
                &self.cosk,
                &self.sink,
            ],
        )?;
        self.frames_done += b as u64;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    fn modes(&self) -> usize {
        self.medium.modes
    }

    fn sim_seconds(&self) -> f64 {
        self.frames_done as f64 / self.params.frame_rate_hz
    }

    fn energy_joules(&self) -> f64 {
        self.sim_seconds() * self.params.power_watts
    }

    fn kind(&self) -> &'static str {
        "optical-hlo"
    }
}

/// Exact digital projection (the GPU baseline's math, host execution,
/// GPU timing model for the simulated clock).  Backing-polymorphic: the
/// streamed medium makes this the "GPU that regenerates its matrix" —
/// the honest digital comparator at sizes where the dense matrix would
/// not fit, still bitwise the dense result.
pub struct DigitalProjector {
    medium: Medium,
    gpu: GpuModel,
    projections: u64,
    batches: u64,
    batch_hint: usize,
    /// Optional host pool: row-block-parallel matmuls (bitwise identical
    /// to the serial path) keep the silicon baseline an honest
    /// comparator when the farm gets multiple cores.
    pool: Option<Arc<ThreadPool>>,
}

impl DigitalProjector {
    pub fn new(medium: TransmissionMatrix) -> Self {
        Self::with_medium(Medium::Dense(medium))
    }

    /// Backing-polymorphic constructor.
    pub fn with_medium(medium: Medium) -> Self {
        DigitalProjector {
            medium,
            gpu: GpuModel::v100(),
            projections: 0,
            batches: 0,
            batch_hint: 1,
            pool: None,
        }
    }

    /// Run the host matmuls row-block-parallel on `pool` (dense backing;
    /// a streamed backing parallelizes over its own pool).
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    pub fn medium(&self) -> &Medium {
        &self.medium
    }
}

impl Projector for DigitalProjector {
    fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        let (p1, p2) = self.medium.project(frames, self.pool.as_deref());
        self.projections += frames.rows() as u64;
        self.batches += 1;
        self.batch_hint = frames.rows();
        Ok((p1, p2))
    }

    fn modes(&self) -> usize {
        self.medium.modes()
    }

    fn sim_seconds(&self) -> f64 {
        // GPU-model time for the projections done so far, batched as the
        // caller batched them.
        self.batches as f64
            * self
                .gpu
                .seconds(self.medium.d_in(), 2 * self.medium.modes(), self.batch_hint)
    }

    fn energy_joules(&self) -> f64 {
        self.sim_seconds() * self.gpu.power_watts
    }

    fn kind(&self) -> &'static str {
        "digital"
    }

    fn requires_ternary(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optics::stream::StreamedMedium;
    use crate::tensor::matmul;

    fn tern(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn digital_is_exact() {
        let medium = TransmissionMatrix::sample(3, 10, 32);
        let mut proj = DigitalProjector::new(medium.clone());
        let e = tern(4, 10, 1);
        let (p1, p2) = proj.project(&e).unwrap();
        assert_eq!(p1, matmul(&e, &medium.b_re));
        assert_eq!(p2, matmul(&e, &medium.b_im));
        assert!(proj.sim_seconds() > 0.0);
    }

    #[test]
    fn pooled_digital_matches_serial_digital() {
        let medium = TransmissionMatrix::sample(3, 10, 48);
        let pool = Arc::new(ThreadPool::new(3, 16));
        let mut serial = DigitalProjector::new(medium.clone());
        let mut pooled = DigitalProjector::new(medium).with_pool(pool);
        let e = tern(9, 10, 4);
        let (s1, s2) = serial.project(&e).unwrap();
        let (p1, p2) = pooled.project(&e).unwrap();
        assert_eq!(s1, p1);
        assert_eq!(s2, p2);
    }

    #[test]
    fn streamed_digital_is_bitwise_dense_digital() {
        let medium = TransmissionMatrix::sample(3, 10, 40);
        let mut dense = DigitalProjector::new(medium.clone());
        let mut streamed =
            DigitalProjector::with_medium(Medium::Streamed(StreamedMedium::new(3, 10, 40)));
        let e = tern(6, 10, 5);
        let (d1, d2) = dense.project(&e).unwrap();
        let (s1, s2) = streamed.project(&e).unwrap();
        assert_eq!(d1, s1);
        assert_eq!(d2, s2);
        // Same GPU timing model under both backings.
        assert_eq!(dense.sim_seconds(), streamed.sim_seconds());
        assert!(!streamed.requires_ternary());
    }

    #[test]
    fn native_optical_approximates_digital() {
        let medium = TransmissionMatrix::sample(3, 10, 64);
        let mut opt =
            NativeOpticalProjector::new(OpuParams::default(), medium.clone(), 5);
        let mut dig = DigitalProjector::new(medium);
        let e = tern(8, 10, 2);
        let (o1, _) = opt.project(&e).unwrap();
        let (d1, _) = dig.project(&e).unwrap();
        let c = crate::util::stats::correlation(
            &o1.data().iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &d1.data().iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!(c > 0.97, "correlation {c}");
        // optical charges the frame clock
        assert!((opt.sim_seconds() - 8.0 / 1500.0).abs() < 1e-9);
    }
}
