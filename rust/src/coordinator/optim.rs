//! Host-side Adam optimizer (Kingma & Ba 2015).
//!
//! Reference twin of the fused L1 `adam_update` Pallas kernel: used by
//! the pure-rust trainers ([`super::host`]) and as the oracle in the
//! cross-implementation tests (`rust/tests/`).  Hyper-parameters match
//! the kernel's compile-time constants.

use crate::tensor::Tensor;

pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

/// Adam state for one set of parameter tensors.
#[derive(Clone, Debug)]
pub struct Adam {
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub t: f32,
    pub lr: f32,
}

impl Adam {
    /// Zero-initialized moments shaped like `params`.
    pub fn new(params: &[Tensor], lr: f32) -> Self {
        Adam {
            m: params.iter().map(|p| Tensor::zeros(p.shape())).collect(),
            v: params.iter().map(|p| Tensor::zeros(p.shape())).collect(),
            t: 0.0,
            lr,
        }
    }

    /// One step: `params[i] -= lr·m̂/(√v̂+ε)` for every tensor.
    /// `grads` must align with `params`.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1.0;
        let bc1 = 1.0 - BETA1.powf(self.t);
        let bc2 = 1.0 - BETA2.powf(self.t);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.shape(), g.shape());
            let pd = p.data_mut();
            let gd = g.data();
            let md = m.data_mut();
            let vd = v.data_mut();
            for i in 0..pd.len() {
                md[i] = BETA1 * md[i] + (1.0 - BETA1) * gd[i];
                vd[i] = BETA2 * vd[i] + (1.0 - BETA2) * gd[i] * gd[i];
                let mhat = md[i] / bc1;
                let vhat = vd[i] / bc2;
                pd[i] -= self.lr * mhat / (vhat.sqrt() + EPS);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_signed_lr() {
        let mut params = vec![Tensor::zeros(&[1, 3])];
        let grads = vec![Tensor::from_vec(&[1, 3], vec![3.0, -2.0, 0.5])];
        let mut opt = Adam::new(&params, 0.01);
        opt.step(&mut params, &grads);
        // t=1: update ≈ -lr·sign(g) (up to ε)
        for (p, g) in params[0].data().iter().zip(grads[0].data()) {
            assert!((p + 0.01 * g.signum()).abs() < 1e-4, "{p} vs {g}");
        }
    }

    #[test]
    fn matches_closed_form_two_steps() {
        let mut params = vec![Tensor::from_vec(&[1, 1], vec![1.0])];
        let g1 = vec![Tensor::from_vec(&[1, 1], vec![0.5])];
        let g2 = vec![Tensor::from_vec(&[1, 1], vec![-0.25])];
        let mut opt = Adam::new(&params, 0.1);
        opt.step(&mut params, &g1);
        opt.step(&mut params, &g2);

        // closed form
        let (b1, b2, eps, lr) = (BETA1, BETA2, EPS, 0.1f32);
        let mut m = 0.0f32;
        let mut v = 0.0f32;
        let mut p = 1.0f32;
        for (t, g) in [(1.0f32, 0.5f32), (2.0, -0.25)] {
            m = b1 * m + (1.0 - b1) * g;
            v = b2 * v + (1.0 - b2) * g * g;
            let mhat = m / (1.0 - b1.powf(t));
            let vhat = v / (1.0 - b2.powf(t));
            p -= lr * mhat / (vhat.sqrt() + eps);
        }
        assert!((params[0].data()[0] - p).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize (x - 3)²
        let mut params = vec![Tensor::from_vec(&[1, 1], vec![0.0])];
        let mut opt = Adam::new(&params, 0.1);
        for _ in 0..300 {
            let x = params[0].data()[0];
            let grads = vec![Tensor::from_vec(&[1, 1], vec![2.0 * (x - 3.0)])];
            opt.step(&mut params, &grads);
        }
        assert!((params[0].data()[0] - 3.0).abs() < 0.05);
    }
}
