//! The unified `Topology` builder: one declarative device-graph
//! descriptor for every projection deployment shape.
//!
//! "Hardware Beyond Backpropagation" (Launay et al., 2020) scales DFA's
//! optical error projection to *fleets* of devices with differing speeds
//! and failure modes.  Before this module, every (device kind ×
//! partition × medium backing × pool) combination was a bespoke
//! [`ProjectorFarm`] constructor — ~15 of them — and heterogeneous or
//! weighted deployments were unreachable by combinatorics alone.
//!
//! A [`Topology`] is a validated **value type**: a list of
//! [`ShardSpec`]s — each with a device kind (optical/digital), a
//! relative **service weight**, an optional explicit mode range and an
//! optional camera-noise stream — plus the partition axis, the medium
//! backing and the pool policy.  One build path turns it into shard
//! devices ([`Topology::build_devices`]), a farm
//! ([`Topology::build_farm`]), a trainer-facing projector
//! ([`Topology::build_projector`]) or a running shard-aware service
//! ([`Topology::build_service`]).
//!
//! **Determinism contract** (pinned in `rust/tests/topology.rs`):
//!
//! * a topology is hashable ([`Topology::stable_hash`]) and serializable
//!   ([`Topology::shorthand`] round-trips through [`Topology::parse`]);
//! * `build_*` are pure functions of the topology and their physical
//!   inputs (medium, seeds) — same topology, same bits;
//! * an **equal-weight homogeneous** topology is *bitwise identical* to
//!   the legacy constructor matrix it replaces: mode windows come from
//!   the same [`balanced_widths`] arithmetic
//!   ([`weighted_widths`] reduces to it exactly for equal weights),
//!   noise streams are the same `NOISE_STREAM_BASE + i` assignment, and
//!   the farm/scheduler row splits are unchanged.
//!
//! **What the weights buy**: under the batch partition the farm and the
//! frame-slot scheduler split a frame's rows proportionally to the shard
//! weights instead of evenly — the ROADMAP's weighted frame-slot
//! scheduling — so a device that services frames 3× faster can be
//! declared `@3` and receive 3× the rows.  Mixed `opt`/`dig` specs give
//! heterogeneous farms: graceful degradation and honest comparators in
//! one fleet.
//!
//! **What a topology does not carry**: per-*instance* properties of the
//! physical medium — notably the streamed backing's cross-step tile
//! cache (`--tile-cache-mb` / `[topology] tile_cache_mb`, with its lock
//! layout under `--tile-cache-stripes` / `[topology]
//! tile_cache_stripes`, both [`TrainConfig`](crate::config::TrainConfig)
//! knobs).  The trainer attaches the cache to the [`Medium`] *before*
//! the build carves shard windows, so every shard of any topology
//! shares one budget (and one stripe map — stripes change lock
//! contention, never bits); builds stay pure functions of (topology,
//! medium) either way.
//!
//! Shorthand grammar (CLI `--topology`, TOML `topology = "..."`):
//!
//! ```text
//! [hetero:]GROUP(+GROUP)*
//! GROUP := KIND:COUNT[@WEIGHT][!ADDR]
//! KIND  := opt | optical | dig | digital
//! ADDR  := tcp:host:port | uds:/path | host:port
//! ```
//!
//! e.g. `opt:4` (4 equal optical shards), `hetero:opt:4+dig:2` (4
//! optical + 2 digital), `opt:2@3+dig:1` (2 optical shards at weight 3
//! each, 1 digital at weight 1), `opt:2+opt:2!tcp:10.0.0.7:9000` (2
//! local optical shards plus 2 served by the projector server at
//! `10.0.0.7:9000` — a mixed local+remote fleet in one descriptor).
//!
//! **Remote shards**: a shard spec with an `endpoint` builds a
//! [`RemoteProjector`](crate::net::RemoteProjector) speaking the
//! [`crate::net::frame`] wire protocol to a `litl serve` process
//! instead of instantiating the device locally.  The endpoint is part
//! of the descriptor's identity (shorthand/canonical/stable-hash); the
//! transport *tuning* ([`crate::net::NetOptions`], set via
//! [`Topology::with_net`]) is not — timeouts, the session-resume
//! budget (`resume_tries`), and any injected
//! [`FaultPlanCfg`](crate::net::FaultPlanCfg) shape when a dial gives
//! up or how a dead connection re-attaches, never what bits a
//! projection returns.  A loopback remote shard is bitwise the
//! in-process shard (`rust/tests/net_parity.rs`), and stays bitwise
//! under seeded fault injection with resume on
//! (`rust/tests/chaos.rs`).
//!
//! [`balanced_widths`]: crate::util::balanced_widths
//! [`weighted_widths`]: crate::util::weighted_widths
//! [`NOISE_STREAM_BASE`]: crate::optics::NOISE_STREAM_BASE

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{MediumBacking, Partition};
use crate::exec::ThreadPool;
use crate::metrics::Registry;
use crate::net::{Addr, NetOptions, RemoteProjector};
use crate::optics::stream::Medium;
use crate::optics::{OpuParams, NOISE_STREAM_BASE};
use crate::util::weighted_widths;

use super::farm::ProjectorFarm;
use super::projector::{DigitalProjector, NativeOpticalProjector, Projector};
use super::service::{ShardRebuild, ShardServiceConfig, ShardedProjectionService};

/// What physics a shard device runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Simulated OPU (rust-native physics, camera noise, frame clock).
    Optical,
    /// Exact digital projection (the silicon comparator).
    Digital,
}

impl DeviceKind {
    pub fn parse(s: &str) -> Result<DeviceKind> {
        Ok(match s {
            "opt" | "optical" => DeviceKind::Optical,
            "dig" | "digital" => DeviceKind::Digital,
            other => bail!("unknown device kind '{other}' (opt|dig)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Optical => "opt",
            DeviceKind::Digital => "dig",
        }
    }
}

/// Where a farm built from a topology gets its worker threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PoolPolicy {
    /// The farm owns a pool sized to its shard count (the legacy
    /// default).
    Owned,
    /// Use the process-wide [`crate::exec::shared_pool`], so several
    /// farms/components in one process share worker threads.
    Shared,
}

impl PoolPolicy {
    pub fn parse(s: &str) -> Result<PoolPolicy> {
        Ok(match s {
            "owned" => PoolPolicy::Owned,
            "shared" => PoolPolicy::Shared,
            other => bail!("unknown pool policy '{other}' (owned|shared)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PoolPolicy::Owned => "owned",
            PoolPolicy::Shared => "shared",
        }
    }
}

/// One virtual device in the topology.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShardSpec {
    /// Device physics.
    pub device: DeviceKind,
    /// Relative service weight (rows per frame under the batch
    /// partition, mode-window width under the modes partition).  Must
    /// be ≥ 1 — a zero-weight shard would silently starve.
    pub weight: u32,
    /// Explicit mode window `[start, end)` under the modes partition.
    /// `None` (the common case) derives contiguous windows from the
    /// weights.  All-or-none: mixing explicit and derived ranges in one
    /// topology is rejected.
    pub mode_range: Option<(usize, usize)>,
    /// Camera-noise PCG stream for an optical shard.  `None` assigns
    /// the legacy `NOISE_STREAM_BASE + shard_index`, which is what keeps
    /// equal-weight topologies bitwise on the legacy noise draws.
    pub noise_stream: Option<u64>,
    /// Remote endpoint (`tcp:host:port` / `uds:/path`).  `None` (the
    /// default) instantiates the device in-process; `Some` builds a
    /// [`RemoteProjector`] to a `litl serve` process hosting this shard
    /// id.  The spec's `device` then documents the *expected* remote
    /// physics; the wire hello verifies the mode width.
    pub endpoint: Option<String>,
}

impl ShardSpec {
    /// An implicit-range, default-stream shard of `device` at `weight`.
    pub fn new(device: DeviceKind, weight: u32) -> ShardSpec {
        ShardSpec {
            device,
            weight,
            mode_range: None,
            noise_stream: None,
            endpoint: None,
        }
    }

    /// Builder: serve this shard from the projector server at `addr`.
    pub fn remote(mut self, addr: impl Into<String>) -> ShardSpec {
        self.endpoint = Some(addr.into());
        self
    }
}

/// The declarative device graph: shard specs + partition axis + medium
/// backing + pool policy.  See the module docs for the contract.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Topology {
    pub shards: Vec<ShardSpec>,
    pub partition: Partition,
    pub backing: MediumBacking,
    pub pool: PoolPolicy,
    /// Transport tuning for any remote shards (timeouts/backoff,
    /// session-resume budget, chaos fault plan).
    /// Operational only: excluded from [`Topology::canonical`] — two
    /// topologies differing solely in `net` are the same deployment.
    pub net: NetOptions,
}

impl Topology {
    /// `n` equal-weight shards of one device kind — the topology that
    /// reproduces every legacy homogeneous constructor bit for bit.
    pub fn homogeneous(device: DeviceKind, n: usize) -> Topology {
        Topology {
            shards: (0..n).map(|_| ShardSpec::new(device, 1)).collect(),
            partition: Partition::Modes,
            backing: MediumBacking::Materialized,
            pool: PoolPolicy::Owned,
            net: NetOptions::default(),
        }
    }

    /// Builder: set the partition axis.
    pub fn with_partition(mut self, partition: Partition) -> Topology {
        self.partition = partition;
        self
    }

    /// Builder: set the medium backing.
    pub fn with_backing(mut self, backing: MediumBacking) -> Topology {
        self.backing = backing;
        self
    }

    /// Builder: set the backing to match an already-built [`Medium`]
    /// (what the legacy `*_backed` shims do).
    pub fn with_backing_of(self, medium: &Medium) -> Topology {
        self.with_backing(backing_of(medium))
    }

    /// Builder: set the pool policy.
    pub fn with_pool(mut self, pool: PoolPolicy) -> Topology {
        self.pool = pool;
        self
    }

    /// Builder: set the remote-shard transport tuning.
    pub fn with_net(mut self, net: NetOptions) -> Topology {
        self.net = net;
        self
    }

    /// A copy with every remote endpoint cleared — what `litl serve`
    /// builds locally so the *hosting* process instantiates real
    /// devices instead of dialing itself.
    pub fn strip_endpoints(&self) -> Topology {
        let mut t = self.clone();
        for spec in &mut t.shards {
            spec.endpoint = None;
        }
        t
    }

    /// Builder: append a shard spec.
    pub fn push(mut self, spec: ShardSpec) -> Topology {
        self.shards.push(spec);
        self
    }

    /// Parse the `--topology` shorthand (see module docs for the
    /// grammar).  An optional leading `hetero:` tag is accepted and
    /// ignored — it is CLI self-documentation, not information.
    pub fn parse(s: &str) -> Result<Topology> {
        let body = s.strip_prefix("hetero:").unwrap_or(s).trim();
        if body.is_empty() {
            bail!("empty topology (want e.g. 'opt:4' or 'opt:4+dig:2')");
        }
        let mut shards = Vec::new();
        for group in body.split('+') {
            // `!ADDR` (remote endpoint) splits off first: the address
            // itself contains ':' and may contain '@'-free host names.
            let (local_part, endpoint) = match group.split_once('!') {
                Some((lp, addr)) => {
                    let addr = Addr::parse(addr).map_err(|e| {
                        anyhow::anyhow!("topology group '{group}': {e}")
                    })?;
                    (lp, Some(addr.canonical()))
                }
                None => (group, None),
            };
            let (kind_count, weight) = match local_part.split_once('@') {
                Some((kc, w)) => {
                    let w: u32 = w
                        .parse()
                        .map_err(|e| anyhow::anyhow!("topology weight '{w}': {e}"))?;
                    (kc, w)
                }
                None => (local_part, 1),
            };
            let Some((kind, count)) = kind_count.split_once(':') else {
                bail!(
                    "topology group '{group}' is not KIND:COUNT[@WEIGHT][!ADDR] \
                     (e.g. 'opt:4', 'dig:2@3' or 'opt:2!tcp:host:9000')"
                );
            };
            let device = DeviceKind::parse(kind)?;
            let count: usize = count
                .parse()
                .map_err(|e| anyhow::anyhow!("topology count '{count}': {e}"))?;
            if count == 0 {
                bail!("topology group '{group}': count must be >= 1");
            }
            if weight == 0 {
                bail!("topology group '{group}': zero-weight shard (weights must be >= 1)");
            }
            for _ in 0..count {
                let mut spec = ShardSpec::new(device, weight);
                spec.endpoint = endpoint.clone();
                shards.push(spec);
            }
        }
        let topo = Topology {
            shards,
            partition: Partition::Modes,
            backing: MediumBacking::Materialized,
            pool: PoolPolicy::Owned,
            net: NetOptions::default(),
        };
        topo.validate()?;
        Ok(topo)
    }

    /// Canonical shorthand: adjacent same-(kind, weight, endpoint)
    /// shards coalesce into one `KIND:COUNT[@WEIGHT][!ADDR]` group;
    /// `@1` is omitted.  For any topology without explicit mode ranges
    /// or noise streams, `Topology::parse(t.shorthand())` reproduces
    /// `t`'s shard list — remote endpoints included.
    pub fn shorthand(&self) -> String {
        let mut groups: Vec<(DeviceKind, u32, Option<&String>, usize)> = Vec::new();
        for spec in &self.shards {
            match groups.last_mut() {
                Some((kind, weight, endpoint, count))
                    if *kind == spec.device
                        && *weight == spec.weight
                        && *endpoint == spec.endpoint.as_ref() =>
                {
                    *count += 1
                }
                _ => groups.push((spec.device, spec.weight, spec.endpoint.as_ref(), 1)),
            }
        }
        groups
            .iter()
            .map(|(kind, weight, endpoint, count)| {
                let mut g = if *weight == 1 {
                    format!("{}:{count}", kind.name())
                } else {
                    format!("{}:{count}@{weight}", kind.name())
                };
                if let Some(ep) = endpoint {
                    g.push('!');
                    g.push_str(ep);
                }
                g
            })
            .collect::<Vec<_>>()
            .join("+")
    }

    /// Full canonical encoding (shorthand + partition + backing + pool +
    /// any explicit ranges/streams) — the serialization
    /// [`Topology::stable_hash`] digests.
    pub fn canonical(&self) -> String {
        let mut s = format!(
            "{}|partition={}|medium={}|pool={}",
            self.shorthand(),
            self.partition.name(),
            self.backing.name(),
            self.pool.name()
        );
        for (i, spec) in self.shards.iter().enumerate() {
            if let Some((a, b)) = spec.mode_range {
                s.push_str(&format!("|range{i}={a}..{b}"));
            }
            if let Some(ns) = spec.noise_stream {
                s.push_str(&format!("|stream{i}={ns}"));
            }
        }
        s
    }

    /// FNV-1a over [`Topology::canonical`] — a stable, host-independent
    /// identity for caches, logs and experiment records.
    pub fn stable_hash(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for byte in self.canonical().bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Number of virtual devices.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard service weights, in shard order.
    pub fn weights(&self) -> Vec<u32> {
        self.shards.iter().map(|s| s.weight).collect()
    }

    /// Whether every shard runs the same device kind.
    pub fn is_homogeneous(&self) -> bool {
        self.shards
            .windows(2)
            .all(|w| w[0].device == w[1].device)
    }

    /// The farm `kind` tag for logs/metrics.
    pub fn kind_tag(&self) -> &'static str {
        if !self.is_homogeneous() {
            "farm-hetero"
        } else if self.shards.first().map(|s| s.device) == Some(DeviceKind::Digital) {
            "farm-digital"
        } else {
            "farm-optical"
        }
    }

    /// Structural validation (shape-independent; the `build_*` methods
    /// additionally check the topology against the concrete medium).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.shards.is_empty(), "topology needs at least one shard");
        for (i, spec) in self.shards.iter().enumerate() {
            anyhow::ensure!(
                spec.weight >= 1,
                "shard {i}: zero-weight shard (weights must be >= 1)"
            );
            if let Some((a, b)) = spec.mode_range {
                anyhow::ensure!(
                    a < b,
                    "shard {i}: empty mode range {a}..{b} (start must be < end)"
                );
                anyhow::ensure!(
                    self.partition == Partition::Modes,
                    "shard {i}: explicit mode ranges only apply to the modes \
                     partition (batch shards are full-medium replicas)"
                );
            }
            if let Some(ep) = &spec.endpoint {
                Addr::parse(ep).map_err(|e| {
                    anyhow::anyhow!("shard {i}: bad remote endpoint '{ep}': {e}")
                })?;
            }
        }
        let explicit = self.shards.iter().filter(|s| s.mode_range.is_some()).count();
        anyhow::ensure!(
            explicit == 0 || explicit == self.shards.len(),
            "mode ranges must be given for all shards or none \
             ({explicit}/{} have one)",
            self.shards.len()
        );
        if explicit > 0 {
            // Overlap check over the explicit windows (order-independent).
            let mut ranges: Vec<(usize, usize)> =
                self.shards.iter().filter_map(|s| s.mode_range).collect();
            ranges.sort_unstable();
            for pair in ranges.windows(2) {
                anyhow::ensure!(
                    pair[0].1 <= pair[1].0,
                    "overlapping mode ranges {}..{} and {}..{}",
                    pair[0].0,
                    pair[0].1,
                    pair[1].0,
                    pair[1].1
                );
            }
        }
        Ok(())
    }

    /// Contiguous mode-window widths for the modes partition over
    /// `modes_total` output modes: the explicit ranges when given (they
    /// must tile `[0, modes_total)` exactly), else a weighted split —
    /// which for equal weights is *exactly* the legacy
    /// `split_modes` arithmetic.
    pub fn mode_widths(&self, modes_total: usize) -> Result<Vec<usize>> {
        self.validate()?;
        if self.shards.iter().all(|s| s.mode_range.is_some()) && !self.shards.is_empty()
        {
            // Explicit windows: must be the shards' declared order and
            // tile the axis (the gather concatenates in shard order).
            let mut at = 0usize;
            let mut widths = Vec::with_capacity(self.shards.len());
            for (i, spec) in self.shards.iter().enumerate() {
                let (a, b) = spec.mode_range.unwrap();
                anyhow::ensure!(
                    a == at,
                    "shard {i}: mode range {a}..{b} leaves a gap (expected start {at})"
                );
                widths.push(b - a);
                at = b;
            }
            anyhow::ensure!(
                at == modes_total,
                "explicit mode ranges cover 0..{at}, medium has {modes_total} modes"
            );
            return Ok(widths);
        }
        let n = self.shards.len();
        anyhow::ensure!(
            n <= modes_total,
            "cannot shard {modes_total} modes across {n} devices"
        );
        let widths = weighted_widths(modes_total, &self.weights());
        anyhow::ensure!(
            widths.iter().all(|&w| w >= 1),
            "weighted mode split {widths:?} starves a shard of modes \
             ({modes_total} modes over weights {:?}); lower the skew or \
             give explicit mode ranges",
            self.weights()
        );
        Ok(widths)
    }

    /// Build the shard devices in shard order: mode windows of `medium`
    /// under the modes partition, full-medium replicas under batch.
    /// Optical shard `i` draws camera noise from PCG stream
    /// `NOISE_STREAM_BASE + i` of `noise_seed` unless its spec pins one.
    ///
    /// A shard with a remote endpoint dials its projector server
    /// instead (eagerly — a dead server fails the build, not the first
    /// projection) and is checked against the mode width the topology
    /// carves for that slot; its net counters report into `registry`.
    pub fn build_devices(
        &self,
        params: OpuParams,
        medium: &Medium,
        noise_seed: u64,
        registry: &Registry,
    ) -> Result<Vec<Box<dyn Projector + Send>>> {
        self.validate()?;
        self.ensure_backing_matches(medium)?;
        // Expected output width per shard: its carved window under the
        // modes partition, the full medium under batch replicas.
        let widths: Vec<usize> = match self.partition {
            Partition::Modes => self.mode_widths(medium.modes())?,
            Partition::Batch => vec![medium.modes(); self.shards.len()],
        };
        if self.partition == Partition::Batch {
            let local = self.shards.iter().filter(|s| s.endpoint.is_none()).count();
            warn_streamed_batch_cost(medium, local);
        }
        let mut out: Vec<Box<dyn Projector + Send>> =
            Vec::with_capacity(self.shards.len());
        let mut c0 = 0usize;
        for (i, (spec, &w)) in self.shards.iter().zip(&widths).enumerate() {
            let col0 = c0;
            if self.partition == Partition::Modes {
                c0 += w;
            }
            if let Some(ep) = &spec.endpoint {
                let addr = Addr::parse(ep)?;
                let remote =
                    RemoteProjector::connect(&addr, i as u32, self.net, registry)?;
                anyhow::ensure!(
                    remote.modes() == w,
                    "remote shard {i} at {addr} serves {} modes, topology \
                     expects {w}",
                    remote.modes()
                );
                out.push(Box::new(remote));
                continue;
            }
            // Local shard: carve/clone the medium only now, so remote
            // shards never pay for (or touch) a local medium copy.
            let shard_medium = match self.partition {
                Partition::Modes => medium.window(col0, w),
                Partition::Batch => medium.clone(),
            };
            let stream = spec.noise_stream.unwrap_or(NOISE_STREAM_BASE + i as u64);
            out.push(match spec.device {
                DeviceKind::Optical => {
                    Box::new(NativeOpticalProjector::with_medium_stream(
                        params,
                        shard_medium,
                        noise_seed,
                        stream,
                    )) as Box<dyn Projector + Send>
                }
                DeviceKind::Digital => {
                    Box::new(DigitalProjector::with_medium(shard_medium))
                        as Box<dyn Projector + Send>
                }
            });
        }
        Ok(out)
    }

    /// Build a [`ProjectorFarm`]: the devices above, the topology's
    /// weights driving the batch-partition row split, and a pool per the
    /// pool policy.
    pub fn build_farm(
        &self,
        params: OpuParams,
        medium: &Medium,
        noise_seed: u64,
        registry: Registry,
    ) -> Result<ProjectorFarm> {
        let devices = self.build_devices(params, medium, noise_seed, &registry)?;
        let pool: Option<Arc<ThreadPool>> = match self.pool {
            PoolPolicy::Owned => None,
            PoolPolicy::Shared => Some(crate::exec::shared_pool()),
        };
        ProjectorFarm::from_shards_weighted(
            devices,
            self.weights(),
            self.kind_tag(),
            self.partition,
            registry,
            pool,
        )
    }

    /// Build the trainer-facing projector: the bare legacy single
    /// device for a 1-shard homogeneous topology (bit-identical anyway,
    /// but without the farm machinery around it), the weighted farm
    /// otherwise.
    pub fn build_projector(
        &self,
        params: OpuParams,
        medium: &Medium,
        noise_seed: u64,
        registry: Registry,
    ) -> Result<Box<dyn Projector>> {
        self.validate()?;
        self.ensure_backing_matches(medium)?;
        if self.shards.len() == 1 && self.shards[0].mode_range.is_none() {
            let spec = &self.shards[0];
            let stream = spec.noise_stream.unwrap_or(NOISE_STREAM_BASE);
            return Ok(match spec.device {
                DeviceKind::Optical => Box::new(
                    NativeOpticalProjector::with_medium_stream(
                        params,
                        medium.clone(),
                        noise_seed,
                        stream,
                    ),
                ) as Box<dyn Projector>,
                // Row-block-parallel host matmuls on the process-wide
                // pool keep the silicon baseline honest on multi-core
                // hosts (bitwise identical to the serial path).
                DeviceKind::Digital => Box::new(
                    DigitalProjector::with_medium(medium.clone())
                        .with_pool(crate::exec::shared_pool()),
                ) as Box<dyn Projector>,
            });
        }
        Ok(Box::new(self.build_farm(params, medium, noise_seed, registry)?))
    }

    /// Build a running [`ShardedProjectionService`] over this topology:
    /// one worker per shard device, the frame-slot scheduler splitting
    /// batch rows proportionally to the shard weights.  `cfg.partition`
    /// must match the topology's.
    ///
    /// The service also gets a failover *rebuild factory*: when
    /// `cfg.failover` is on and a shard trips on device errors, its
    /// worker rebuilds that shard's device from this same topology +
    /// medium + seed — under the modes partition that re-windows the
    /// medium exactly as the original build did
    /// ([`Medium::window`](crate::optics::stream::Medium::window)
    /// under the hood), under batch it re-clones the replica.  The
    /// factory is inert while `cfg.failover.enabled` is false, so the
    /// pinned deterministic schedules are untouched by default.
    pub fn build_service(
        &self,
        params: OpuParams,
        medium: &Medium,
        noise_seed: u64,
        d_in: usize,
        cfg: ShardServiceConfig,
        metrics: Registry,
    ) -> Result<ShardedProjectionService> {
        anyhow::ensure!(
            cfg.partition == self.partition,
            "topology partition {:?} != service partition {:?}",
            self.partition,
            cfg.partition
        );
        let devices = self.build_devices(params, medium, noise_seed, &metrics)?;
        let topo = self.clone();
        let medium2 = medium.clone();
        let reg2 = metrics.clone();
        let rebuild: ShardRebuild = Arc::new(move |shard| {
            let mut rebuilt = topo.build_devices(params, &medium2, noise_seed, &reg2)?;
            anyhow::ensure!(shard < rebuilt.len(), "no shard {shard} in topology");
            Ok(rebuilt.swap_remove(shard))
        });
        ShardedProjectionService::start_full(
            devices,
            self.weights(),
            d_in,
            cfg,
            metrics,
            Some(rebuild),
        )
    }

    fn ensure_backing_matches(&self, medium: &Medium) -> Result<()> {
        let medium_backing = backing_of(medium);
        anyhow::ensure!(
            medium_backing == self.backing,
            "topology backing '{}' but the supplied medium is '{}'",
            self.backing.name(),
            medium_backing.name()
        );
        Ok(())
    }
}

/// The one [`Medium`] → [`MediumBacking`] mapping, shared by
/// [`Topology::with_backing_of`] and the build-time backing check so
/// the two can never disagree.
fn backing_of(medium: &Medium) -> MediumBacking {
    match medium {
        Medium::Dense(_) => MediumBacking::Materialized,
        Medium::Streamed(_) => MediumBacking::Streamed,
    }
}

/// Streamed replicas under the batch partition each regenerate the full
/// mode width — total generation work scales with the shard count.  Say
/// so once at build rather than letting a 1e5+-mode run discover it
/// from the wall clock.  (A shared tile cache — the medium-instance
/// `--tile-cache-mb` knob, attached before the build carves replicas —
/// softens this: the replicas hit each other's tiles.)
fn warn_streamed_batch_cost(medium: &Medium, shards: usize) {
    if shards > 1 && matches!(medium, Medium::Streamed(_)) {
        let cached = matches!(
            medium,
            Medium::Streamed(sm) if sm.tile_cache().is_some()
        );
        log::warn!(
            "streamed medium × batch partition: each of the {shards} replicas \
             regenerates all {} modes per projection (~{shards}× the modes \
             partition's generation work{}); prefer --partition modes at \
             large mode counts",
            medium.modes(),
            if cached {
                ", softened by the shared tile cache"
            } else {
                "; --tile-cache-mb lets replicas share generated tiles"
            }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optics::medium::TransmissionMatrix;
    use crate::tensor::{matmul, Tensor};
    use crate::util::rng::Pcg64;

    fn tern(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn shorthand_round_trips() {
        for s in [
            "opt:4",
            "dig:2",
            "opt:4+dig:2",
            "opt:2@3+dig:1",
            "opt:1@2+opt:1",
            "opt:2!tcp:127.0.0.1:9000",
            "opt:1+dig:1!uds:/tmp/litl.sock",
            "opt:1@2!tcp:10.0.0.7:9000+opt:1",
        ] {
            let t = Topology::parse(s).unwrap();
            assert_eq!(t.shorthand(), s, "canonical form of '{s}'");
            assert_eq!(Topology::parse(&t.shorthand()).unwrap(), t);
        }
        // Aliases and the hetero: tag normalize to the canonical form.
        let t = Topology::parse("hetero:optical:4+digital:2").unwrap();
        assert_eq!(t.shorthand(), "opt:4+dig:2");
        assert_eq!(t.shard_count(), 6);
        assert!(!t.is_homogeneous());
        assert_eq!(t.weights(), vec![1; 6]);
    }

    #[test]
    fn parse_rejects_malformed_shorthand() {
        for bad in [
            "", "opt", "opt:", "opt:x", "opt:0", "opt:2@0", "laser:2", "opt:2@x",
            "opt:2++dig:1", "opt:2!", "opt:2!tcp:", "opt:2!uds:", "opt:2!nohost",
        ] {
            assert!(Topology::parse(bad).is_err(), "'{bad}' should be rejected");
        }
    }

    #[test]
    fn endpoints_strip_and_hash_distinctly() {
        let remote = Topology::parse("opt:2!tcp:127.0.0.1:9000").unwrap();
        assert_eq!(remote.shards[0].endpoint.as_deref(), Some("tcp:127.0.0.1:9000"));
        let local = remote.strip_endpoints();
        assert!(local.shards.iter().all(|s| s.endpoint.is_none()));
        assert_eq!(local, Topology::parse("opt:2").unwrap());
        // Endpoint placement is part of the canonical identity; net
        // tuning knobs are not.
        assert_ne!(remote.stable_hash(), local.stable_hash());
        let tuned = remote.clone().with_net(NetOptions {
            reconnect_tries: 9,
            ..NetOptions::default()
        });
        assert_eq!(tuned.stable_hash(), remote.stable_hash());
    }

    #[test]
    fn validate_rejects_zero_weight_and_overlapping_ranges() {
        let mut t = Topology::homogeneous(DeviceKind::Digital, 2);
        t.shards[1].weight = 0;
        let err = t.validate().unwrap_err().to_string();
        assert!(err.contains("zero-weight"), "{err}");

        let mut t = Topology::homogeneous(DeviceKind::Digital, 2);
        t.shards[0].mode_range = Some((0, 10));
        t.shards[1].mode_range = Some((8, 20));
        let err = t.validate().unwrap_err().to_string();
        assert!(err.contains("overlapping"), "{err}");

        // Mixing explicit and implicit ranges is rejected too.
        let mut t = Topology::homogeneous(DeviceKind::Digital, 2);
        t.shards[0].mode_range = Some((0, 10));
        assert!(t.validate().is_err());

        // Explicit ranges under the batch partition make no sense.
        let mut t = Topology::homogeneous(DeviceKind::Digital, 1)
            .with_partition(Partition::Batch);
        t.shards[0].mode_range = Some((0, 10));
        assert!(t.validate().is_err());
    }

    #[test]
    fn equal_weight_mode_widths_are_the_legacy_split() {
        for (modes, n) in [(52usize, 4usize), (37, 5), (10, 3), (8, 1)] {
            let t = Topology::homogeneous(DeviceKind::Digital, n);
            assert_eq!(
                t.mode_widths(modes).unwrap(),
                crate::util::balanced_widths(modes, n),
                "{modes} modes / {n} shards"
            );
        }
    }

    #[test]
    fn weighted_mode_widths_follow_the_weights() {
        let t = Topology {
            shards: vec![
                ShardSpec::new(DeviceKind::Optical, 3),
                ShardSpec::new(DeviceKind::Optical, 1),
            ],
            partition: Partition::Modes,
            backing: MediumBacking::Materialized,
            pool: PoolPolicy::Owned,
            net: NetOptions::default(),
        };
        assert_eq!(t.mode_widths(40).unwrap(), vec![30, 10]);
        // Starvation is an error, not a silent zero-width shard.
        let skew = Topology {
            shards: vec![
                ShardSpec::new(DeviceKind::Optical, 1000),
                ShardSpec::new(DeviceKind::Optical, 1),
            ],
            partition: Partition::Modes,
            backing: MediumBacking::Materialized,
            pool: PoolPolicy::Owned,
            net: NetOptions::default(),
        };
        assert!(skew.mode_widths(4).is_err());
    }

    #[test]
    fn explicit_ranges_must_tile_the_axis() {
        let mut t = Topology::homogeneous(DeviceKind::Digital, 2);
        t.shards[0].mode_range = Some((0, 12));
        t.shards[1].mode_range = Some((12, 30));
        assert_eq!(t.mode_widths(30).unwrap(), vec![12, 18]);
        assert!(t.mode_widths(31).is_err(), "short of the axis");
        let mut gap = Topology::homogeneous(DeviceKind::Digital, 2);
        gap.shards[0].mode_range = Some((0, 10));
        gap.shards[1].mode_range = Some((12, 30));
        assert!(gap.mode_widths(30).is_err(), "gap in the tiling");
    }

    #[test]
    fn stable_hash_distinguishes_topologies_and_is_stable() {
        let a = Topology::parse("opt:4").unwrap();
        let b = Topology::parse("opt:4+dig:2").unwrap();
        let c = Topology::parse("opt:4").unwrap().with_partition(Partition::Batch);
        assert_eq!(a.stable_hash(), Topology::parse("opt:4").unwrap().stable_hash());
        assert_ne!(a.stable_hash(), b.stable_hash());
        assert_ne!(a.stable_hash(), c.stable_hash());
        assert!(a.canonical().contains("partition=modes"));
    }

    #[test]
    fn hetero_farm_projects_its_mode_slices() {
        // 1 optical (noiseless) + 1 digital shard over one medium: the
        // digital half is exactly the dense slice, the optical half is
        // within ADC tolerance — both concatenated in shard order.
        let medium = TransmissionMatrix::sample(41, 10, 24);
        let noiseless = OpuParams {
            n_ph: -1.0,
            read_sigma: 0.0,
            ..OpuParams::default()
        };
        let topo = Topology::parse("opt:1+dig:1").unwrap();
        let mut farm = topo
            .build_farm(
                noiseless,
                &Medium::Dense(medium.clone()),
                7,
                Registry::new(),
            )
            .unwrap();
        assert_eq!(farm.kind(), "farm-hetero");
        assert!(farm.requires_ternary(), "any optical shard demands ternary");
        let e = tern(5, 10, 3);
        let (p1, _) = farm.project(&e).unwrap();
        let want = matmul(&e, &medium.b_re);
        // Digital half (columns 12..24) is bit-exact.
        for r in 0..5 {
            for c in 12..24 {
                assert_eq!(p1.at(r, c), want.at(r, c), "digital half ({r},{c})");
            }
        }
        // Optical half agrees to fp/ADC tolerance.
        let mut max_diff = 0.0f32;
        for r in 0..5 {
            for c in 0..12 {
                max_diff = max_diff.max((p1.at(r, c) - want.at(r, c)).abs());
            }
        }
        assert!(max_diff < 1e-5, "optical half diff {max_diff}");
    }

    #[test]
    fn build_rejects_backing_mismatch() {
        let medium = Medium::Dense(TransmissionMatrix::sample(1, 10, 8));
        let topo = Topology::parse("dig:2")
            .unwrap()
            .with_backing(MediumBacking::Streamed);
        let err = topo
            .build_devices(OpuParams::default(), &medium, 1, &Registry::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("backing"), "{err}");
    }

    #[test]
    fn single_shard_projector_is_the_bare_device() {
        let medium = TransmissionMatrix::sample(2, 10, 16);
        let topo = Topology::homogeneous(DeviceKind::Optical, 1);
        let mut built = topo
            .build_projector(
                OpuParams::default(),
                &Medium::Dense(medium.clone()),
                5,
                Registry::new(),
            )
            .unwrap();
        assert_eq!(built.kind(), "optical-native");
        let mut classic = NativeOpticalProjector::new(OpuParams::default(), medium, 5);
        let e = tern(4, 10, 9);
        assert_eq!(built.project(&e).unwrap(), classic.project(&e).unwrap());

        let topo = Topology::homogeneous(DeviceKind::Digital, 1);
        let built = topo
            .build_projector(
                OpuParams::default(),
                &Medium::Dense(TransmissionMatrix::sample(2, 10, 16)),
                5,
                Registry::new(),
            )
            .unwrap();
        assert_eq!(built.kind(), "digital");
    }

    #[test]
    fn rejects_more_shards_than_modes() {
        let medium = Medium::Dense(TransmissionMatrix::sample(1, 10, 4));
        let topo = Topology::homogeneous(DeviceKind::Digital, 5);
        assert!(topo
            .build_devices(OpuParams::default(), &medium, 1, &Registry::new())
            .is_err());
    }
}
