//! `litl` — light-in-the-loop CLI (the L3 leader process).
//!
//! ```text
//! litl train   [--algo bp|dfa-float|dfa-ternary|optical] [--epochs N] ...
//! litl serve   --listen tcp:HOST:PORT|uds:/PATH [--topology opt:2] ...
//! litl eval    --checkpoint file.ckpt [--config paper]
//! litl opu     [--modes N]            # device self-test + info
//! litl trace   [--algo optical]       # one-step dataflow trace (Fig. 1)
//! litl help
//! ```

use anyhow::{bail, Context, Result};
use litl::cli::Args;
use litl::config::{Algo, MediumBacking, Partition, TrainConfig};
use litl::coordinator::topology::Topology;
use litl::coordinator::Trainer;
use litl::data::{self, Split};
use litl::metrics::Registry;
use litl::net::{Addr, ProjectorServer, ServerOptions};
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::{Medium, StreamedMedium};
use litl::optics::{OpticalOpu, OpuParams};
use litl::tensor::Tensor;
use litl::util::logging;
use litl::util::rng::Pcg64;

const TRAIN_FLAGS: &[&str] = &[
    "algo", "epochs", "train-size", "test-size", "lr", "theta", "seed",
    "config", "projector", "set", "artifacts", "out-dir", "eval-every",
    "checkpoint", "paper-lr", "n-ph", "read-sigma", "metrics", "shards",
    "partition", "medium", "topology", "tile-cache-mb", "tile-cache-stripes",
    "adapt-weights", "failover", "admit-rate-fps", "trace", "trace-out",
    "metrics-out", "resume", "tile-cache-save", "tile-cache-load",
    "net-connect-timeout-ms", "net-request-timeout-ms", "net-reconnect-tries",
    "net-resume", "fault-plan",
];

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "opu" => cmd_opu(&args),
        "trace" => cmd_trace(&args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `litl help`)"),
    }
}

fn build_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.flag("config-file") {
        cfg.load_file(path)?;
    }
    if let Some(a) = args.flag("algo") {
        cfg.algo = Algo::parse(a)?;
    }
    if let Some(e) = args.flag_parse::<usize>("epochs")? {
        cfg.epochs = e;
    }
    if let Some(n) = args.flag_parse::<usize>("train-size")? {
        cfg.train_size = n;
    }
    if let Some(n) = args.flag_parse::<usize>("test-size")? {
        cfg.test_size = n;
    }
    if let Some(lr) = args.flag_parse::<f32>("lr")? {
        cfg.lr = lr;
    }
    if let Some(th) = args.flag_parse::<f32>("theta")? {
        cfg.theta = th;
    }
    if let Some(s) = args.flag_parse::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(c) = args.flag("config") {
        cfg.artifact_config = c.to_string();
    }
    if let Some(p) = args.flag("projector") {
        cfg.set_kv(&format!("projector={p}"))?;
    }
    if let Some(d) = args.flag("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(d) = args.flag("out-dir") {
        cfg.out_dir = Some(d.to_string());
    }
    if let Some(n) = args.flag_parse::<usize>("eval-every")? {
        cfg.eval_every = n;
    }
    if let Some(n) = args.flag_parse::<f32>("n-ph")? {
        cfg.n_ph = Some(n);
    }
    if let Some(n) = args.flag_parse::<f32>("read-sigma")? {
        cfg.read_sigma = Some(n);
    }
    if let Some(n) = args.flag_parse::<usize>("shards")? {
        anyhow::ensure!(n >= 1, "--shards must be >= 1");
        cfg.shards = n;
    }
    if let Some(p) = args.flag("partition") {
        cfg.partition = Partition::parse(p)?;
    }
    if let Some(m) = args.flag("medium") {
        cfg.medium = MediumBacking::parse(m)?;
    }
    if let Some(t) = args.flag("topology") {
        cfg.topology = Some(Topology::parse(t)?);
    }
    if let Some(n) = args.flag_parse::<usize>("tile-cache-mb")? {
        cfg.tile_cache_mb = n;
    }
    if let Some(n) = args.flag_parse::<usize>("tile-cache-stripes")? {
        cfg.tile_cache_stripes = n;
    }
    if let Some(v) = args.flag("adapt-weights") {
        cfg.adapt_weights = parse_switch("adapt-weights", v)?;
    }
    if let Some(v) = args.flag("failover") {
        cfg.failover = parse_switch("failover", v)?;
    }
    if let Some(r) = args.flag("admit-rate-fps") {
        // Route through set_kv so the CLI and config-file spellings
        // share one validation path.
        cfg.set_kv(&format!("admit_rate_fps={r}"))?;
    }
    if let Some(l) = args.flag("trace") {
        cfg.set_kv(&format!("trace={l}"))?;
    }
    if let Some(p) = args.flag("trace-out") {
        cfg.set_kv(&format!("trace_out={p}"))?;
    }
    if let Some(p) = args.flag("metrics-out") {
        cfg.set_kv(&format!("metrics_out={p}"))?;
    }
    if let Some(p) = args.flag("resume") {
        cfg.set_kv(&format!("resume={p}"))?;
    }
    if let Some(p) = args.flag("tile-cache-save") {
        cfg.set_kv(&format!("tile_cache_save={p}"))?;
    }
    if let Some(p) = args.flag("tile-cache-load") {
        cfg.set_kv(&format!("tile_cache_load={p}"))?;
    }
    if let Some(v) = args.flag("net-connect-timeout-ms") {
        cfg.set_kv(&format!("net_connect_timeout_ms={v}"))?;
    }
    if let Some(v) = args.flag("net-request-timeout-ms") {
        cfg.set_kv(&format!("net_request_timeout_ms={v}"))?;
    }
    if let Some(v) = args.flag("net-reconnect-tries") {
        cfg.set_kv(&format!("net_reconnect_tries={v}"))?;
    }
    if let Some(v) = args.flag("net-resume") {
        cfg.net_resume = parse_switch("net-resume", v)?;
    }
    if let Some(spec) = args.flag("fault-plan") {
        cfg.set_kv(&format!("fault_plan={spec}"))?;
    } else if cfg.fault_plan.is_none() {
        // Env spelling for chaos drills on deployments whose launch
        // scripts can't grow flags; --fault-plan and the config file
        // both win over the environment.
        cfg.fault_plan = litl::net::FaultPlanCfg::from_env("LITL_FAULT_PLAN")?;
    }
    for kv in args.flag_all("set") {
        cfg.set_kv(kv)?;
    }
    if args.flag_bool("paper-lr") {
        cfg = cfg.with_paper_lr();
    }
    Ok(cfg)
}

fn parse_switch(flag: &str, value: &str) -> Result<bool> {
    match value {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => bail!("--{flag} expects on|off, got '{other}'"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    args.ensure_known(&[TRAIN_FLAGS, &["config-file"]].concat())?;
    let cfg = build_config(args)?;
    // Fail fast on inconsistent projection knobs, before data/artifacts.
    cfg.validate_projection()?;
    log::info!(
        "train: algo={} lr={} epochs={} config={} projector={:?} shards={} \
         partition={} medium={} tile_cache_mb={} tile_cache_stripes={} \
         adapt_weights={} failover={} admit_rate_fps={}",
        cfg.algo.name(),
        cfg.lr,
        cfg.epochs,
        cfg.artifact_config,
        cfg.projector,
        cfg.shards,
        cfg.partition.name(),
        cfg.medium.name(),
        cfg.tile_cache_mb,
        cfg.tile_cache_stripes,
        cfg.adapt_weights,
        cfg.failover,
        cfg.admit_rate_fps
    );
    if cfg.algo == Algo::Optical && cfg.projector != litl::config::ProjectorKind::OpticalHlo
    {
        let topo = cfg.projection_topology();
        log::info!(
            "topology: {} (partition={}, pool={}, hash={:016x})",
            topo.shorthand(),
            topo.partition.name(),
            topo.pool.name(),
            topo.stable_hash()
        );
    }
    let ds = data::load_or_synth(cfg.seed, cfg.train_size, cfg.test_size)?;
    log::info!(
        "dataset: {} train / {} test samples",
        ds.len(Split::Train),
        ds.len(Split::Test)
    );
    let mut trainer = Trainer::new(cfg.clone())?;
    // Install the trace session around the whole run so every pipeline
    // thread (packer, shard workers, trainer loop) shares one clock.
    let session = litl::metrics::trace::TraceSession::begin(
        cfg.trace,
        litl::metrics::trace::TraceClock::wall(),
        cfg.trace_ring_events,
    );
    let run = trainer.run(&ds);
    // Uninstall and drain even when the run errored, so a failed run
    // still leaves the process trace-free (and the buffers reclaimed).
    let trace_report = session.finish();
    let report = run?;
    if let Some(path) = &cfg.trace_out {
        litl::metrics::export::write_chrome_trace(path, &trace_report)?;
        log::info!(
            "chrome trace written to {path}: {} spans across {} threads \
             ({} events dropped)",
            trace_report.spans.len(),
            trace_report.threads,
            trace_report.dropped
        );
    }
    if let Some(path) = &cfg.metrics_out {
        litl::metrics::export::write_prometheus(path, trainer.metrics())?;
        log::info!("prometheus metrics written to {path}");
    }
    println!(
        "\n{} (lr={}): final test accuracy {:.2}%  ({} params)",
        report.algo.name(),
        report.lr,
        report.final_accuracy_pct(),
        report.num_params
    );
    println!(
        "wall {:.1}s | simulated device time {:.1}s | device energy {:.1} J | {} frames",
        report.wall_seconds,
        report.sim_device_seconds,
        report.device_energy_joules,
        report.frames
    );
    if let Some(path) = args.flag("checkpoint") {
        trainer.save_checkpoint(path)?;
        log::info!("checkpoint saved to {path}");
    }
    if args.flag_bool("metrics") {
        println!("\n== metrics snapshot ==");
        for (name, value) in trainer.metrics().snapshot() {
            println!("  {name:<32} {value:.6}");
        }
    }
    Ok(())
}

/// Host shards of a topology behind a wire-protocol listener — the
/// remote end of `--topology 'opt:2!tcp:HOST:PORT'`.  Devices are built
/// through the SAME `Topology::build_devices` path a local run uses, so
/// a loopback remote shard answers bitwise what the in-process shard
/// would, noisy optics included: the leader and the server only have to
/// agree on shapes and seeds (pass the leader's `--seed` as
/// `--train-seed` and the derivations match `Trainer` exactly).
fn cmd_serve(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "listen", "topology", "partition", "medium", "d-in", "modes",
        "train-seed", "medium-seed", "noise-seed", "serve-shards",
        "tile-cache-mb", "tile-cache-stripes", "tile-cache-load",
        "tile-cache-save", "n-ph", "read-sigma", "fault-plan", "journal-cap",
    ])?;
    let listen = args
        .flag("listen")
        .ok_or_else(|| anyhow::anyhow!("--listen tcp:HOST:PORT|uds:/PATH required"))?;
    let addr = Addr::parse(listen)?;
    let d_in = args.flag_parse::<usize>("d-in")?.unwrap_or(10);
    let modes = args.flag_parse::<usize>("modes")?.unwrap_or(1024);
    let train_seed = args
        .flag_parse::<u64>("train-seed")?
        .unwrap_or(TrainConfig::default().seed);
    let medium_seed =
        args.flag_parse::<u64>("medium-seed")?.unwrap_or(train_seed ^ 0xB);
    let noise_seed =
        args.flag_parse::<u64>("noise-seed")?.unwrap_or(train_seed ^ 0xF00);
    let backing = MediumBacking::parse(args.flag("medium").unwrap_or("materialized"))?;
    let tile_mb = args.flag_parse::<usize>("tile-cache-mb")?.unwrap_or(0);
    let stripes =
        args.flag_parse::<usize>("tile-cache-stripes")?.unwrap_or(0).max(1);
    let medium = match backing {
        MediumBacking::Materialized => {
            Medium::Dense(TransmissionMatrix::sample(medium_seed, d_in, modes))
        }
        MediumBacking::Streamed => {
            Medium::Streamed(StreamedMedium::new(medium_seed, d_in, modes))
                .with_tile_cache_mb_striped(tile_mb, stripes)
        }
    };
    if let Some(path) = args.flag("tile-cache-load") {
        match &medium {
            Medium::Streamed(sm) => {
                let cache = sm.tile_cache().ok_or_else(|| {
                    anyhow::anyhow!("--tile-cache-load needs --tile-cache-mb >= 1")
                })?;
                let n = cache.load_snapshot(path)?;
                log::info!("tile cache warm-started: {n} tiles from {path}");
            }
            Medium::Dense(_) => {
                bail!("--tile-cache-load only applies to --medium streamed")
            }
        }
    }
    // Validate --tile-cache-save up front (the snapshot happens at
    // graceful shutdown — a bad combination must fail at startup, not
    // after hours of serving).
    if args.flag("tile-cache-save").is_some() {
        match &medium {
            Medium::Streamed(sm) if sm.tile_cache().is_some() => {}
            Medium::Streamed(_) => {
                bail!("--tile-cache-save needs --tile-cache-mb >= 1")
            }
            Medium::Dense(_) => {
                bail!("--tile-cache-save only applies to --medium streamed")
            }
        }
    }
    // Endpoints in the spec describe the LEADER's dial plan; this
    // process builds every shard locally and serves the requested ones.
    let spec = args.flag("topology").unwrap_or("opt:1");
    let mut topo = Topology::parse(spec)?.strip_endpoints().with_backing(backing);
    if let Some(p) = args.flag("partition") {
        topo = topo.with_partition(Partition::parse(p)?);
    }
    let mut params = OpuParams::default();
    if let Some(n) = args.flag_parse::<f32>("n-ph")? {
        params.n_ph = n;
    }
    if let Some(r) = args.flag_parse::<f32>("read-sigma")? {
        params.read_sigma = r;
    }
    let registry = Registry::new();
    let devices = topo.build_devices(params, &medium, noise_seed, &registry)?;
    let total = devices.len();
    let mut slots: Vec<Option<_>> = devices.into_iter().map(Some).collect();
    let ids: Vec<usize> = match args.flag("serve-shards") {
        Some(list) => list
            .split(',')
            .map(|t| {
                t.trim().parse::<usize>().map_err(|_| {
                    anyhow::anyhow!(
                        "--serve-shards expects comma-separated shard \
                         indices, got '{t}'"
                    )
                })
            })
            .collect::<Result<_>>()?,
        None => (0..total).collect(),
    };
    let mut serve = Vec::with_capacity(ids.len());
    for i in ids {
        anyhow::ensure!(
            i < total,
            "--serve-shards index {i} out of range (topology has {total} shards)"
        );
        let dev = slots[i]
            .take()
            .ok_or_else(|| anyhow::anyhow!("--serve-shards lists shard {i} twice"))?;
        serve.push((i as u32, dev));
    }
    let mut server_opts = ServerOptions::default();
    if let Some(spec) = args.flag("fault-plan") {
        server_opts.faults = Some(litl::net::FaultPlanCfg::parse(spec)?);
    }
    if let Some(cap) = args.flag_parse::<usize>("journal-cap")? {
        server_opts.journal_cap = cap;
    }
    let hosted = serve.len();
    install_shutdown_handler();
    let mut server = ProjectorServer::bind_with(&addr, serve, registry, server_opts)?;
    log::info!(
        "serving {hosted} of {total} '{}' shards (partition={}, medium={}, \
         d_in={d_in}, modes={modes})",
        topo.shorthand(),
        topo.partition.name(),
        backing.name(),
    );
    // The sentinel line is the spawn contract: parent processes (tests,
    // operators' scripts) read it to learn the bound address — with
    // `tcp:HOST:0` the kernel picks the port, so print what was bound.
    println!("litl-serve listening on {}", server.local_addr());
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    // Serve until SIGTERM/Ctrl-C: connections are handled by the
    // listener's own threads, so the main thread just polls the flag.
    while !SHUTDOWN.load(std::sync::atomic::Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    // Graceful shutdown: stop accepting, let in-flight projections
    // reply, then persist the warm tile cache before exit.
    log::info!("shutdown signal received: draining in-flight requests");
    server.shutdown();
    if !server.drain(std::time::Duration::from_secs(30)) {
        log::warn!("drain timed out with requests still executing");
    }
    if let Some(path) = args.flag("tile-cache-save") {
        if let Medium::Streamed(sm) = &medium {
            if let Some(cache) = sm.tile_cache() {
                cache
                    .save_snapshot(path)
                    .with_context(|| format!("saving tile cache snapshot {path}"))?;
                log::info!(
                    "tile cache snapshot saved to {path} ({} tiles)",
                    cache.tiles_resident()
                );
            }
        }
    }
    log::info!("litl-serve exiting cleanly");
    Ok(())
}

/// Set by the SIGTERM/SIGINT handler; the serve loop polls it.
static SHUTDOWN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: i32) {
    // Async-signal-safe: a single atomic store, nothing else.
    SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT (Ctrl-C) to the shutdown flag via libc's
/// `signal(2)` — no new dependency, and the default disposition (kill)
/// is replaced only for `litl serve`, where abrupt death would skip the
/// drain + tile-cache flush.
fn install_shutdown_handler() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_shutdown_signal);
        signal(SIGTERM, on_shutdown_signal);
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    args.ensure_known(&["checkpoint", "config", "artifacts", "test-size", "seed"])?;
    let ckpt = args
        .flag("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint required"))?;
    let mut cfg = TrainConfig::default();
    if let Some(c) = args.flag("config") {
        cfg.artifact_config = c.to_string();
    }
    if let Some(d) = args.flag("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(n) = args.flag_parse::<usize>("test-size")? {
        cfg.test_size = n;
    }
    if let Some(s) = args.flag_parse::<u64>("seed")? {
        cfg.seed = s;
    }
    let ds = data::load_or_synth(cfg.seed, 1, cfg.test_size)?;
    let mut trainer = Trainer::new(cfg)?;
    trainer.load_checkpoint(ckpt)?;
    let ev = trainer.evaluate(&ds, Split::Test)?;
    println!(
        "checkpoint {ckpt}: accuracy {:.2}% (loss {:.4}, {} samples)",
        ev.accuracy * 100.0,
        ev.loss,
        ev.samples
    );
    Ok(())
}

/// Device info + self-test: projection SNR at the configured noise.
fn cmd_opu(args: &Args) -> Result<()> {
    args.ensure_known(&["modes", "n-ph", "read-sigma", "frames"])?;
    let modes = args.flag_parse::<usize>("modes")?.unwrap_or(1024);
    let frames = args.flag_parse::<usize>("frames")?.unwrap_or(64);
    let mut params = OpuParams::default();
    if let Some(n) = args.flag_parse::<f32>("n-ph")? {
        params.n_ph = n;
    }
    if let Some(r) = args.flag_parse::<f32>("read-sigma")? {
        params.read_sigma = r;
    }
    println!("OPU (simulated): LightOn-style, off-axis holography");
    println!("  frame rate   : {} Hz", params.frame_rate_hz);
    println!("  power        : {} W", params.power_watts);
    println!("  max modes    : {}", params.max_modes);
    println!("  camera       : {}x oversample, 8-bit ADC", params.oversample);
    println!("  noise        : n_ph={} read_sigma={}", params.n_ph, params.read_sigma);

    let medium = TransmissionMatrix::sample(1, 10, modes);
    let mut opu = OpticalOpu::new(params, medium.clone(), 7);
    let mut rng = Pcg64::seeded(1);
    let mut e = Tensor::zeros(&[frames, 10]);
    for v in e.data_mut() {
        *v = (rng.next_below(3) as i64 - 1) as f32;
    }
    let (p1, _) = opu.project(&e)?;
    let exact = litl::tensor::matmul(&e, &medium.b_re);
    let err: f64 = p1
        .data()
        .iter()
        .zip(exact.data())
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
        / (p1.numel() as f64).sqrt();
    let sig: f64 = exact.data().iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
        / (exact.numel() as f64).sqrt();
    println!("\nself-test ({frames} frames x {modes} modes):");
    println!("  recovery SNR : {:.1} dB", 20.0 * (sig / err).log10());
    println!("  sim time     : {:.1} ms", opu.sim_seconds() * 1e3);
    println!("  energy       : {:.1} mJ", opu.stats().energy_joules * 1e3);
    Ok(())
}

/// One-step dataflow trace: the Fig. 1 schematic, live.
fn cmd_trace(args: &Args) -> Result<()> {
    args.ensure_known(&["algo", "artifacts", "config", "seed"])?;
    let mut cfg = TrainConfig::default();
    cfg.artifact_config = args.flag("config").unwrap_or("small").to_string();
    cfg.epochs = 1;
    cfg.train_size = 256;
    cfg.test_size = 64;
    if let Some(a) = args.flag("algo") {
        cfg.algo = Algo::parse(a)?;
    }
    if let Some(d) = args.flag("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    let ds = data::load_or_synth(cfg.seed, cfg.train_size, cfg.test_size)?;
    let mut trainer = Trainer::new(cfg.clone())?;
    trainer.warmup()?;
    let mut rng = Pcg64::seeded(0);
    let batch = trainer.model().batch;
    let (x, yoh) = ds.batches(Split::Train, batch, &mut rng).next().unwrap();

    println!("one {} step, batch={batch}:", cfg.algo.name());
    match cfg.algo {
        Algo::Bp => {
            println!("  [silicon] fwd+bwd+adam : bp_step (fused HLO)");
        }
        Algo::DfaFloat | Algo::DfaTernary => {
            println!("  [silicon] fwd+proj+adam: dfa_digital_step (fused HLO)");
        }
        Algo::Optical => {
            println!("  [silicon] forward      : fwd_train (HLO)");
            println!("  [light  ] projection   : SLM -> medium -> camera -> demod");
            println!("  [silicon] update       : dfa_apply (fused DFA+Adam HLO)");
        }
    }
    let t0 = std::time::Instant::now();
    let loss = trainer.train_step(&x, &yoh)?;
    println!("\nloss={loss:.4}  wall={:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    if trainer.sim_device_seconds() > 0.0 {
        println!(
            "simulated OPU time: {:.2} ms ({} frames @ 1.5 kHz)",
            trainer.sim_device_seconds() * 1e3,
            batch
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        r#"litl — Light-in-the-loop: photonic co-processor DFA training

USAGE: litl <command> [flags]

COMMANDS:
  train   Train the paper's MLP (synthetic MNIST unless LITL_MNIST_DIR set)
          --algo bp|dfa-float|dfa-ternary|optical   (default optical)
          --epochs N --lr F --theta F --seed N
          --config paper|small      artifact build config
          --projector native|hlo|digital
          --shards N                shard the projection across N virtual
                                    devices (projector farm)
          --topology SPEC           declarative device graph, e.g.
                                    hetero:opt:4+dig:2 or opt:2@3+dig:1
                                    (KIND:COUNT[@WEIGHT] groups joined
                                    by '+'; weights drive the batch-row
                                    split; replaces --shards); append
                                    !tcp:HOST:PORT or !uds:/PATH to a
                                    group to dial a `litl serve` process
                                    for those shards instead of building
                                    them in-process (bitwise identical
                                    either way)
          --partition modes|batch   farm partition axis: output-mode
                                    slices (default) or batch-row ranges
          --medium materialized|streamed
                                    medium backing: dense tensors or
                                    memory-less tile regeneration (1e5+
                                    modes; optical algo, native/digital
                                    projector)
          --tile-cache-mb N         bounded LRU cache of generated TM
                                    tiles for --medium streamed (MiB;
                                    default 0 = off): repeated training
                                    steps hit cache instead of
                                    regenerating; bitwise identical
                                    either way
          --tile-cache-stripes N    lock stripes for the tile cache
                                    (rounded up to a power of two;
                                    default 0 = auto: next pow2 >= the
                                    projection pool's threads); stripes
                                    change contention only, never bits
          --adapt-weights on|off    adapt shard weights to observed
                                    service rates (windowed EWMA;
                                    default off = the declared weights,
                                    bitwise-deterministic schedule)
          --failover on|off         trip erroring/stalled shards, drain
                                    their queues onto survivors, rebuild
                                    and re-admit via probation (default
                                    off)
          --admit-rate-fps F        per-client admission rate in
                                    frames/s (token bucket; 0 = off);
                                    tune admit_burst / admit_max_wait_ms
                                    via --set
          --trace off|summary|full  frame-level tracing (default off =
                                    zero overhead, pinned schedules stay
                                    bitwise): summary enables profiling
                                    histograms + periodic p50/p95/p99
                                    lines (cadence via --set
                                    summary_every_batches=N), full also
                                    records per-span events
          --trace-out FILE          write recorded spans as Chrome
                                    trace_event JSON at exit (load in
                                    Perfetto / chrome://tracing;
                                    requires --trace full)
          --metrics-out FILE        dump the metrics registry in
                                    Prometheus text exposition format at
                                    exit (any trace level)
          --resume FILE             load a checkpoint first and continue
                                    training from its step (killed-and-
                                    resumed == uninterrupted, bitwise,
                                    for deterministic projectors)
          --tile-cache-save FILE    snapshot the resident TM tiles at
                                    exit (streamed medium + cache only);
                                    --tile-cache-load FILE warm-starts
                                    the next run from it (bitwise replay,
                                    zero regeneration for cached tiles)
          --net-connect-timeout-ms N / --net-request-timeout-ms N /
          --net-reconnect-tries N   remote-shard client knobs (dial,
                                    per-request deadline, bounded
                                    exponential-backoff redial)
          --net-resume on|off       session resume for remote shards
                                    (default off): a redialed client
                                    re-attaches its stream and re-
                                    requests the in-flight frame, which
                                    the server's replay journal executes
                                    exactly once — faulted runs finish
                                    bitwise identical to fault-free
          --fault-plan SPEC         seeded deterministic fault injection
                                    for chaos drills, e.g.
                                    seed=7,cut_every=50,corrupt_ppm=2000
                                    (env: LITL_FAULT_PLAN; see
                                    docs/operator-guide.md; never
                                    set in production)
          --train-size N --test-size N --eval-every N
          --paper-lr                use the paper's lr for the algo
          --out-dir DIR             write loss curves (CSV)
          --checkpoint FILE         save state at the end
          --set key=value           raw config override (repeatable)
  serve   Host topology shards behind a wire-protocol listener — the
          remote end of --topology 'opt:2!tcp:HOST:PORT'
          --listen tcp:HOST:PORT|uds:/PATH   (tcp HOST:0 = pick a port;
                                    the bound address is printed as
                                    `litl-serve listening on ...`)
          --topology SPEC --partition modes|batch
          --medium materialized|streamed --d-in N --modes N
          --train-seed S            derive medium/noise seeds exactly as
                                    the leader with --seed S does, so a
                                    loopback shard is bitwise identical
                                    (or set --medium-seed/--noise-seed)
          --serve-shards 0,2        host a subset of the shard indices
          --tile-cache-mb N --tile-cache-stripes N --tile-cache-load FILE
          --tile-cache-save FILE    snapshot the warm tile cache during
                                    graceful shutdown (SIGTERM/Ctrl-C
                                    stops accepting, drains in-flight
                                    requests, then flushes the snapshot)
          --journal-cap N           session-resume journal entries kept
                                    (default 256; 0 disables resume
                                    server-side)
          --fault-plan SPEC         server-side device faults for chaos
                                    drills (dev_err_ppm, dev_stall_ppm…)
          --n-ph F --read-sigma F   OPU noise, as in train
  eval    Evaluate a checkpoint: --checkpoint FILE [--config paper]
  opu     Simulated device info + self-test [--modes N --n-ph F]
  trace   One-step dataflow trace (Fig. 1) [--algo optical]
  help    This text

ENV: LITL_MNIST_DIR (real MNIST IDX files), LITL_LOG (error|warn|info|debug)"#
    );
}
