//! Host-side f32 tensors.
//!
//! The heavy math of the request path runs inside the AOT-compiled HLO
//! artifacts; this module covers everything *around* it: parameter
//! initialization, the pure-rust reference trainers (test oracles and the
//! "silicon baseline" in benches), metrics, and the native OPU physics.
//!
//! Row-major, f32, shape-checked at runtime.  Matmul is cache-blocked
//! with a k-inner micro-kernel — good enough that the host baseline is an
//! honest comparator (see EXPERIMENTS.md §Perf), without pretending to be
//! a BLAS.

mod ops;

pub use ops::*;

use crate::util::rng::Pcg64;

/// Dense row-major f32 tensor (rank 1 or 2 in practice).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let numel = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; numel],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar(x: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![x],
        }
    }

    /// Standard-normal entries scaled by `scale`.
    pub fn randn(shape: &[usize], rng: &mut Pcg64, scale: f32) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data);
        if scale != 1.0 {
            for x in t.data.iter_mut() {
                *x *= scale;
            }
        }
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-matrix {:?}", self.shape);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on non-matrix {:?}", self.shape);
        self.shape[1]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert_eq!(self.shape.len(), 2);
        &mut self.data[r * self.shape[1] + c]
    }

    /// Row slice of a matrix.
    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Max |a - b| over all entries.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Pcg64::seeded(0);
        let t = Tensor::randn(&[100, 100], &mut rng, 2.0);
        let mean: f32 = t.data().iter().sum::<f32>() / t.numel() as f32;
        let var: f32 = t.data().iter().map(|x| x * x).sum::<f32>()
            / t.numel() as f32;
        assert!(mean.abs() < 0.05);
        assert!((var - 4.0).abs() < 0.2);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }
}
