//! Tensor operations: blocked matmul, transposed variants, elementwise.
//!
//! The matmul family is the host baseline's hot path ("digital projection
//! on silicon" in E2/E3), so it is cache-blocked (i-k-j loop order with a
//! j-vectorizable inner loop) rather than naive.  Each variant also has a
//! row-block-parallel twin (`*_pooled`) that fans output-row blocks out
//! over an [`exec::ThreadPool`] scope; serial and pooled paths share the
//! same per-row kernels, so their results are **bitwise identical** —
//! parallelism never changes the accumulation order of any output
//! element.  Everything else is straightforward elementwise code.
//!
//! [`exec::ThreadPool`]: crate::exec::ThreadPool

use super::Tensor;
use crate::exec::ThreadPool;

/// Cache block edges (tuned on the 1-core sandbox; see EXPERIMENTS §Perf).
const MC: usize = 64;
const KC: usize = 256;

/// Row-block kernel of `a @ b`: fills `od` (rows `r0 .. r0+rows` of the
/// output, row-major) with the k-blocked i-k-j product.  Accumulation
/// order per output element is ascending `kk` regardless of how rows are
/// partitioned, which is what guarantees serial/pooled bit parity.
fn matmul_rows(ad: &[f32], bd: &[f32], od: &mut [f32], r0: usize, rows: usize, k: usize, n: usize) {
    for kc in (0..k).step_by(KC) {
        let k_end = (kc + KC).min(k);
        for i in 0..rows {
            let arow = &ad[(r0 + i) * k..(r0 + i + 1) * k];
            let orow = &mut od[i * n..(i + 1) * n];
            for kk in kc..k_end {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &bd[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += aik * brow[j];
                }
            }
        }
    }
}

/// Row-block kernel of `aᵀ @ b` for output rows `r0 .. r0+rows`
/// (columns of `a`); `kk`-outer keeps the outer-product access pattern.
fn matmul_tn_rows(
    ad: &[f32],
    bd: &[f32],
    od: &mut [f32],
    r0: usize,
    rows: usize,
    k: usize,
    m: usize,
    n: usize,
) {
    for kk in 0..k {
        let arow = &ad[kk * m..(kk + 1) * m];
        let brow = &bd[kk * n..(kk + 1) * n];
        for i in 0..rows {
            let aki = arow[r0 + i];
            if aki == 0.0 {
                continue;
            }
            let orow = &mut od[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += aki * brow[j];
            }
        }
    }
}

/// Row-block kernel of `a @ bᵀ` for output rows `r0 .. r0+rows`.
fn matmul_nt_rows(
    ad: &[f32],
    bd: &[f32],
    od: &mut [f32],
    r0: usize,
    rows: usize,
    k: usize,
    n: usize,
) {
    for i in 0..rows {
        let arow = &ad[(r0 + i) * k..(r0 + i + 1) * k];
        let orow = &mut od[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            orow[j] = acc;
        }
    }
}

/// Rows per parallel job: enough blocks to balance the pool without
/// shredding cache locality.
fn row_block(m: usize, pool: &ThreadPool) -> usize {
    let jobs = pool.threads().max(1) * 2;
    m.div_ceil(jobs).max(1)
}

/// Below this many multiply-accumulates, fan-out overhead beats the
/// parallel win; run the kernel inline (same code, same bits).
const PAR_MIN_MACS: usize = 1 << 15;

/// Fan `rows`-partitioned work over the pool: `kernel(od_block, r0, rows)`.
/// `work` is the total MAC estimate used for the serial-fallback gate.
///
/// Panics if a row-block job panicked (the pool contains job panics, so
/// without this check a poisoned chunk would come back silently zeroed;
/// propagating mirrors what the serial kernel would have done).
fn parallel_rows<K>(od: &mut [f32], m: usize, n: usize, work: usize, pool: &ThreadPool, kernel: K)
where
    K: Fn(&mut [f32], usize, usize) + Send + Sync,
{
    if m == 0 || n == 0 {
        return;
    }
    if work < PAR_MIN_MACS {
        kernel(od, 0, m);
        return;
    }
    let block = row_block(m, pool);
    let kernel = &kernel;
    let completed = std::sync::atomic::AtomicUsize::new(0);
    let completed = &completed;
    let mut jobs = 0usize;
    pool.scope(|s| {
        for (bi, chunk) in od.chunks_mut(block * n).enumerate() {
            let r0 = bi * block;
            let rows = chunk.len() / n;
            jobs += 1;
            s.submit(move || {
                kernel(chunk, r0, rows);
                completed.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            });
        }
    });
    let done = completed.load(std::sync::atomic::Ordering::SeqCst);
    assert_eq!(done, jobs, "parallel matmul: {} row-block job(s) panicked", jobs - done);
}

/// `out = a @ b` — `[m,k] x [k,n] -> [m,n]`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    // MC-sized row blocks keep b's rows hot in cache; the partitioning
    // has no numeric effect (see `matmul_rows`).
    for r0 in (0..m).step_by(MC) {
        let rows = MC.min(m - r0);
        matmul_rows(ad, bd, &mut od[r0 * n..(r0 + rows) * n], r0, rows, k, n);
    }
    out
}

/// Row-block-parallel `a @ b` over a pool; bitwise equal to [`matmul`].
pub fn matmul_pooled(a: &Tensor, b: &Tensor, pool: &ThreadPool) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let work = m.saturating_mul(k).saturating_mul(n);
    parallel_rows(out.data_mut(), m, n, work, pool, |chunk, r0, rows| {
        matmul_rows(ad, bd, chunk, r0, rows, k, n)
    });
    out
}

/// `out = aᵀ @ b` — `[k,m] x [k,n] -> [m,n]` (outer-product reductions:
/// the DFA/BP weight-gradient shape).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut out = Tensor::zeros(&[m, n]);
    matmul_tn_rows(a.data(), b.data(), out.data_mut(), 0, m, k, m, n);
    out
}

/// Row-block-parallel `aᵀ @ b`; bitwise equal to [`matmul_tn`].
pub fn matmul_tn_pooled(a: &Tensor, b: &Tensor, pool: &ThreadPool) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let work = m.saturating_mul(k).saturating_mul(n);
    parallel_rows(out.data_mut(), m, n, work, pool, |chunk, r0, rows| {
        matmul_tn_rows(ad, bd, chunk, r0, rows, k, m, n)
    });
    out
}

/// `out = a @ bᵀ` — `[m,k] x [n,k] -> [m,n]` (backprop's `δ @ Wᵀ` shape).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut out = Tensor::zeros(&[m, n]);
    matmul_nt_rows(a.data(), b.data(), out.data_mut(), 0, m, k, n);
    out
}

/// Row-block-parallel `a @ bᵀ`; bitwise equal to [`matmul_nt`].
pub fn matmul_nt_pooled(a: &Tensor, b: &Tensor, pool: &ThreadPool) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let work = m.saturating_mul(k).saturating_mul(n);
    parallel_rows(out.data_mut(), m, n, work, pool, |chunk, r0, rows| {
        matmul_nt_rows(ad, bd, chunk, r0, rows, k, n)
    });
    out
}

/// `x + row` broadcast over rows (bias add), in place.
pub fn add_row_inplace(x: &mut Tensor, row: &[f32]) {
    let n = x.cols();
    assert_eq!(row.len(), n);
    for chunk in x.data_mut().chunks_mut(n) {
        for (v, b) in chunk.iter_mut().zip(row) {
            *v += b;
        }
    }
}

/// Elementwise tanh, in place.
pub fn tanh_inplace(x: &mut Tensor) {
    for v in x.data_mut().iter_mut() {
        *v = v.tanh();
    }
}

/// Row-wise softmax.
pub fn softmax(x: &Tensor) -> Tensor {
    let n = x.cols();
    let mut out = x.clone();
    for row in out.data_mut().chunks_mut(n) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    out
}

/// Column sums of a matrix: `[m,n] -> [n]`.
pub fn col_sum(x: &Tensor) -> Vec<f32> {
    let n = x.cols();
    let mut out = vec![0.0f32; n];
    for row in x.data().chunks(n) {
        for (o, v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

/// `a ⊙ (1 - b²)` — the tanh-derivative gate used by both trainers.
pub fn gate_tanh(a: &Tensor, h: &Tensor) -> Tensor {
    assert_eq!(a.shape(), h.shape());
    let data = a
        .data()
        .iter()
        .zip(h.data())
        .map(|(&p, &hv)| p * (1.0 - hv * hv))
        .collect();
    Tensor::from_vec(a.shape(), data)
}

/// `acc[j] += s * x[j]` — the accumulate step of the streamed (tile-at-
/// a-time) projection.  Kept as the exact expression of `matmul_rows`'s
/// inner loop so a streamed projection that walks input rows ascending
/// and skips zero coefficients is **bitwise identical** to the blocked
/// matmul over the materialized matrix.
#[inline]
pub fn axpy(acc: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (o, v) in acc.iter_mut().zip(x) {
        *o += s * v;
    }
}

/// Scale in place.
pub fn scale_inplace(x: &mut Tensor, s: f32) {
    for v in x.data_mut().iter_mut() {
        *v *= s;
    }
}

/// Eq. 4 ternarization into a fresh tensor.
pub fn ternarize(x: &Tensor, threshold: f32) -> Tensor {
    let data = x
        .data()
        .iter()
        .map(|&v| {
            if v > threshold {
                1.0
            } else if v < -threshold {
                -1.0
            } else {
                0.0
            }
        })
        .collect();
    Tensor::from_vec(x.shape(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at(i, kk) * b.at(kk, j);
                }
                *out.at_mut(i, j) = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (65, 300, 33), (128, 784, 64)] {
            let a = Tensor::randn(&[m, k], &mut rng, 1.0);
            let b = Tensor::randn(&[k, n], &mut rng, 1.0);
            let got = matmul(&a, &b);
            let want = naive_matmul(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn transposed_variants_match() {
        let mut rng = Pcg64::seeded(2);
        let (m, k, n) = (17, 23, 9);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let want = matmul(&a, &b);

        // aᵀ stored: build at = transpose(a), check matmul_tn(at, b).
        let mut at = Tensor::zeros(&[k, m]);
        for i in 0..m {
            for kk in 0..k {
                *at.at_mut(kk, i) = a.at(i, kk);
            }
        }
        assert!(matmul_tn(&at, &b).max_abs_diff(&want) < 1e-4);

        // bᵀ stored: check matmul_nt(a, bt).
        let mut bt = Tensor::zeros(&[n, k]);
        for kk in 0..k {
            for j in 0..n {
                *bt.at_mut(j, kk) = b.at(kk, j);
            }
        }
        assert!(matmul_nt(&a, &bt).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn pooled_matmuls_are_bitwise_identical_to_serial() {
        let pool = ThreadPool::new(4, 64);
        let mut rng = Pcg64::seeded(9);
        for (m, k, n) in [(1, 1, 1), (7, 13, 5), (65, 300, 33), (128, 784, 64)] {
            let a = Tensor::randn(&[m, k], &mut rng, 1.0);
            let b = Tensor::randn(&[k, n], &mut rng, 1.0);
            assert_eq!(matmul_pooled(&a, &b, &pool), matmul(&a, &b), "({m},{k},{n})");

            let at = Tensor::randn(&[k, m], &mut rng, 1.0);
            assert_eq!(matmul_tn_pooled(&at, &b, &pool), matmul_tn(&at, &b));

            let bt = Tensor::randn(&[n, k], &mut rng, 1.0);
            assert_eq!(matmul_nt_pooled(&a, &bt, &pool), matmul_nt(&a, &bt));
        }
    }

    #[test]
    fn pooled_matmul_handles_degenerate_shapes() {
        let pool = ThreadPool::new(2, 16);
        let a = Tensor::zeros(&[0, 5]);
        let b = Tensor::zeros(&[5, 4]);
        assert_eq!(matmul_pooled(&a, &b, &pool).shape(), &[0, 4]);
        let a = Tensor::zeros(&[3, 5]);
        let b = Tensor::zeros(&[5, 0]);
        assert_eq!(matmul_pooled(&a, &b, &pool).shape(), &[3, 0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Pcg64::seeded(3);
        let x = Tensor::randn(&[5, 11], &mut rng, 3.0);
        let s = softmax(&x);
        for row in s.data().chunks(11) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let y = Tensor::from_vec(&[1, 3], vec![1001.0, 1002.0, 1003.0]);
        assert!(softmax(&x).max_abs_diff(&softmax(&y)) < 1e-6);
    }

    #[test]
    fn gate_and_ternarize() {
        let p = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, -1.0]);
        let h = Tensor::from_vec(&[1, 3], vec![0.0, 0.5, 1.0]);
        let g = gate_tanh(&p, &h);
        assert_eq!(g.data(), &[1.0, 2.0 * 0.75, 0.0]);

        let x = Tensor::from_vec(&[1, 4], vec![0.2, 0.05, -0.2, -0.05]);
        assert_eq!(ternarize(&x, 0.1).data(), &[1.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn axpy_accumulation_is_bitwise_the_matmul_inner_loop() {
        // Row-by-row axpy over a's columns must equal matmul exactly.
        let mut rng = Pcg64::seeded(8);
        let (m, k, n) = (5usize, 13usize, 37usize);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let want = matmul(&a, &b);
        let mut got = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let orow = &mut got.data_mut()[i * n..(i + 1) * n];
            for kk in 0..k {
                let s = a.at(i, kk);
                if s == 0.0 {
                    continue;
                }
                axpy(orow, s, &b.data()[kk * n..(kk + 1) * n]);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn bias_and_colsum() {
        let mut x = Tensor::zeros(&[2, 3]);
        add_row_inplace(&mut x, &[1.0, 2.0, 3.0]);
        assert_eq!(col_sum(&x), vec![2.0, 4.0, 6.0]);
    }
}
