//! The diffusive medium: a fixed complex Gaussian transmission matrix.
//!
//! Multiple light scattering through a thick diffuser acts on the input
//! field as a dense complex matrix with i.i.d. CN(0, 1) entries (Saade et
//! al. 2016).  The matrix is *physical*: nobody stores it, it never
//! changes, and its size is set by SLM/camera geometry, not memory.  Here
//! it is sampled once per device from a seed (re/im ~ N(0, 1/2)) so runs
//! are reproducible; the "never stored" property is modeled in the E4
//! bench by streaming row generation ([`TransmissionMatrix::stream_row`]).

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Transmission matrix quadratures, `[d_in, modes]` each.
#[derive(Clone, Debug)]
pub struct TransmissionMatrix {
    pub d_in: usize,
    pub modes: usize,
    pub b_re: Tensor,
    pub b_im: Tensor,
    seed: u64,
}

const SCALE: f32 = std::f32::consts::FRAC_1_SQRT_2; // re/im ~ N(0, 1/2)

impl TransmissionMatrix {
    /// Sample a dense medium (the normal path; dims at MNIST scale).
    pub fn sample(seed: u64, d_in: usize, modes: usize) -> Self {
        let mut rng = Pcg64::new(seed, 0x0b7);
        let b_re = Tensor::randn(&[d_in, modes], &mut rng, SCALE);
        let b_im = Tensor::randn(&[d_in, modes], &mut rng, SCALE);
        TransmissionMatrix {
            d_in,
            modes,
            b_re,
            b_im,
            seed,
        }
    }

    /// Generate row `r` (input dimension r's couplings) without storing
    /// the matrix — models the "memory-less" property at huge dims.
    /// Deterministic per (seed, row): independent stream per row.
    pub fn stream_row(seed: u64, row: usize, modes: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed ^ 0x5eed, row as u64);
        let mut re = vec![0.0f32; modes];
        let mut im = vec![0.0f32; modes];
        for j in 0..modes {
            re[j] = rng.next_normal_f32() * SCALE;
            im[j] = rng.next_normal_f32() * SCALE;
        }
        (re, im)
    }

    /// Memory-less projection of one ternary vector using streamed rows:
    /// only touches rows where `e` is non-zero (the SLM's "dark pixels
    /// contribute no light" physics).
    pub fn project_streamed(seed: u64, e: &[f32], modes: usize) -> (Vec<f32>, Vec<f32>) {
        let mut yre = vec![0.0f32; modes];
        let mut yim = vec![0.0f32; modes];
        for (row, &v) in e.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let (re, im) = Self::stream_row(seed, row, modes);
            for j in 0..modes {
                yre[j] += v * re[j];
                yim[j] += v * im[j];
            }
        }
        (yre, yim)
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_unit_power() {
        let a = TransmissionMatrix::sample(1, 50, 80);
        let b = TransmissionMatrix::sample(1, 50, 80);
        assert_eq!(a.b_re, b.b_re);
        let power: f32 = a
            .b_re
            .data()
            .iter()
            .zip(a.b_im.data())
            .map(|(r, i)| r * r + i * i)
            .sum::<f32>()
            / (50.0 * 80.0);
        assert!((power - 1.0).abs() < 0.05, "mean |B|² = {power}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = TransmissionMatrix::sample(1, 10, 10);
        let b = TransmissionMatrix::sample(2, 10, 10);
        assert!(a.b_re.max_abs_diff(&b.b_re) > 0.1);
    }

    #[test]
    fn stream_row_is_deterministic_and_independent() {
        let (r0a, i0a) = TransmissionMatrix::stream_row(9, 0, 32);
        let (r0b, _) = TransmissionMatrix::stream_row(9, 0, 32);
        let (r1, i1) = TransmissionMatrix::stream_row(9, 1, 32);
        assert_eq!(r0a, r0b);
        assert_ne!(r0a, r1);
        assert_ne!(i0a, i1);
    }

    #[test]
    fn streamed_projection_matches_dense_structure() {
        // Not the same matrix as `sample` (different streams), but same
        // statistics and exact linearity: P(e1 + e2) = P(e1) + P(e2).
        let modes = 64;
        let e1: Vec<f32> = (0..10).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let e2: Vec<f32> = (0..10).map(|i| if i % 4 == 1 { -1.0 } else { 0.0 }).collect();
        let sum: Vec<f32> = e1.iter().zip(&e2).map(|(a, b)| a + b).collect();
        let (p1, _) = TransmissionMatrix::project_streamed(3, &e1, modes);
        let (p2, _) = TransmissionMatrix::project_streamed(3, &e2, modes);
        let (ps, _) = TransmissionMatrix::project_streamed(3, &sum, modes);
        for j in 0..modes {
            assert!((ps[j] - p1[j] - p2[j]).abs() < 1e-5);
        }
    }
}
