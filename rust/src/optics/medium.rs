//! The diffusive medium: a fixed complex Gaussian transmission matrix.
//!
//! Multiple light scattering through a thick diffuser acts on the input
//! field as a dense complex matrix with i.i.d. CN(0, 1) entries (Saade et
//! al. 2016).  The matrix is *physical*: nobody stores it, it never
//! changes, and its size is set by SLM/camera geometry, not memory.
//!
//! ## Counter-addressable generation (materialized ⇔ streamed determinism)
//!
//! The matrix is **defined** by its seed, not by a stored buffer: row `r`
//! is the Box–Muller output of the dedicated PCG stream
//! `Pcg64::new(seed ^ 0x5eed, r)`, interleaved `(re[j], im[j])` per
//! column, so column `c` of a row is Box–Muller *pair* `c` of that
//! stream — reachable in O(log c) via [`Pcg64::advance`] without
//! generating the prefix.  Both medium backings realize the same
//! definition:
//!
//! * **Materialized** ([`TransmissionMatrix::sample`]) caches every row
//!   into dense `[d_in, modes]` quadrature tensors — the right call at
//!   MNIST scale, where the slice fits and is reused every step.
//! * **Streamed** ([`super::stream::StreamedMedium`]) regenerates tiles
//!   of rows on the fly into reusable scratch and never holds a
//!   `[modes, d_in]` slice — the paper's "nobody stores it" property at
//!   1e5+ modes.
//!
//! Because both backings read the identical entry values and accumulate
//! in the identical order (ascending input row, zero rows skipped), the
//! streamed projection is **bitwise equal** to the materialized one for
//! the digital path and for the optics up to the camera (hence bitwise
//! through noiseless *and* noisy optics, since the camera-noise stream
//! does not depend on the backing).  The one caveat: Box–Muller rejects
//! a uniform draw of exactly 0.0 (probability 2⁻⁵³ per pair), which
//! would shift the pair↔column alignment for the rest of that row; no
//! realizable seed/shape in the tests hits it.
//!
//! Since the generation walk evaluates its transcendentals through the
//! crate-owned polynomial kernels ([`crate::util::mathk`], `+ − × ÷
//! sqrt` only — no libm in the loop), the entry *values* are also
//! **platform-independent**: the seed defines the same matrix bits on
//! every IEEE-754 host, not just within one libc build.

use crate::tensor::{axpy, Tensor};
use crate::util::rng::Pcg64;

/// Transmission matrix quadratures, `[d_in, modes]` each.
#[derive(Clone, Debug)]
pub struct TransmissionMatrix {
    pub d_in: usize,
    pub modes: usize,
    pub b_re: Tensor,
    pub b_im: Tensor,
    seed: u64,
}

const SCALE: f32 = std::f32::consts::FRAC_1_SQRT_2; // re/im ~ N(0, 1/2)

impl TransmissionMatrix {
    /// Materialize the dense medium from the counter-addressable row
    /// streams (the normal path; dims at MNIST scale).  Bitwise equal,
    /// row for row, to what [`TransmissionMatrix::stream_row`] and the
    /// streamed backing ([`super::stream::StreamedMedium`]) generate.
    pub fn sample(seed: u64, d_in: usize, modes: usize) -> Self {
        let mut b_re = Tensor::zeros(&[d_in, modes]);
        let mut b_im = Tensor::zeros(&[d_in, modes]);
        for r in 0..d_in {
            Self::stream_row_into(
                seed,
                r,
                &mut b_re.data_mut()[r * modes..(r + 1) * modes],
                &mut b_im.data_mut()[r * modes..(r + 1) * modes],
            );
        }
        TransmissionMatrix {
            d_in,
            modes,
            b_re,
            b_im,
            seed,
        }
    }

    /// Generate row `r` (input dimension r's couplings) without storing
    /// the matrix — the "memory-less" property at huge dims.
    /// Deterministic per (seed, row): independent stream per row.
    pub fn stream_row(seed: u64, row: usize, modes: usize) -> (Vec<f32>, Vec<f32>) {
        let mut re = vec![0.0f32; modes];
        let mut im = vec![0.0f32; modes];
        Self::stream_row_into(seed, row, &mut re, &mut im);
        (re, im)
    }

    /// Allocation-free [`TransmissionMatrix::stream_row`]: fills the
    /// caller's scratch with columns `0..re.len()` of row `row`.  The
    /// hot-loop form — the streamed engine and `project_streamed` call
    /// this once per (row, tile) into reusable buffers.
    pub fn stream_row_into(seed: u64, row: usize, re: &mut [f32], im: &mut [f32]) {
        Self::stream_row_window_into(seed, row, 0, re, im);
    }

    /// Fill scratch with columns `col0 .. col0 + re.len()` of row `row`
    /// — the tile primitive.  Column `c` is Box–Muller pair `c` of the
    /// row stream, so the window seeks there with one O(log col0)
    /// [`Pcg64::advance`] and then generates sequentially through the
    /// batched lane kernel ([`Pcg64::fill_normal_quadrature`]), which is
    /// bitwise identical to the scalar per-pair walk it replaced (pinned
    /// in `util::rng` tests, including `advance`-seeked odd offsets).
    pub fn stream_row_window_into(
        seed: u64,
        row: usize,
        col0: usize,
        re: &mut [f32],
        im: &mut [f32],
    ) {
        debug_assert_eq!(re.len(), im.len());
        let mut rng = Pcg64::new(seed ^ 0x5eed, row as u64);
        if col0 > 0 {
            // One pair = (re, im) = exactly 2 raw draws.
            rng.advance(2 * col0 as u128);
        }
        rng.fill_normal_quadrature(SCALE, re, im);
    }

    /// Memory-less projection of one ternary vector using streamed rows:
    /// only touches rows where `e` is non-zero (the SLM's "dark pixels
    /// contribute no light" physics).  Row scratch is reused across the
    /// whole projection — two `modes`-sized buffers, independent of
    /// `d_in`.  Bitwise equal to `e @ b_re` / `e @ b_im` on the
    /// materialized medium of the same seed (same entries, same
    /// ascending-row accumulation, same zero skip).
    pub fn project_streamed(seed: u64, e: &[f32], modes: usize) -> (Vec<f32>, Vec<f32>) {
        let mut yre = vec![0.0f32; modes];
        let mut yim = vec![0.0f32; modes];
        let mut re = vec![0.0f32; modes];
        let mut im = vec![0.0f32; modes];
        for (row, &v) in e.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            Self::stream_row_into(seed, row, &mut re, &mut im);
            axpy(&mut yre, v, &re);
            axpy(&mut yim, v, &im);
        }
        (yre, yim)
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Column-slice the medium to the mode range `[start, end)`.
    ///
    /// A shard of a farm device sees exactly the couplings of its own
    /// camera region: the same physical matrix, restricted to a
    /// contiguous output-mode window.  Slicing and re-concatenating
    /// ([`TransmissionMatrix::concat_modes`]) is the identity, which is
    /// what makes the farm's `shards=1` path bit-identical to the
    /// single-device path.
    pub fn slice_modes(&self, start: usize, end: usize) -> TransmissionMatrix {
        assert!(start < end && end <= self.modes, "mode slice {start}..{end}");
        let width = end - start;
        let mut b_re = Tensor::zeros(&[self.d_in, width]);
        let mut b_im = Tensor::zeros(&[self.d_in, width]);
        for r in 0..self.d_in {
            let src = r * self.modes + start;
            let dst = r * width;
            b_re.data_mut()[dst..dst + width]
                .copy_from_slice(&self.b_re.data()[src..src + width]);
            b_im.data_mut()[dst..dst + width]
                .copy_from_slice(&self.b_im.data()[src..src + width]);
        }
        TransmissionMatrix {
            d_in: self.d_in,
            modes: width,
            b_re,
            b_im,
            seed: self.seed,
        }
    }

    /// Partition the mode axis into `shards` contiguous, balanced
    /// windows ([`crate::util::balanced_widths`] — the same arithmetic
    /// every shard split in the crate uses).  The concatenation of the
    /// shards is the original medium, in order.
    pub fn split_modes(&self, shards: usize) -> Vec<TransmissionMatrix> {
        assert!(shards >= 1, "need at least one shard");
        assert!(
            shards <= self.modes,
            "cannot split {} modes across {shards} shards",
            self.modes
        );
        let mut out = Vec::with_capacity(shards);
        let mut start = 0usize;
        for width in crate::util::balanced_widths(self.modes, shards) {
            out.push(self.slice_modes(start, start + width));
            start += width;
        }
        debug_assert_eq!(start, self.modes);
        out
    }

    /// Stack shard media back along the mode axis (inverse of
    /// [`TransmissionMatrix::split_modes`]); the test oracle for farm
    /// parity ("the equivalent stacked medium").
    pub fn concat_modes(parts: &[TransmissionMatrix]) -> TransmissionMatrix {
        assert!(!parts.is_empty());
        let d_in = parts[0].d_in;
        assert!(parts.iter().all(|p| p.d_in == d_in), "d_in mismatch");
        let modes: usize = parts.iter().map(|p| p.modes).sum();
        let mut b_re = Tensor::zeros(&[d_in, modes]);
        let mut b_im = Tensor::zeros(&[d_in, modes]);
        let mut at = 0usize;
        for part in parts {
            for r in 0..d_in {
                let dst = r * modes + at;
                let src = r * part.modes;
                b_re.data_mut()[dst..dst + part.modes]
                    .copy_from_slice(&part.b_re.data()[src..src + part.modes]);
                b_im.data_mut()[dst..dst + part.modes]
                    .copy_from_slice(&part.b_im.data()[src..src + part.modes]);
            }
            at += part.modes;
        }
        TransmissionMatrix {
            d_in,
            modes,
            b_re,
            b_im,
            seed: parts[0].seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_unit_power() {
        let a = TransmissionMatrix::sample(1, 50, 80);
        let b = TransmissionMatrix::sample(1, 50, 80);
        assert_eq!(a.b_re, b.b_re);
        let power: f32 = a
            .b_re
            .data()
            .iter()
            .zip(a.b_im.data())
            .map(|(r, i)| r * r + i * i)
            .sum::<f32>()
            / (50.0 * 80.0);
        assert!((power - 1.0).abs() < 0.05, "mean |B|² = {power}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = TransmissionMatrix::sample(1, 10, 10);
        let b = TransmissionMatrix::sample(2, 10, 10);
        assert!(a.b_re.max_abs_diff(&b.b_re) > 0.1);
    }

    #[test]
    fn stream_row_is_deterministic_and_independent() {
        let (r0a, i0a) = TransmissionMatrix::stream_row(9, 0, 32);
        let (r0b, _) = TransmissionMatrix::stream_row(9, 0, 32);
        let (r1, i1) = TransmissionMatrix::stream_row(9, 1, 32);
        assert_eq!(r0a, r0b);
        assert_ne!(r0a, r1);
        assert_ne!(i0a, i1);
    }

    #[test]
    fn sample_rows_are_the_row_streams() {
        // The materialized medium IS the stacked row streams — the
        // determinism contract between the two backings.
        let full = TransmissionMatrix::sample(6, 7, 33);
        for r in 0..7 {
            let (re, im) = TransmissionMatrix::stream_row(6, r, 33);
            assert_eq!(&full.b_re.data()[r * 33..(r + 1) * 33], &re[..]);
            assert_eq!(&full.b_im.data()[r * 33..(r + 1) * 33], &im[..]);
        }
    }

    #[test]
    fn row_window_is_counter_addressable() {
        // A window generated after an advance() seek must be bitwise the
        // corresponding slice of the full row, at any offset.
        let modes = 97;
        let (re_full, im_full) = TransmissionMatrix::stream_row(13, 4, modes);
        for (col0, w) in [(0usize, 97usize), (1, 10), (50, 47), (96, 1)] {
            let mut re = vec![0.0f32; w];
            let mut im = vec![0.0f32; w];
            TransmissionMatrix::stream_row_window_into(13, 4, col0, &mut re, &mut im);
            assert_eq!(&re[..], &re_full[col0..col0 + w], "col0 {col0}");
            assert_eq!(&im[..], &im_full[col0..col0 + w], "col0 {col0}");
        }
    }

    #[test]
    fn row_window_is_bitwise_the_scalar_pair_walk() {
        // The generation contract, spelled out: entry (r, c) of the
        // matrix is Box–Muller pair c of the row stream, cos quadrature
        // to re, sin to im, scaled in f32.  The batched lane kernel
        // behind `stream_row_window_into` must reproduce this scalar
        // walk bit for bit at any window offset.
        for (col0, w) in [(0usize, 100usize), (1, 37), (7, 64), (4096, 33)] {
            let mut rng = Pcg64::new(21 ^ 0x5eed, 6);
            rng.advance(2 * col0 as u128);
            let mut want_re = vec![0.0f32; w];
            let mut want_im = vec![0.0f32; w];
            for k in 0..w {
                want_re[k] = rng.next_normal_f32() * SCALE;
                want_im[k] = rng.next_normal_f32() * SCALE;
            }
            let mut re = vec![0.0f32; w];
            let mut im = vec![0.0f32; w];
            TransmissionMatrix::stream_row_window_into(21, 6, col0, &mut re, &mut im);
            assert_eq!(
                re.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want_re.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "re col0 {col0}"
            );
            assert_eq!(
                im.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want_im.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "im col0 {col0}"
            );
        }
    }

    #[test]
    fn split_concat_roundtrips() {
        let full = TransmissionMatrix::sample(4, 12, 37);
        for shards in [1usize, 2, 3, 5, 7, 37] {
            let parts = full.split_modes(shards);
            assert_eq!(parts.len(), shards);
            let widths: Vec<usize> = parts.iter().map(|p| p.modes).collect();
            assert_eq!(widths.iter().sum::<usize>(), 37);
            assert!(widths.iter().max().unwrap() - widths.iter().min().unwrap() <= 1);
            let back = TransmissionMatrix::concat_modes(&parts);
            assert_eq!(back.b_re, full.b_re);
            assert_eq!(back.b_im, full.b_im);
        }
    }

    #[test]
    fn slice_is_a_column_window() {
        let full = TransmissionMatrix::sample(9, 5, 10);
        let mid = full.slice_modes(3, 7);
        assert_eq!(mid.modes, 4);
        for r in 0..5 {
            for c in 0..4 {
                assert_eq!(mid.b_re.at(r, c), full.b_re.at(r, 3 + c));
                assert_eq!(mid.b_im.at(r, c), full.b_im.at(r, 3 + c));
            }
        }
    }

    #[test]
    fn streamed_projection_is_bitwise_the_dense_projection() {
        // Same matrix as `sample` now (one generation scheme): the
        // memory-less path must reproduce the dense matvec exactly.
        let (d_in, modes) = (10usize, 64usize);
        let dense = TransmissionMatrix::sample(3, d_in, modes);
        let e: Vec<f32> = (0..d_in)
            .map(|i| match i % 3 {
                0 => 1.0,
                1 => -1.0,
                _ => 0.0,
            })
            .collect();
        let et = Tensor::from_vec(&[1, d_in], e.clone());
        let want_re = crate::tensor::matmul(&et, &dense.b_re);
        let want_im = crate::tensor::matmul(&et, &dense.b_im);
        let (p_re, p_im) = TransmissionMatrix::project_streamed(3, &e, modes);
        assert_eq!(p_re, want_re.data());
        assert_eq!(p_im, want_im.data());
    }

    #[test]
    fn streamed_projection_is_linear() {
        let modes = 64;
        let e1: Vec<f32> = (0..10).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let e2: Vec<f32> = (0..10).map(|i| if i % 4 == 1 { -1.0 } else { 0.0 }).collect();
        let sum: Vec<f32> = e1.iter().zip(&e2).map(|(a, b)| a + b).collect();
        let (p1, _) = TransmissionMatrix::project_streamed(3, &e1, modes);
        let (p2, _) = TransmissionMatrix::project_streamed(3, &e2, modes);
        let (ps, _) = TransmissionMatrix::project_streamed(3, &sum, modes);
        for j in 0..modes {
            assert!((ps[j] - p1[j] - p2[j]).abs() < 1e-5);
        }
    }
}
