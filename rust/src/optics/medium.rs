//! The diffusive medium: a fixed complex Gaussian transmission matrix.
//!
//! Multiple light scattering through a thick diffuser acts on the input
//! field as a dense complex matrix with i.i.d. CN(0, 1) entries (Saade et
//! al. 2016).  The matrix is *physical*: nobody stores it, it never
//! changes, and its size is set by SLM/camera geometry, not memory.  Here
//! it is sampled once per device from a seed (re/im ~ N(0, 1/2)) so runs
//! are reproducible; the "never stored" property is modeled in the E4
//! bench by streaming row generation ([`TransmissionMatrix::stream_row`]).

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Transmission matrix quadratures, `[d_in, modes]` each.
#[derive(Clone, Debug)]
pub struct TransmissionMatrix {
    pub d_in: usize,
    pub modes: usize,
    pub b_re: Tensor,
    pub b_im: Tensor,
    seed: u64,
}

const SCALE: f32 = std::f32::consts::FRAC_1_SQRT_2; // re/im ~ N(0, 1/2)

impl TransmissionMatrix {
    /// Sample a dense medium (the normal path; dims at MNIST scale).
    pub fn sample(seed: u64, d_in: usize, modes: usize) -> Self {
        let mut rng = Pcg64::new(seed, 0x0b7);
        let b_re = Tensor::randn(&[d_in, modes], &mut rng, SCALE);
        let b_im = Tensor::randn(&[d_in, modes], &mut rng, SCALE);
        TransmissionMatrix {
            d_in,
            modes,
            b_re,
            b_im,
            seed,
        }
    }

    /// Generate row `r` (input dimension r's couplings) without storing
    /// the matrix — models the "memory-less" property at huge dims.
    /// Deterministic per (seed, row): independent stream per row.
    pub fn stream_row(seed: u64, row: usize, modes: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed ^ 0x5eed, row as u64);
        let mut re = vec![0.0f32; modes];
        let mut im = vec![0.0f32; modes];
        for j in 0..modes {
            re[j] = rng.next_normal_f32() * SCALE;
            im[j] = rng.next_normal_f32() * SCALE;
        }
        (re, im)
    }

    /// Memory-less projection of one ternary vector using streamed rows:
    /// only touches rows where `e` is non-zero (the SLM's "dark pixels
    /// contribute no light" physics).
    pub fn project_streamed(seed: u64, e: &[f32], modes: usize) -> (Vec<f32>, Vec<f32>) {
        let mut yre = vec![0.0f32; modes];
        let mut yim = vec![0.0f32; modes];
        for (row, &v) in e.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let (re, im) = Self::stream_row(seed, row, modes);
            for j in 0..modes {
                yre[j] += v * re[j];
                yim[j] += v * im[j];
            }
        }
        (yre, yim)
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Column-slice the medium to the mode range `[start, end)`.
    ///
    /// A shard of a farm device sees exactly the couplings of its own
    /// camera region: the same physical matrix, restricted to a
    /// contiguous output-mode window.  Slicing and re-concatenating
    /// ([`TransmissionMatrix::concat_modes`]) is the identity, which is
    /// what makes the farm's `shards=1` path bit-identical to the
    /// single-device path.
    pub fn slice_modes(&self, start: usize, end: usize) -> TransmissionMatrix {
        assert!(start < end && end <= self.modes, "mode slice {start}..{end}");
        let width = end - start;
        let mut b_re = Tensor::zeros(&[self.d_in, width]);
        let mut b_im = Tensor::zeros(&[self.d_in, width]);
        for r in 0..self.d_in {
            let src = r * self.modes + start;
            let dst = r * width;
            b_re.data_mut()[dst..dst + width]
                .copy_from_slice(&self.b_re.data()[src..src + width]);
            b_im.data_mut()[dst..dst + width]
                .copy_from_slice(&self.b_im.data()[src..src + width]);
        }
        TransmissionMatrix {
            d_in: self.d_in,
            modes: width,
            b_re,
            b_im,
            seed: self.seed,
        }
    }

    /// Partition the mode axis into `shards` contiguous, balanced
    /// windows (sizes differ by at most one; earlier shards get the
    /// remainder).  The concatenation of the shards is the original
    /// medium, in order.
    pub fn split_modes(&self, shards: usize) -> Vec<TransmissionMatrix> {
        assert!(shards >= 1, "need at least one shard");
        assert!(
            shards <= self.modes,
            "cannot split {} modes across {shards} shards",
            self.modes
        );
        let base = self.modes / shards;
        let extra = self.modes % shards;
        let mut out = Vec::with_capacity(shards);
        let mut start = 0usize;
        for i in 0..shards {
            let width = base + usize::from(i < extra);
            out.push(self.slice_modes(start, start + width));
            start += width;
        }
        debug_assert_eq!(start, self.modes);
        out
    }

    /// Stack shard media back along the mode axis (inverse of
    /// [`TransmissionMatrix::split_modes`]); the test oracle for farm
    /// parity ("the equivalent stacked medium").
    pub fn concat_modes(parts: &[TransmissionMatrix]) -> TransmissionMatrix {
        assert!(!parts.is_empty());
        let d_in = parts[0].d_in;
        assert!(parts.iter().all(|p| p.d_in == d_in), "d_in mismatch");
        let modes: usize = parts.iter().map(|p| p.modes).sum();
        let mut b_re = Tensor::zeros(&[d_in, modes]);
        let mut b_im = Tensor::zeros(&[d_in, modes]);
        let mut at = 0usize;
        for part in parts {
            for r in 0..d_in {
                let dst = r * modes + at;
                let src = r * part.modes;
                b_re.data_mut()[dst..dst + part.modes]
                    .copy_from_slice(&part.b_re.data()[src..src + part.modes]);
                b_im.data_mut()[dst..dst + part.modes]
                    .copy_from_slice(&part.b_im.data()[src..src + part.modes]);
            }
            at += part.modes;
        }
        TransmissionMatrix {
            d_in,
            modes,
            b_re,
            b_im,
            seed: parts[0].seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_unit_power() {
        let a = TransmissionMatrix::sample(1, 50, 80);
        let b = TransmissionMatrix::sample(1, 50, 80);
        assert_eq!(a.b_re, b.b_re);
        let power: f32 = a
            .b_re
            .data()
            .iter()
            .zip(a.b_im.data())
            .map(|(r, i)| r * r + i * i)
            .sum::<f32>()
            / (50.0 * 80.0);
        assert!((power - 1.0).abs() < 0.05, "mean |B|² = {power}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = TransmissionMatrix::sample(1, 10, 10);
        let b = TransmissionMatrix::sample(2, 10, 10);
        assert!(a.b_re.max_abs_diff(&b.b_re) > 0.1);
    }

    #[test]
    fn stream_row_is_deterministic_and_independent() {
        let (r0a, i0a) = TransmissionMatrix::stream_row(9, 0, 32);
        let (r0b, _) = TransmissionMatrix::stream_row(9, 0, 32);
        let (r1, i1) = TransmissionMatrix::stream_row(9, 1, 32);
        assert_eq!(r0a, r0b);
        assert_ne!(r0a, r1);
        assert_ne!(i0a, i1);
    }

    #[test]
    fn split_concat_roundtrips() {
        let full = TransmissionMatrix::sample(4, 12, 37);
        for shards in [1usize, 2, 3, 5, 7, 37] {
            let parts = full.split_modes(shards);
            assert_eq!(parts.len(), shards);
            let widths: Vec<usize> = parts.iter().map(|p| p.modes).collect();
            assert_eq!(widths.iter().sum::<usize>(), 37);
            assert!(widths.iter().max().unwrap() - widths.iter().min().unwrap() <= 1);
            let back = TransmissionMatrix::concat_modes(&parts);
            assert_eq!(back.b_re, full.b_re);
            assert_eq!(back.b_im, full.b_im);
        }
    }

    #[test]
    fn slice_is_a_column_window() {
        let full = TransmissionMatrix::sample(9, 5, 10);
        let mid = full.slice_modes(3, 7);
        assert_eq!(mid.modes, 4);
        for r in 0..5 {
            for c in 0..4 {
                assert_eq!(mid.b_re.at(r, c), full.b_re.at(r, 3 + c));
                assert_eq!(mid.b_im.at(r, c), full.b_im.at(r, 3 + c));
            }
        }
    }

    #[test]
    fn streamed_projection_matches_dense_structure() {
        // Not the same matrix as `sample` (different streams), but same
        // statistics and exact linearity: P(e1 + e2) = P(e1) + P(e2).
        let modes = 64;
        let e1: Vec<f32> = (0..10).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let e2: Vec<f32> = (0..10).map(|i| if i % 4 == 1 { -1.0 } else { 0.0 }).collect();
        let sum: Vec<f32> = e1.iter().zip(&e2).map(|(a, b)| a + b).collect();
        let (p1, _) = TransmissionMatrix::project_streamed(3, &e1, modes);
        let (p2, _) = TransmissionMatrix::project_streamed(3, &e2, modes);
        let (ps, _) = TransmissionMatrix::project_streamed(3, &sum, modes);
        for j in 0..modes {
            assert!((ps[j] - p1[j] - p2[j]).abs() < 1e-5);
        }
    }
}
