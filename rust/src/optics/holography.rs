//! Off-axis holography demodulation: counts → complex field estimate.
//!
//! Two implementations (see `python/compile/optics.py` for the physics
//! derivation, identical on both sides):
//!
//! * [`demod_quadrature`] — spatial phase stepping.  With the carrier at
//!   k = π/2 rad/pixel and 4 pixels per macropixel, the four pixels of
//!   mode `m` sample the interference at phases 0, π/2, π, 3π/2, so
//!   `Re y = (I₀-I₂)/4A`, `Im y = (I₁-I₃)/4A` and the DC terms cancel
//!   exactly.  This is the hot path.
//! * [`demod_fft`] — the textbook Fourier side-band filter (mix down by
//!   e^{+ikp}, low-pass, macropixel average).  Exact for smooth fields;
//!   has known truncation error on blocky macropixels — kept as the
//!   reference implementation and validated against quadrature at the
//!   correlation level (mirrors the python test).

use crate::util::fft;

/// Quadrature demodulation of one frame.
/// `counts`: `4·modes` ADC values; returns `(re, im)` of length `modes`.
pub fn demod_quadrature(counts: &[f32], modes: usize, amp: f64, gain: f64) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(counts.len(), 4 * modes, "off-axis frame is 4 px/mode");
    let scale = (gain / (4.0 * amp)) as f32;
    let mut re = vec![0.0f32; modes];
    let mut im = vec![0.0f32; modes];
    for m in 0..modes {
        let i0 = counts[4 * m];
        let i1 = counts[4 * m + 1];
        let i2 = counts[4 * m + 2];
        let i3 = counts[4 * m + 3];
        re[m] = (i0 - i2) * scale;
        im[m] = (i1 - i3) * scale;
    }
    (re, im)
}

/// Fourier side-band demodulation of one frame (reference path).
/// `carrier` in rad/pixel; `oversample` pixels per mode.
pub fn demod_fft(
    counts: &[f32],
    modes: usize,
    oversample: usize,
    carrier: f64,
    amp: f64,
    gain: f64,
) -> (Vec<f32>, Vec<f32>) {
    let npix = modes * oversample;
    assert_eq!(counts.len(), npix);
    assert!(npix.is_power_of_two(), "fft path needs power-of-two frames");

    // Mix down: I(p)·e^{+ikp} puts the A·y term at baseband.
    let mut sig: Vec<fft::C64> = (0..npix)
        .map(|p| {
            let i = counts[p] as f64 * gain;
            let ph = carrier * p as f64;
            (i * ph.cos(), i * ph.sin())
        })
        .collect();
    fft::fft_in_place(&mut sig, false);

    // Low-pass: keep |f| < npix·carrier/(4π) bins (half the carrier).
    let cutoff = (npix as f64 * carrier / (4.0 * std::f64::consts::PI)) as usize;
    for (bin, v) in sig.iter_mut().enumerate() {
        let f = if bin <= npix / 2 { bin } else { npix - bin };
        if f >= cutoff {
            *v = (0.0, 0.0);
        }
    }
    let base = fft::ifft(&sig);

    // Per-macropixel average, divided by the reference amplitude.
    let mut re = vec![0.0f32; modes];
    let mut im = vec![0.0f32; modes];
    for m in 0..modes {
        let mut sr = 0.0;
        let mut si = 0.0;
        for o in 0..oversample {
            sr += base[m * oversample + o].0;
            si += base[m * oversample + o].1;
        }
        re[m] = (sr / (oversample as f64 * amp)) as f32;
        im[m] = (si / (oversample as f64 * amp)) as f32;
    }
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optics::camera::Camera;
    use crate::util::rng::Pcg64;
    use crate::util::stats::correlation;

    const K: f64 = std::f64::consts::FRAC_PI_2;

    /// Build a noiseless frame for a known field and demodulate.
    fn make_frame(modes: usize, amp: f64, gain: f64, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seeded(seed);
        let yre: Vec<f32> = (0..modes).map(|_| rng.next_normal_f32()).collect();
        let yim: Vec<f32> = (0..modes).map(|_| rng.next_normal_f32()).collect();
        let yre_pix: Vec<f32> = yre.iter().flat_map(|&v| [v; 4]).collect();
        let yim_pix: Vec<f32> = yim.iter().flat_map(|&v| [v; 4]).collect();
        let cam = Camera::new(4 * modes, K, amp, gain);
        let mut counts = vec![0.0f32; 4 * modes];
        cam.expose(&yre_pix, &yim_pix, -1.0, 0.0, &mut rng, &mut counts);
        (counts, yre, yim)
    }

    #[test]
    fn quadrature_recovers_field_to_adc_lsb() {
        let (amp, gain) = (16.0, 2.0);
        let (counts, yre, yim) = make_frame(64, amp, gain, 1);
        let (re, im) = demod_quadrature(&counts, 64, amp, gain);
        let lsb = (gain / (4.0 * amp)) as f32;
        for m in 0..64 {
            assert!((re[m] - yre[m]).abs() <= 1.5 * lsb, "mode {m}");
            assert!((im[m] - yim[m]).abs() <= 1.5 * lsb, "mode {m}");
        }
    }

    #[test]
    fn quadrature_dc_cancellation_is_exact() {
        // Huge DC (strong |y|²) must not leak: use large signal.
        let (amp, gain) = (40.0, 8.0);
        let (counts, yre, _) = make_frame(32, amp, gain, 2);
        let (re, _) = demod_quadrature(&counts, 32, amp, gain);
        let lsb = (gain / (4.0 * amp)) as f32;
        for m in 0..32 {
            assert!((re[m] - yre[m]).abs() <= 1.5 * lsb);
        }
    }

    #[test]
    fn fft_demod_correlates_with_truth() {
        let (amp, gain) = (16.0, 2.0);
        let (counts, yre, yim) = make_frame(128, amp, gain, 3);
        let (re, im) = demod_fft(&counts, 128, 4, K, amp, gain);
        let c_re = correlation(
            &re.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &yre.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        let c_im = correlation(
            &im.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &yim.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        // > 0.9: the hard LPF on blocky macropixels has inherent
        // truncation error (module docstring); quadrature is the exact
        // path and is tested to ADC precision above.
        assert!(c_re > 0.9, "re correlation {c_re}");
        assert!(c_im > 0.9, "im correlation {c_im}");
    }

    #[test]
    fn fft_and_quadrature_agree() {
        let (amp, gain) = (16.0, 2.0);
        let (counts, _, _) = make_frame(128, amp, gain, 4);
        let (qr, _) = demod_quadrature(&counts, 128, amp, gain);
        let (fr, _) = demod_fft(&counts, 128, 4, K, amp, gain);
        let c = correlation(
            &fr.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &qr.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!(c > 0.95, "correlation {c}");
    }

    #[test]
    #[should_panic(expected = "4 px/mode")]
    fn quadrature_rejects_wrong_size() {
        demod_quadrature(&[0.0; 10], 4, 16.0, 2.0);
    }
}
