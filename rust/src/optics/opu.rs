//! The OPU device: SLM → medium → camera → demodulation, with frame
//! clock and energy accounting.
//!
//! [`OpticalOpu::project`] is the rust-native request-path implementation
//! of the photonic co-processor: it takes a batch of ternary error
//! frames and returns the two recovered projection quadratures, charging
//! one camera frame of simulated time per sample (the paper's 1.5 kHz is
//! the loop's pacing element — accounted on a [`SimClock`], not slept).

use anyhow::Result;

use super::camera::Camera;
use super::holography;
use super::medium::TransmissionMatrix;
use super::slm::Slm;
use super::stream::Medium;
use crate::sim::clock::SimClock;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Physical constants of the simulated device.  Mirrors
/// `python/compile/optics.py::OpuConfig`; loaded from the artifact
/// manifest so both implementations describe the same hardware.
#[derive(Clone, Copy, Debug)]
pub struct OpuParams {
    pub oversample: usize,
    pub carrier: f64,
    pub amp: f64,
    pub n_ph: f32,
    pub read_sigma: f32,
    pub frame_rate_hz: f64,
    pub power_watts: f64,
    pub max_modes: usize,
}

impl Default for OpuParams {
    fn default() -> Self {
        OpuParams {
            oversample: 4,
            carrier: std::f64::consts::FRAC_PI_2,
            amp: 16.0,
            n_ph: 100.0,
            read_sigma: 2.0,
            frame_rate_hz: 1500.0,
            power_watts: 30.0,
            max_modes: 100_000,
        }
    }
}

impl OpuParams {
    /// ADC gain auto-ranged to the input dimension (same formula as the
    /// python twin: headroom of 4.5σ of the field over the reference).
    pub fn gain_for(&self, d_in: usize) -> f64 {
        let peak = (self.amp + 4.5 * (d_in as f64 / 2.0).sqrt()).powi(2);
        peak / 250.0
    }
}

/// Statistics the device keeps about itself.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpuStats {
    pub frames: u64,
    pub dropped_frames: u64,
    pub sim_seconds: f64,
    pub energy_joules: f64,
}

/// The simulated photonic co-processor.
pub struct OpticalOpu {
    params: OpuParams,
    medium: Medium,
    slm: Slm,
    camera: Camera,
    noise_rng: Pcg64,
    clock: SimClock,
    stats: OpuStats,
    // Reusable scratch (hot path is allocation-free after warmup).
    scratch_pix: Vec<f32>,
    scratch_counts: Vec<f32>,
}

/// Base PCG stream id of a device's camera-noise generator.  Farm shard
/// `i` draws from stream `NOISE_STREAM_BASE + i`, so shard 0 of a
/// one-shard farm is bit-identical to a standalone device while every
/// further shard gets an independent, reproducible noise stream.
pub const NOISE_STREAM_BASE: u64 = 0xca3e4a;

impl OpticalOpu {
    pub fn new(params: OpuParams, medium: TransmissionMatrix, noise_seed: u64) -> Self {
        Self::with_noise_stream(params, medium, noise_seed, NOISE_STREAM_BASE)
    }

    /// Like [`OpticalOpu::new`] with an explicit PCG noise stream —
    /// virtual farm devices share a seed but must not share draws.
    pub fn with_noise_stream(
        params: OpuParams,
        medium: TransmissionMatrix,
        noise_seed: u64,
        noise_stream: u64,
    ) -> Self {
        Self::with_medium(params, Medium::Dense(medium), noise_seed, noise_stream)
    }

    /// The backing-polymorphic constructor: the device is identical
    /// physics over either [`Medium`] backing — a streamed medium gives
    /// the same field at the camera plane bit for bit, so the noise
    /// draws, the ADC counts and the demodulated quadratures all agree
    /// with the dense device of the same seed.
    pub fn with_medium(
        params: OpuParams,
        medium: Medium,
        noise_seed: u64,
        noise_stream: u64,
    ) -> Self {
        assert!(
            medium.modes() <= params.max_modes,
            "medium has {} modes; device supports {}",
            medium.modes(),
            params.max_modes
        );
        let npix = params.oversample * medium.modes();
        let gain = params.gain_for(medium.d_in());
        let camera = Camera::new(npix, params.carrier, params.amp, gain);
        let slm = Slm::new(medium.d_in());
        OpticalOpu {
            params,
            slm,
            camera,
            noise_rng: Pcg64::new(noise_seed, noise_stream),
            clock: SimClock::new(),
            stats: OpuStats::default(),
            scratch_pix: vec![0.0; 2 * npix],
            scratch_counts: vec![0.0; npix],
            medium,
        }
    }

    /// Replace the SLM (failure injection: dead pixels, frame drops).
    pub fn set_slm(&mut self, slm: Slm) {
        assert_eq!(slm.d_in, self.medium.d_in());
        self.slm = slm;
    }

    /// Override camera noise levels (E5 noise sweeps).
    pub fn set_noise(&mut self, n_ph: f32, read_sigma: f32) {
        self.params.n_ph = n_ph;
        self.params.read_sigma = read_sigma;
    }

    pub fn params(&self) -> &OpuParams {
        &self.params
    }

    pub fn medium(&self) -> &Medium {
        &self.medium
    }

    pub fn stats(&self) -> OpuStats {
        self.stats
    }

    pub fn modes(&self) -> usize {
        self.medium.modes()
    }

    /// Share a simulated clock with the coordinator.
    pub fn attach_clock(&mut self, clock: SimClock) {
        self.clock = clock;
    }

    /// Project a batch of ternary frames `[B, d_in]` through the optical
    /// pipeline.  Returns `(P1, P2) = (Re ŷ, Im ŷ)`, each `[B, modes]`.
    ///
    /// Every *sample* is one camera frame: B frames of simulated time and
    /// energy are charged.  Dropped frames (SLM failure injection) are
    /// re-exposed — the retry is also charged, like real hardware.
    pub fn project(&mut self, frames: &Tensor) -> Result<(Tensor, Tensor)> {
        let (shown, displayed) = self.slm.encode(frames, &mut self.noise_rng)?;
        let batch = shown.rows();
        let modes = self.medium.modes();
        let os = self.params.oversample;
        let npix = os * modes;

        // Scattering: complex field at the camera plane for every sample.
        // (The physical device does this in the light; numerically it is
        // the projection itself — dense f32 matmul or the streamed tile
        // engine, bitwise the same field either way.)
        let (yre, yim) = self.medium.project(&shown, None);

        let mut p1 = Tensor::zeros(&[batch, modes]);
        let mut p2 = Tensor::zeros(&[batch, modes]);
        let gain = self.camera.gain;
        let amp = self.camera.amp;

        for b in 0..batch {
            // Dropped frame: the camera missed the exposure — retry once
            // (charged), mirroring the driver's re-arm behaviour.
            let retries = if displayed[b] { 1 } else { 2 };
            self.stats.frames += retries as u64 - 1;
            self.stats.dropped_frames += (retries - 1) as u64;

            // Macropixel expansion into reusable scratch.
            let (pix_re, pix_im) = self.scratch_pix.split_at_mut(npix);
            for m in 0..modes {
                let vre = yre.at(b, m);
                let vim = yim.at(b, m);
                for o in 0..os {
                    pix_re[m * os + o] = vre;
                    pix_im[m * os + o] = vim;
                }
            }
            self.camera.expose(
                pix_re,
                pix_im,
                self.params.n_ph,
                self.params.read_sigma,
                &mut self.noise_rng,
                &mut self.scratch_counts,
            );
            let (re, im) =
                holography::demod_quadrature(&self.scratch_counts, modes, amp, gain);
            p1.data_mut()[b * modes..(b + 1) * modes].copy_from_slice(&re);
            p2.data_mut()[b * modes..(b + 1) * modes].copy_from_slice(&im);

            self.stats.frames += 1;
        }

        // Timing/energy: every exposure (incl. retries) takes one frame.
        let exposures =
            batch as f64 + displayed.iter().filter(|&&d| !d).count() as f64;
        let secs = exposures / self.params.frame_rate_hz;
        self.clock.advance_secs(secs);
        self.stats.sim_seconds += secs;
        self.stats.energy_joules += secs * self.params.power_watts;
        Ok((p1, p2))
    }

    /// Simulated seconds consumed so far.
    pub fn sim_seconds(&self) -> f64 {
        self.stats.sim_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;

    fn device(modes: usize) -> OpticalOpu {
        let medium = TransmissionMatrix::sample(1, 10, modes);
        OpticalOpu::new(OpuParams::default(), medium, 2)
    }

    fn ternary_batch(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn shapes_and_accounting() {
        let mut opu = device(32);
        let e = ternary_batch(8, 10, 3);
        let (p1, p2) = opu.project(&e).unwrap();
        assert_eq!(p1.shape(), &[8, 32]);
        assert_eq!(p2.shape(), &[8, 32]);
        let st = opu.stats();
        assert_eq!(st.frames, 8);
        assert!((st.sim_seconds - 8.0 / 1500.0).abs() < 1e-12);
        assert!((st.energy_joules - 30.0 * 8.0 / 1500.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_projection_correlates_with_exact() {
        let mut opu = device(64);
        let e = ternary_batch(16, 10, 4);
        let (p1, _) = opu.project(&e).unwrap();
        let exact = matmul(&e, &TransmissionMatrix::sample(1, 10, 64).b_re);
        let c = crate::util::stats::correlation(
            &p1.data().iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &exact.data().iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!(c > 0.97, "correlation {c}");
    }

    #[test]
    fn noise_knob_changes_error() {
        let e = ternary_batch(16, 10, 5);
        let err_at = |n_ph: f32| {
            let mut opu = device(64);
            opu.set_noise(n_ph, 0.0);
            let exact = matmul(&e, &TransmissionMatrix::sample(1, 10, 64).b_re);
            let (p1, _) = opu.project(&e).unwrap();
            p1.max_abs_diff(&exact)
        };
        assert!(err_at(5.0) > err_at(1e6));
    }

    #[test]
    fn rejects_non_ternary() {
        let mut opu = device(16);
        let mut e = ternary_batch(2, 10, 6);
        e.data_mut()[0] = 0.5;
        assert!(opu.project(&e).is_err());
    }

    #[test]
    fn dropped_frames_are_retried_and_charged() {
        let mut opu = device(16);
        opu.set_slm(Slm::new(10).with_drop_prob(0.5));
        let e = ternary_batch(64, 10, 7);
        let (p1, _) = opu.project(&e).unwrap();
        assert_eq!(p1.shape(), &[64, 16]);
        let st = opu.stats();
        assert!(st.dropped_frames > 10, "{st:?}");
        assert_eq!(st.frames, 64 + st.dropped_frames);
        // charged time includes retries
        assert!(st.sim_seconds > 64.0 / 1500.0);
    }

    #[test]
    fn base_stream_matches_default_constructor() {
        let medium = TransmissionMatrix::sample(1, 10, 16);
        let mut a = OpticalOpu::new(OpuParams::default(), medium.clone(), 9);
        let mut b =
            OpticalOpu::with_noise_stream(OpuParams::default(), medium, 9, NOISE_STREAM_BASE);
        let e = ternary_batch(4, 10, 8);
        assert_eq!(a.project(&e).unwrap().0, b.project(&e).unwrap().0);
    }

    #[test]
    fn shard_streams_decorrelate() {
        let medium = TransmissionMatrix::sample(1, 10, 16);
        let mut a = OpticalOpu::with_noise_stream(
            OpuParams::default(),
            medium.clone(),
            9,
            NOISE_STREAM_BASE,
        );
        let mut b = OpticalOpu::with_noise_stream(
            OpuParams::default(),
            medium,
            9,
            NOISE_STREAM_BASE + 1,
        );
        let e = ternary_batch(4, 10, 8);
        let (pa, _) = a.project(&e).unwrap();
        let (pb, _) = b.project(&e).unwrap();
        // Same physics, different noise draws: close but not identical.
        assert_ne!(pa, pb);
    }

    #[test]
    fn streamed_device_is_bitwise_the_dense_device_even_with_noise() {
        // The backing decides how the field is computed, not what it is:
        // identical field → identical noise draws → identical counts.
        let dense = TransmissionMatrix::sample(1, 10, 32);
        let mut a = OpticalOpu::new(OpuParams::default(), dense, 9);
        let mut b = OpticalOpu::with_medium(
            OpuParams::default(),
            Medium::Streamed(crate::optics::stream::StreamedMedium::new(1, 10, 32)),
            9,
            NOISE_STREAM_BASE,
        );
        for step in 0..3 {
            let e = ternary_batch(4, 10, 50 + step);
            let (a1, a2) = a.project(&e).unwrap();
            let (b1, b2) = b.project(&e).unwrap();
            assert_eq!(a1, b1, "step {step}");
            assert_eq!(a2, b2, "step {step}");
        }
        assert_eq!(a.stats().frames, b.stats().frames);
    }

    #[test]
    fn deterministic_given_seeds() {
        let medium = TransmissionMatrix::sample(1, 10, 16);
        let mut a = OpticalOpu::new(OpuParams::default(), medium.clone(), 9);
        let mut b = OpticalOpu::new(OpuParams::default(), medium, 9);
        let e = ternary_batch(4, 10, 8);
        let (pa, _) = a.project(&e).unwrap();
        let (pb, _) = b.project(&e).unwrap();
        assert_eq!(pa, pb);
    }
}
