//! The simulated Optical Processing Unit (OPU) — rust-native physics.
//!
//! This is the substitution for the paper's photonic hardware (DESIGN.md
//! §2): a physics-level simulation of LightOn's OPU modified for off-axis
//! holography, faithful to the stages that shape the learning signal:
//!
//! ```text
//!  ternary e ──SLM──▶ coherent beam ──scattering (fixed complex B)──▶
//!      field y = e·B ──+ tilted reference──▶ camera |y + A·e^{ikp}|²
//!      ──shot/read noise, 8-bit ADC──▶ counts ──demodulation──▶ ŷ ≈ y
//! ```
//!
//! `Re(ŷ)` and `Im(ŷ)` are two independent Gaussian random projections of
//! `e` — one optical frame feeds both hidden layers of the paper's MLP.
//!
//! The same physics exists as a JAX twin (`python/compile/optics.py`,
//! AOT-lowered to the `opu_project` artifact); `rust/tests/` cross-checks
//! the two implementations numerically.  The rust-native path is the
//! default device because it allows runtime noise sweeps (E5) and
//! arbitrary sizes (E2/E4) without re-lowering.
//!
//! Module map: [`medium`] (transmission matrix, counter-addressable row
//! streams), [`stream`] (the streamed/memory-less projection engine and
//! the [`stream::Medium`] backing policy), [`slm`] (input encoding +
//! failure injection), [`camera`] (intensity, noise, ADC),
//! [`holography`] (demodulation, quadrature + FFT), [`opu`] (the device:
//! frame clock, energy accounting, end-to-end `project`).

pub mod camera;
pub mod holography;
pub mod medium;
pub mod opu;
pub mod slm;
pub mod stream;

pub use opu::{OpticalOpu, OpuParams, NOISE_STREAM_BASE};
pub use stream::{Medium, StreamedMedium};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    /// End-to-end: noiseless optical projection ≈ exact digital one.
    #[test]
    fn end_to_end_recovery() {
        let params = OpuParams {
            n_ph: 1e12,
            read_sigma: 0.0,
            ..OpuParams::default()
        };
        let medium = medium::TransmissionMatrix::sample(7, 10, 64);
        let mut opu = OpticalOpu::new(params, medium.clone(), 123);
        let mut rng = Pcg64::seeded(3);
        let mut e = Tensor::zeros(&[4, 10]);
        for v in e.data_mut() {
            *v = ((rng.next_below(3) as i64) - 1) as f32;
        }
        let (p1, p2) = opu.project(&e).unwrap();
        let exact1 = crate::tensor::matmul(&e, &medium.b_re);
        let exact2 = crate::tensor::matmul(&e, &medium.b_im);
        let lsb = (opu.params().gain_for(10) / (4.0 * opu.params().amp)) as f32;
        assert!(p1.max_abs_diff(&exact1) <= 1.5 * lsb);
        assert!(p2.max_abs_diff(&exact2) <= 1.5 * lsb);
    }
}
